//! Live walkthrough of paper Table 3: toggle the three Streaming-dLLM
//! modules (Suf. / Dyn. / Exit.) one at a time on GSM-mini and watch
//! accuracy + throughput respond. Runs on any backend (PJRT artifacts
//! or the pure-Rust reference model).
//!
//! ```sh
//! cargo run --release --example ablation_walkthrough -- --n 16
//! ```

use anyhow::Result;
use streaming_dllm::engine::{AnyBackend, GenConfig, Method};
use streaming_dllm::eval::{run_suite, suite_for};
use streaming_dllm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "llada15-mini");
    let n = args.get_usize("n", 16);
    let gen_len = args.get_usize("gen-len", 128);

    let root = streaming_dllm::artifacts_root();
    let backend = AnyBackend::auto(&root, model)?;
    let items = suite_for(&backend, &root, "gsm-mini")?;
    let items = &items[..n.min(items.len())];

    println!(
        "Table 3 ablation — {model} [{}], gsm-mini, L={gen_len} (paper: L=512)",
        backend.describe()
    );
    println!(
        "{:<8}{:<8}{:<8}{:>10}{:>14}{:>10}",
        "Suf.", "Dyn.", "Exit.", "Acc.(%)", "Th.(tok/s)", "NFE"
    );

    // (suf, dyn, exit) in the paper's row order
    let rows =
        [(false, false, false), (true, false, false), (true, true, false), (true, true, true)];
    for (suf, dynamic, exit) in rows {
        let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
        cfg.set_suffix_pruning(suf);
        cfg.set_dynamic_threshold(dynamic);
        cfg.early_exit = exit;
        let res = run_suite(&backend, &cfg, items, None)?;
        println!(
            "{:<8}{:<8}{:<8}{:>10.1}{:>14.1}{:>10.1}",
            mark(suf),
            mark(dynamic),
            mark(exit),
            res.accuracy(),
            res.tokens_per_sec(),
            res.steps as f64 / items.len() as f64
        );
    }
    println!("\n(row 1 = Fast-dLLM-equivalent baseline; row 4 = full Streaming-dLLM)");
    Ok(())
}

fn mark(b: bool) -> &'static str {
    if b {
        "Y"
    } else {
        "x"
    }
}
