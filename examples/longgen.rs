//! Long-generation scaling demo (paper Table 5 shape): as the target
//! generation length grows, vanilla throughput collapses while
//! Streaming-dLLM stays nearly flat — early exit stops at the answer,
//! suffix pruning caps per-step cost.
//!
//! ```sh
//! cargo run --release --example longgen -- --n 4
//! ```

use anyhow::Result;
use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::{load_suite, run_suite};
use streaming_dllm::runtime::{ArtifactsIndex, ModelRuntime, Runtime};
use streaming_dllm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "llada15-mini");
    let n = args.get_usize("n", 4);

    let root = streaming_dllm::artifacts_root();
    let index = ArtifactsIndex::load(&root)?;
    let rt = Runtime::cpu()?;
    let mrt = ModelRuntime::load(&rt, &index.model_dir(model))?;
    let items = load_suite(&index.eval_dir.join("gsm-mini.jsonl"))?;
    let items = &items[..n.min(items.len())];

    println!("generation-length scaling — {model}, gsm-mini (paper Table 5, lengths ÷4)");
    println!("{:<10}{:>14}{:>16}{:>14}{:>12}", "L", "method", "tok/s", "s/sample", "speedup");
    for gen_len in [128usize, 256, 512] {
        let mut base_tps = 0.0;
        for method in [Method::Vanilla, Method::FastDllm, Method::Streaming] {
            let cfg = GenConfig::preset(method, gen_len);
            let res = run_suite(&mrt, &cfg, items, None)?;
            let tps = res.tokens_per_sec();
            if method == Method::Vanilla {
                base_tps = tps;
            }
            println!(
                "{:<10}{:>14}{:>16.2}{:>14.2}{:>11.1}x",
                gen_len,
                method.name(),
                tps,
                res.mean_latency(),
                if base_tps > 0.0 { tps / base_tps } else { 0.0 }
            );
        }
    }
    Ok(())
}
