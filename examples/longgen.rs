//! Long-generation scaling demo (paper Table 5 shape): as the target
//! generation length grows, vanilla throughput collapses while
//! Streaming-dLLM stays nearly flat — early exit stops at the answer,
//! suffix pruning caps per-step cost. Runs on any backend (PJRT
//! artifacts or the pure-Rust reference model).
//!
//! ```sh
//! cargo run --release --example longgen -- --n 4
//! ```

use anyhow::Result;
use streaming_dllm::engine::{AnyBackend, GenConfig, Method};
use streaming_dllm::eval::{run_suite, suite_for};
use streaming_dllm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "llada15-mini");
    let n = args.get_usize("n", 4);

    let root = streaming_dllm::artifacts_root();
    let backend = AnyBackend::auto(&root, model)?;
    let items = suite_for(&backend, &root, "gsm-mini")?;
    let items = &items[..n.min(items.len())];

    println!(
        "generation-length scaling — {model} [{}], gsm-mini (paper Table 5, lengths ÷4)",
        backend.describe()
    );
    println!("{:<10}{:>14}{:>16}{:>14}{:>12}", "L", "method", "tok/s", "s/sample", "speedup");
    for gen_len in [128usize, 256, 512] {
        let mut base_tps = 0.0;
        for method in [Method::Vanilla, Method::FastDllm, Method::Streaming] {
            let cfg = GenConfig::preset(method, gen_len);
            let res = run_suite(&backend, &cfg, items, None)?;
            let tps = res.tokens_per_sec();
            if method == Method::Vanilla {
                base_tps = tps;
            }
            println!(
                "{:<10}{:>14}{:>16.2}{:>14.3}{:>11.1}x",
                gen_len,
                method.name(),
                tps,
                res.mean_latency(),
                if base_tps > 0.0 { tps / base_tps } else { 0.0 }
            );
        }
    }
    Ok(())
}
