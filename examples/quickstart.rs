//! Quickstart: pick the best available backend (PJRT artifacts when
//! built with `--features pjrt` and `make artifacts` has run, the
//! deterministic reference model otherwise), decode a few GSM-mini
//! prompts with the vanilla schedule and with Streaming-dLLM, and print
//! the texts plus the speedup.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use streaming_dllm::engine::{AnyBackend, Backend, GenConfig, Generator, Method, SeqState};
use streaming_dllm::eval::{extract_final, suite_for};
use streaming_dllm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "llada15-mini");
    let n = args.get_usize("n", 5);

    let root = streaming_dllm::artifacts_root();
    let backend = AnyBackend::auto(&root, model)?;
    println!("backend: {}", backend.describe());

    let items = suite_for(&backend, &root, "gsm-mini")?;
    let items = &items[..n.min(items.len())];

    for method in [Method::Vanilla, Method::Streaming] {
        let cfg = GenConfig::preset(method, 64);
        let mut generator = Generator::new(&backend, cfg.clone())?;
        println!("\n== {} (L={}, K={}) ==", method.name(), cfg.gen_len, cfg.block_size);
        let mut correct = 0;
        let mut wall = 0.0;
        let mut tokens = 0u64;
        for item in items {
            let mut seqs = vec![SeqState::new(&item.prompt, cfg.gen_len, &backend.special())];
            let report = generator.generate(&mut seqs, None)?;
            let text = backend.detokenize(seqs[0].generated());
            let ok = extract_final(&text) == item.answer;
            correct += ok as usize;
            wall += report.wall_secs;
            tokens += report.non_eos_tokens;
            println!(
                "  {:<28} -> {:<24} [{}] {} steps, {:.3}s",
                format!("…{}", truncate(&backend.detokenize(&item.prompt), 26)),
                text,
                if ok { "ok" } else { "WRONG" },
                report.steps,
                report.wall_secs
            );
        }
        println!(
            "  accuracy {}/{} | {:.1} tok/s | {:.3}s/sample",
            correct,
            items.len(),
            tokens as f64 / wall.max(1e-9),
            wall / items.len().max(1) as f64
        );
    }
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        s[s.len() - n..].to_string()
    }
}
