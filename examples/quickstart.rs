//! Quickstart: load a backbone, decode a few GSM-mini prompts with the
//! vanilla schedule and with Streaming-dLLM, and print the texts plus
//! the speedup. Run after `make artifacts`:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use streaming_dllm::engine::{GenConfig, Generator, Method, SeqState};
use streaming_dllm::eval::{extract_final, load_suite};
use streaming_dllm::runtime::{ArtifactsIndex, ModelRuntime, Runtime};
use streaming_dllm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let model = args.get_or("model", "llada15-mini");
    let n = args.get_usize("n", 5);

    let root = streaming_dllm::artifacts_root();
    let index = ArtifactsIndex::load(&root)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mrt = ModelRuntime::load(&rt, &index.model_dir(model))?;
    println!("model: {} ({} params arrays)", model, mrt.manifest.param_order.len());

    let items = load_suite(&index.eval_dir.join("gsm-mini.jsonl"))?;
    let items = &items[..n.min(items.len())];

    for method in [Method::Vanilla, Method::Streaming] {
        let cfg = GenConfig::preset(method, 64);
        let generator = Generator::new(&mrt, cfg.clone())?;
        println!("\n== {} (L={}, K={}) ==", method.name(), cfg.gen_len, cfg.block_size);
        let mut correct = 0;
        let mut wall = 0.0;
        let mut tokens = 0u64;
        for item in items {
            let mut seqs = vec![SeqState::new(&item.prompt, cfg.gen_len, &mrt.manifest.special)];
            let report = generator.generate(&mut seqs, None)?;
            let text = mrt.manifest.detokenize_until_eos(seqs[0].generated());
            let ok = extract_final(&text) == item.answer;
            correct += ok as usize;
            wall += report.wall_secs;
            tokens += report.non_eos_tokens;
            println!(
                "  {:<28} -> {:<24} [{}] {} steps, {:.2}s",
                format!("…{}", truncate(&mrt.manifest.detokenize_until_eos(&item.prompt), 26)),
                text,
                if ok { "ok" } else { "WRONG" },
                report.steps,
                report.wall_secs
            );
        }
        println!(
            "  accuracy {}/{} | {:.1} tok/s | {:.2}s/sample",
            correct,
            items.len(),
            tokens as f64 / wall,
            wall / items.len() as f64
        );
    }
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        s[s.len() - n..].to_string()
    }
}
