//! End-to-end serving driver (the EXPERIMENTS.md §End-to-end run):
//! boots the full stack — per-engine worker threads, dynamic batcher,
//! TCP server — fires concurrent client load from the eval suites, then
//! reports accuracy, throughput (non-EOS tok/s), latency percentiles
//! and server metrics. Proves all layers compose: rust coordinator →
//! model backend (PJRT AOT executables, or the pure-Rust reference
//! model on a bare checkout).
//!
//! Serving knobs (`--max-batch`, `--gen-lens`, `--deadline-ms`,
//! `--max-engines`, ...) resolve through [`ServeConfig`] with the same
//! CLI > `SDLLM_*` env > default precedence as the `serve` subcommand.
//!
//! ```sh
//! cargo run --release --example serve_batch -- --n 32 --concurrency 8
//! ```

use anyhow::Result;
use streaming_dllm::coordinator::{run_load, Request, RouterHandle, ServeConfig, Server};
use streaming_dllm::engine::{AnyBackend, Method};
use streaming_dllm::eval::{extract_final, suite_for, EvalItem};
use streaming_dllm::util::cli::Args;
use streaming_dllm::util::stats::Samples;

#[cfg(feature = "pjrt")]
fn spawn_router(root: &std::path::Path, cfg: &ServeConfig) -> RouterHandle {
    if AnyBackend::pjrt_available(root) {
        RouterHandle::spawn_pjrt_opts(
            root.to_path_buf(),
            cfg.model.clone(),
            cfg.router_options(),
        )
    } else {
        RouterHandle::spawn_reference_opts(cfg.ref_mode, cfg.router_options())
    }
}

#[cfg(not(feature = "pjrt"))]
fn spawn_router(_root: &std::path::Path, cfg: &ServeConfig) -> RouterHandle {
    RouterHandle::spawn_reference_opts(cfg.ref_mode, cfg.router_options())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let cfg = ServeConfig::from_env_and_args(&args)?;
    let n = args.get_usize("n", 32);
    let concurrency = args.get_usize("concurrency", 8);
    let method = Method::parse(args.get_or("method", "streaming")).expect("method");

    let root = cfg.artifacts_root();
    // The oracle backend only sources/scores the workload; every server
    // worker thread builds its own identical backend.
    let oracle = AnyBackend::auto_with(&root, &cfg.model, cfg.ref_mode)?;

    // mixed workload: round-robin over all four suites
    let suites = ["gsm-mini", "humaneval-mini", "mbpp-mini", "math-mini"];
    let mut pool: Vec<(String, EvalItem)> = vec![];
    for s in suites {
        for item in suite_for(&oracle, &root, s)? {
            pool.push((s.to_string(), item));
        }
    }
    let picked: Vec<(String, EvalItem)> = (0..n)
        .map(|i| pool[(i * 37) % pool.len()].clone())
        .collect();

    // boot the stack on an ephemeral port
    let router = spawn_router(&root, &cfg);
    let metrics = router.metrics.clone();
    let server = Server::bind("127.0.0.1:0", router)?;
    let addr = server.local_addr()?.to_string();
    println!(
        "serving {} [{}] on {addr}; {} reqs, {concurrency} conns, max_batch {} max_engines {}",
        cfg.model,
        oracle.describe(),
        picked.len(),
        cfg.max_batch,
        cfg.max_engines,
    );
    std::thread::scope(|scope| -> Result<()> {
        let srv = &server;
        let n_conns = concurrency;
        scope.spawn(move || {
            let _ = srv.serve_n(n_conns);
        });

        let requests: Vec<Request> = picked
            .iter()
            .enumerate()
            .map(|(i, (_, item))| Request {
                id: i as u64,
                prompt: item.prompt.clone(),
                method,
                policy: None,
                gen_len: cfg.gen_lens[i % cfg.gen_lens.len()],
                deadline_ms: cfg.deadline_ms,
                park_on_miss: false,
            })
            .collect();

        let t0 = std::time::Instant::now();
        let report = run_load(&addr, requests, concurrency)?;
        let wall = t0.elapsed().as_secs_f64();

        // score answers
        let mut correct = 0;
        let mut per_suite: std::collections::BTreeMap<&str, (usize, usize)> = Default::default();
        let mut total_tokens = 0usize;
        for resp in &report.responses {
            let (suite, item) = &picked[resp.id as usize];
            let ok = extract_final(&resp.text) == item.answer;
            correct += ok as usize;
            let e = per_suite.entry(suite.as_str()).or_default();
            e.0 += ok as usize;
            e.1 += 1;
            total_tokens += resp.non_eos_tokens;
        }
        let mut lat = Samples::new();
        for &l in &report.client_latencies {
            lat.push(l);
        }
        println!("\n=== end-to-end serving report ({}) ===", method.name());
        println!("requests ok/err: {}/{}", report.ok, report.errors);
        println!(
            "accuracy: {}/{} ({:.1}%)",
            correct,
            picked.len(),
            100.0 * correct as f64 / picked.len().max(1) as f64
        );
        for (s, (c, t)) in &per_suite {
            println!("  {s:<16} {c}/{t}");
        }
        println!(
            "wall: {wall:.2}s | throughput {:.1} non-EOS tok/s | {:.2} req/s",
            total_tokens as f64 / wall,
            report.ok as f64 / wall
        );
        println!(
            "client latency p50 {:.3}s p95 {:.3}s p99 {:.3}s",
            lat.percentile(50.0),
            lat.percentile(95.0),
            lat.percentile(99.0)
        );
        println!("server metrics: {}", metrics.snapshot().to_string());
        Ok(())
    })?;
    Ok(())
}
