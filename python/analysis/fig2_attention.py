"""Regenerates paper Figure 2: attention distribution at the final layer
between the currently generated block and the full input sequence.

The paper collects statistics over GSM8K samples (LLaDA-1.5, gen length
512, final layer 31): mean attention score per region (prefix / current
block / suffix) with the IQR band, showing that attention over the suffix
decays with distance — most intermediate suffix positions get near-zero
mass while the few blocks adjacent to the current block and the final
token dominate. That observation licenses attenuation-guided suffix
pruning.

Here: the trained llada15-mini backbone, gsm-mini prompts, gen length 64
(÷4 scale), final layer. Emits a CSV (distance-from-block → mean/q25/q75
attention) plus the per-region aggregate, and an ASCII sparkline of the
decay curve.

Usage:  cd python && python -m analysis.fig2_attention [--n 50]
"""

from __future__ import annotations

import argparse
import os
import random
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as M
from compile import tasks, tokenizer as tok
from compile.train import load_model


def attention_probe(cfg, params, tokens, pos, valid, layer):
    """Forward pass that captures the given layer's attention probs
    (pre-output-projection), averaged over heads: [B, T, T]."""
    h = params["emb"][tokens]
    mask = M.self_mask(cfg, pos, valid)
    probs_out = None
    for l in range(cfg.n_layers):
        x = M.rmsnorm(h, params[f"l{l}.ln1"], cfg.norm_eps)
        q = M.rope(M._split_heads(x @ params[f"l{l}.wq"], cfg.n_heads, cfg.d_head), pos, cfg.rope_base)
        k = M.rope(M._split_heads(x @ params[f"l{l}.wk"], cfg.n_heads, cfg.d_head), pos, cfg.rope_base)
        v = M._split_heads(x @ params[f"l{l}.wv"], cfg.n_heads, cfg.d_head)
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
        scores = jnp.einsum("bhqd,bhsd->bhqs", q, k) * scale
        scores = jnp.where(mask[:, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        if l == layer:
            probs_out = probs.mean(axis=1)  # head-mean [B, T, T]
        o = jnp.einsum("bhqs,bhsd->bhqd", probs, v)
        h = h + M._merge_heads(o) @ params[f"l{l}.wo"]
        x2 = M.rmsnorm(h, params[f"l{l}.ln2"], cfg.norm_eps)
        h = h + M.swiglu(x2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"])
    return probs_out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50)
    ap.add_argument("--model", default="llada15-mini")
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--block", type=int, default=1, help="current block index")
    ap.add_argument("--out", default="../artifacts/analysis")
    args = ap.parse_args()

    cfg, params = load_model("../artifacts", args.model)
    layer = cfg.n_layers - 1  # final layer (paper: layer 31)
    K = cfg.block_size
    L = args.gen_len
    rng = random.Random(7_200_000)

    # distance (in tokens) from the current block's end → attention mass
    by_distance: dict[int, list[float]] = {}
    region_mass = {"prefix": [], "current": [], "suffix": [], "final_tok": []}

    probe = jax.jit(lambda t, p, v: attention_probe(cfg, params, t, p, v, layer))

    for _ in range(args.n):
        prompt, _cot, _final = tasks.make_example("gsm-mini", rng)
        p0 = len(prompt)
        T = p0 + L
        toks = np.array(prompt + [tok.MASK] * L, np.int32)
        # paper setting: mid-generation, current block = args.block,
        # earlier blocks left masked-but-being-decoded is fine for the
        # aggregate statistic (the paper averages across diffusion steps)
        pos = np.arange(T, dtype=np.int32)
        probs = np.asarray(probe(jnp.asarray(toks[None]), jnp.asarray(pos[None]),
                                 jnp.asarray([T], np.int32)))[0]
        bs = p0 + args.block * K
        be = bs + K
        # rows = current block queries
        rows = probs[bs:be]  # [K, T]
        region_mass["prefix"].append(float(rows[:, :bs].sum(axis=1).mean()))
        region_mass["current"].append(float(rows[:, bs:be].sum(axis=1).mean()))
        region_mass["suffix"].append(float(rows[:, be:].sum(axis=1).mean()))
        region_mass["final_tok"].append(float(rows[:, T - 1].mean()))
        for col in range(be, T):
            by_distance.setdefault(col - be, []).append(float(rows[:, col].mean()))

    os.makedirs(args.out, exist_ok=True)
    csv_path = os.path.join(args.out, "fig2_attention.csv")
    with open(csv_path, "w") as f:
        f.write("distance,mean,q25,q75\n")
        for d in sorted(by_distance):
            xs = np.array(by_distance[d])
            f.write(f"{d},{xs.mean():.6f},{np.quantile(xs, 0.25):.6f},{np.quantile(xs, 0.75):.6f}\n")

    print(f"=== Figure 2 — suffix attention decay ({args.model}, layer {layer}, "
          f"block {args.block}, n={args.n}) ===")
    for name, xs in region_mass.items():
        print(f"  mean attention mass on {name:<10}: {np.mean(xs):.4f}")
    print("\ndistance-from-block decay (mean attention, suffix region):")
    ds = sorted(by_distance)
    vals = np.array([np.mean(by_distance[d]) for d in ds])
    peak = vals.max() if len(vals) else 1.0
    bars = "▁▂▃▄▅▆▇█"
    line = "".join(bars[min(int(v / peak * 7.999), 7)] for v in vals)
    print(f"  d=0..{ds[-1]}: {line}")
    head = vals[: min(8, len(vals))].mean()
    tail = vals[len(vals) // 2: -1].mean() if len(vals) > 4 else 0.0
    final_v = vals[-1]
    print(f"  near-window mean {head:.5f} vs distant-suffix mean {tail:.5f} "
          f"(ratio {head / max(tail, 1e-9):.1f}x); final token {final_v:.5f}")
    print(f"[saved {csv_path}]")
    print("(expected: attention concentrated on blocks adjacent to the current "
          "block and elevated again at the final token — the paper's Figure 2 shape)")


if __name__ == "__main__":
    main()
