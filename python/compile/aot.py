"""AOT lowering: JAX graphs → HLO text artifacts + manifest.

For every trained backbone this emits the executable grid the rust runtime
serves from (DESIGN.md "Executable grid"):

    prefill_b{B}_p{P}.hlo.txt   (params…, tokens, pos, valid[, p0]) → kv
    decode_b{B}_p{P}_q{Q}.hlo.txt (params…, kv, q_tok, q_pos,
                                   kv_valid, q_valid) → [B,Q,2]
    logits_b{B}_s{S}.hlo.txt    (params…, tokens, pos, valid[, p0]) → [B,S,2]

Interchange format is **HLO text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

``manifest.json`` records, per artifact: kind, bucket sizes, input
signature, and the parameter name/shape order — the contract the rust
``runtime/artifact.rs`` loads against. Buckets are chosen so suffix
pruning genuinely buys compute: the rust scheduler picks the smallest
bucket ≥ the live length and masks the padding.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tasks, tokenizer as tok
from .train import load_model

# Bucket grids (paper lengths ÷ 4 — see DESIGN.md scale substitution).
BATCH_GRID = [1, 4]
# prefix buckets: prompt (≤ ~210) + decoded blocks (≤ 512)
PREFIX_GRID = [96, 160, 224, 352, 800]
# query-bundle buckets: K + w + 1 for w ∈ {4..128}, plus full-suffix sizes
QUERY_GRID = [13, 17, 25, 41, 73, 137, 264, 520]
# full-sequence buckets (vanilla path): prompt + L
SEQ_GRID = [96, 160, 224, 352, 800]

MODELS = ["dream-mini", "llada-mini", "llada15-mini", "pangu-mini"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def param_specs(cfg: M.ModelConfig, params: dict):
    names = M.param_names(cfg)
    return [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]


def lower_one(cfg, params, kind, b, p=None, q=None, s=None):
    """Build + lower one executable; returns (fn_name, hlo_text, signature)."""
    pspecs = param_specs(cfg, params)
    n_params = len(pspecs)
    bc = cfg.attn_mode == "block_causal"
    nl, h, dh = cfg.n_layers, cfg.n_heads, cfg.d_head

    if kind == "prefill":
        def fn(*args):
            pr = M.unflatten_params(cfg, args[:n_params])
            tokens, pos, valid = args[n_params:n_params + 3]
            p0 = args[n_params + 3] if bc else None
            return M.prefill(cfg, pr, tokens, pos, valid, p0)
        specs = [_i32(b, p), _i32(b, p), _i32(b)] + ([_i32(b)] if bc else [])
        name = f"prefill_b{b}_p{p}"
    elif kind == "decode":
        def fn(*args):
            pr = M.unflatten_params(cfg, args[:n_params])
            kv, q_tok, q_pos, kv_valid, q_valid = args[n_params:]
            return M.decode(cfg, pr, kv, q_tok, q_pos, kv_valid, q_valid)
        specs = [_f32(nl, 2, b, h, p, dh), _i32(b, q), _i32(b, q),
                 _i32(b), _i32(b)]
        name = f"decode_b{b}_p{p}_q{q}"
    elif kind == "logits":
        def fn(*args):
            pr = M.unflatten_params(cfg, args[:n_params])
            tokens, pos, valid = args[n_params:n_params + 3]
            p0 = args[n_params + 3] if bc else None
            return M.logits_full(cfg, pr, tokens, pos, valid, p0)
        specs = [_i32(b, s), _i32(b, s), _i32(b)] + ([_i32(b)] if bc else [])
        name = f"logits_b{b}_s{s}"
    else:
        raise ValueError(kind)

    lowered = jax.jit(fn, keep_unused=True).lower(*(pspecs + specs))
    sig = [{"shape": list(sp.shape), "dtype": str(sp.dtype)} for sp in specs]
    return name, to_hlo_text(lowered), sig


def export_model(out_dir: str, name: str, decode_only_small: bool = False):
    cfg, params = load_model(out_dir, name)
    mdir = os.path.join(out_dir, "models", name)
    bc = cfg.attn_mode == "block_causal"

    artifacts = []

    def emit(kind, b, p=None, q=None, s=None):
        art_name, text, sig = lower_one(cfg, params, kind, b, p=p, q=q, s=s)
        path = os.path.join(mdir, art_name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append({
            "name": art_name, "kind": kind, "batch": b,
            "prefix": p, "query": q, "seq": s,
            "file": art_name + ".hlo.txt", "inputs": sig,
        })
        print(f"  {name}/{art_name} ({len(text)//1024} KiB)", flush=True)

    # block-causal serving only needs the small buckets (Table 7 runs at
    # gen length 64); trims ~40% of compile time.
    prefix_grid = PREFIX_GRID[:4] if decode_only_small else PREFIX_GRID
    query_grid = QUERY_GRID[:4] if decode_only_small else QUERY_GRID
    seq_grid = SEQ_GRID[:4] if decode_only_small else SEQ_GRID
    batch_grid = [1] if decode_only_small else BATCH_GRID

    for b in batch_grid:
        for p in prefix_grid:
            emit("prefill", b, p=p)
        for p in prefix_grid:
            for q in query_grid:
                emit("decode", b, p=p, q=q)
        for s in seq_grid:
            emit("logits", b, s=s)

    pnames = M.param_names(cfg)
    manifest = {
        "model": name,
        "attn_mode": cfg.attn_mode,
        "wants_p0": bc,
        "config": json.loads(cfg.to_json()),
        "special_tokens": {"pad": tok.PAD, "mask": tok.MASK, "bos": tok.BOS,
                           "eos": tok.EOS, "sep": tok.SEP},
        "vocab": tok.VOCAB,
        "params_file": "params.npz",
        "param_order": [
            {"name": n, "shape": list(np.asarray(params[n]).shape)}
            for n in pnames
        ],
        "kv_dims": {"layers": cfg.n_layers, "heads": cfg.n_heads,
                    "d_head": cfg.d_head},
        "buckets": {"batch": batch_grid, "prefix": prefix_grid,
                    "query": query_grid, "seq": seq_grid},
        "artifacts": artifacts,
    }
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"{name}: {len(artifacts)} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=MODELS)
    ap.add_argument("--skip-eval-data", action="store_true")
    args = ap.parse_args()

    if not args.skip_eval_data:
        written = tasks.export_all_eval(os.path.join(args.out, "eval"))
        print(f"eval data: {len(written)} files")

    for name in args.models:
        if load_model(args.out, name) is None:
            raise SystemExit(
                f"model {name} not trained; run `python -m compile.train` first")
        export_model(args.out, name,
                     decode_only_small=(name == "pangu-mini"))

    # top-level index the rust side discovers models through
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"models": args.models,
                   "eval_dir": "eval", "models_dir": "models"}, f, indent=1)
    print("wrote index.json")


if __name__ == "__main__":
    main()
