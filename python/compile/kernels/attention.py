"""L1 Pallas kernel: blocked masked bidirectional attention.

This is the paper's compute hot-spot re-thought for the TPU memory model
(DESIGN.md §Hardware-Adaptation): instead of the CUDA threadblock tiling a
GPU implementation would use, the KV stream is tiled into VMEM-sized
blocks and reduced with an online-softmax accumulator held in registers /
scratch. The grid is one program per (batch·head); each program loops over
KV tiles with `lax.fori_loop`, so the lowered HLO stays compact for AOT.

Suffix pruning (attenuation-guided suffix modeling) enters through the
*shape*: the query bundle is `[current block | suffix window | trailing
token]`, so a pruned bundle selects a smaller Q/S bucket and genuinely
fewer tiles.

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); correctness is pinned to ``ref.attention_ref`` by
hypothesis sweeps in ``python/tests/test_kernels.py``. TPU roofline
estimates for the real-hardware BlockSpec live in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# KV tile size: 128 keys per tile = an (8,128)-lane-aligned VMEM block on
# TPU; callers pad S to a multiple of KV_BLOCK (mask covers the padding).
KV_BLOCK = 128


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, kv_block: int):
    """One (batch, head) program: online-softmax over KV tiles.

    q_ref: [Qr, D]; k_ref, v_ref: [S, D]; mask_ref: [Qr, S] (i32 0/1);
    o_ref: [Qr, D]. S is a multiple of kv_block.
    """
    q = q_ref[...].astype(jnp.float32)
    qr, d = q.shape
    s_total = k_ref.shape[0]
    n_tiles = s_total // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def body(i, carry):
        m_prev, l_prev, acc = carry
        start = i * kv_block
        k_tile = pl.load(k_ref, (pl.dslice(start, kv_block), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(start, kv_block), slice(None)))
        mask_tile = pl.load(mask_ref, (slice(None), pl.dslice(start, kv_block)))
        # [Qr, kv_block] scores on the MXU (f32 accumulation).
        s = jax.lax.dot_general(
            q, k_tile.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        s = jnp.where(mask_tile != 0, s, NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask_tile != 0, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_tile.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    init = (
        jnp.full((qr,), NEG_INF, jnp.float32),
        jnp.zeros((qr,), jnp.float32),
        jnp.zeros((qr, d), jnp.float32),
    )
    _, l_fin, acc = jax.lax.fori_loop(0, n_tiles, body, init)
    # NaN guard: fully-masked rows (padded queries) produce zeros.
    denom = jnp.maximum(l_fin, 1e-30)[:, None]
    out = jnp.where((l_fin > 0.0)[:, None], acc / denom, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def attention(q, k, v, mask, *, kv_block: int = KV_BLOCK, interpret: bool = True):
    """Blocked masked attention via Pallas.

    q: [B, H, Qr, D]; k, v: [B, H, S, D]; mask: [B, Qr, S] bool
    (True = attendable). Returns [B, H, Qr, D] f32.

    S is padded internally to a multiple of ``kv_block`` (padding is
    masked out), so any bucket shape from the AOT grid is accepted.
    """
    b, h, qr, d = q.shape
    s = k.shape[2]
    pad = (-s) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))
    s_pad = s + pad

    q2 = q.reshape(b * h, qr, d)
    k2 = k.reshape(b * h, s_pad, d)
    v2 = v.reshape(b * h, s_pad, d)
    # i32 mask: pallas interpret handles integers more uniformly than bool.
    mask_i = mask.astype(jnp.int32)

    kernel = functools.partial(_attn_kernel, kv_block=kv_block)
    out = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((None, qr, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s_pad, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s_pad, d), lambda i: (i, 0, 0)),
            # mask is per-batch: program i uses batch i // h.
            pl.BlockSpec((None, qr, s_pad), lambda i, h=h: (i // h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, qr, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, qr, d), jnp.float32),
        interpret=interpret,
    )(q2, k2, v2, mask_i)
    return out.reshape(b, h, qr, d)
