"""L1 Pallas kernel: fused greedy head (argmax + softmax-max confidence).

The serving hot path never ships full logits to the coordinator: this
kernel reduces `[B, Q, V]` logits to a packed `[B, Q, 2]` tensor of
(token id, confidence) — paper Eq. 4 — tiled over the vocab dimension so
logits are read from HBM exactly once. On the rust side this is the entire
decode-step payload, which is the serving-path bandwidth saving described
in DESIGN.md §Hardware-Adaptation.

Lowered with ``interpret=True``; pinned to ``ref.confidence_ref`` by
hypothesis sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# Vocab tile: one lane-width on TPU; the shared tokenizer vocab (54) fits
# in a single tile, but the kernel handles arbitrary V by streaming tiles.
V_BLOCK = 128


def _conf_kernel(x_ref, o_ref, *, v_block: int, v_real: int):
    """One batch-row program: streamed max/argmax/logsumexp over V tiles.

    x_ref: [Q, V_pad]; o_ref: [Q, 2]. Columns >= v_real are padding.
    """
    q = x_ref.shape[0]
    v_pad = x_ref.shape[1]
    n_tiles = v_pad // v_block

    def body(i, carry):
        m_prev, l_prev, best_val, best_idx = carry
        start = i * v_block
        tile = pl.load(x_ref, (slice(None), pl.dslice(start, v_block)))
        tile = tile.astype(jnp.float32)
        cols = start + jax.lax.broadcasted_iota(jnp.int32, (q, v_block), 1)
        tile = jnp.where(cols < v_real, tile, NEG_INF)
        # Streaming logsumexp.
        t_max = jnp.max(tile, axis=-1)
        m_new = jnp.maximum(m_prev, t_max)
        l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(tile - m_new[:, None]), axis=-1
        )
        # Streaming argmax (first max wins, matching jnp.argmax).
        t_arg = jnp.argmax(tile, axis=-1).astype(jnp.int32) + start
        take_new = t_max > best_val
        best_val = jnp.where(take_new, t_max, best_val)
        best_idx = jnp.where(take_new, t_arg, best_idx)
        return m_new, l_new, best_val, best_idx

    init = (
        jnp.full((q,), NEG_INF, jnp.float32),
        jnp.zeros((q,), jnp.float32),
        jnp.full((q,), NEG_INF, jnp.float32),
        jnp.zeros((q,), jnp.int32),
    )
    m_fin, l_fin, best_val, best_idx = jax.lax.fori_loop(0, n_tiles, body, init)
    conf = jnp.exp(best_val - m_fin) / jnp.maximum(l_fin, 1e-30)
    o_ref[...] = jnp.stack([best_idx.astype(jnp.float32), conf], axis=-1)


def confidence(logits, *, v_block: int = V_BLOCK, interpret: bool = True):
    """Packed (argmax id, softmax max) per position.

    logits: [B, Q, V] → f32 [B, Q, 2].
    """
    b, q, v = logits.shape
    pad = (-v) % v_block
    if pad:
        logits = jnp.pad(logits, ((0, 0), (0, 0), (0, pad)),
                         constant_values=NEG_INF)
    v_pad = v + pad

    kernel = functools.partial(_conf_kernel, v_block=v_block, v_real=v)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((None, q, v_pad), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((None, q, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, q, 2), jnp.float32),
        interpret=interpret,
    )(logits)
