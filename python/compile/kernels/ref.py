"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to tight tolerances. They are also what the
training loop uses (the Pallas interpret path is only wired into the
AOT-lowered inference graphs).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, mask):
    """Masked bidirectional attention.

    q: [B, H, Qr, D]; k, v: [B, H, S, D]; mask: [B, Qr, S] bool
    (True = attendable). Rows whose mask is all-False produce zeros
    (the NaN-guard the serving path relies on for padded rows).
    Returns o: [B, H, Qr, D] in f32.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bhqd,bhsd->bhqs", q, k) * scale
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask[:, None, :, :], e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("bhqs,bhsd->bhqd", p, v)
    any_valid = jnp.any(mask, axis=-1)[:, None, :, None]
    return jnp.where(any_valid, o, 0.0)


def confidence_ref(logits):
    """Fused greedy head: per position, (argmax id, softmax max prob).

    logits: [B, Q, V] -> packed f32 [B, Q, 2] with out[..., 0] = argmax id
    (exact in f32 for any realistic vocab) and out[..., 1] = max softmax
    probability — the confidence c_i^(t) of paper Eq. 4.
    """
    logits = logits.astype(jnp.float32)
    idx = jnp.argmax(logits, axis=-1)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    conf = jnp.exp(m - lse)
    return jnp.stack([idx.astype(jnp.float32), conf], axis=-1)
