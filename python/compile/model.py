"""L2: the dLLM backbone — a LLaDA-style masked-diffusion transformer.

Bidirectional attention, RoPE, RMSNorm, SwiGLU, tied embedding head. Three
inference entrypoints are AOT-lowered per (batch, prefix, query) bucket by
``aot.py``; all take the flattened parameter list as leading arguments so
the rust runtime keeps them device-resident and passes buffers:

- ``prefill``:  prefix tokens → stacked post-RoPE KV  [NL, 2, B, H, P, Dh]
  (computed once per generation block and reused across the block's
  diffusion steps — the Fast-dLLM prefix-cache mechanism, paper §3.3).
- ``decode``:   cached prefix KV + the query bundle
  ``[current block | suffix window | trailing token]`` → packed
  ``[B, Q, 2]`` of (argmax id, confidence). The bundle shape *is* the
  attenuation-guided suffix approximation (paper Eq. 7–8): a pruned
  bundle selects a smaller executable bucket, i.e. genuinely less compute.
- ``logits_full``: full-sequence forward, the vanilla / no-cache baseline.

``attn_mode``:
- ``"full"``: fully bidirectional (Dream / LLaDA / LLaDA-1.5 topology).
- ``"block_causal"``: causal across generation blocks, bidirectional
  within a block, prompt bidirectional (Open-Pangu-like topology for the
  paper's §4.4 extension). Needs the per-sample prompt length ``p0``.

The decode graph is topology-agnostic (the bundle never attends forward of
itself beyond what the caller includes), so one decode executable serves
both topologies; only prefill/logits differ.
"""

from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp

from . import tokenizer as tok
from .kernels import ref as kref
from .kernels.attention import attention as pallas_attention
from .kernels.confidence import confidence as pallas_confidence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = tok.VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 32
    d_ff: int = 256
    rope_base: float = 10000.0
    attn_mode: str = "full"      # "full" | "block_causal"
    block_size: int = 32         # K; used by block_causal masking
    norm_eps: float = 1e-5

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


# Stable parameter ordering — the manifest records this and the rust
# runtime feeds buffers in exactly this order.
def param_names(cfg: ModelConfig) -> list[str]:
    names = ["emb"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.ln1", f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo",
            f"l{l}.ln2", f"l{l}.wg", f"l{l}.wu", f"l{l}.wd",
        ]
    names.append("ln_f")
    return names


def init_params(cfg: ModelConfig, key) -> dict:
    """Scaled-normal init; returned as a flat name→array dict."""
    ks = iter(jax.random.split(key, 1 + 9 * cfg.n_layers))
    d, hd, f = cfg.d_model, cfg.n_heads * cfg.d_head, cfg.d_ff
    p = {"emb": jax.random.normal(next(ks), (cfg.vocab, d)) * 0.02}
    for l in range(cfg.n_layers):
        p[f"l{l}.ln1"] = jnp.ones((d,))
        p[f"l{l}.wq"] = jax.random.normal(next(ks), (d, hd)) * (d ** -0.5)
        p[f"l{l}.wk"] = jax.random.normal(next(ks), (d, hd)) * (d ** -0.5)
        p[f"l{l}.wv"] = jax.random.normal(next(ks), (d, hd)) * (d ** -0.5)
        p[f"l{l}.wo"] = jax.random.normal(next(ks), (hd, d)) * (hd ** -0.5)
        p[f"l{l}.ln2"] = jnp.ones((d,))
        p[f"l{l}.wg"] = jax.random.normal(next(ks), (d, f)) * (d ** -0.5)
        p[f"l{l}.wu"] = jax.random.normal(next(ks), (d, f)) * (d ** -0.5)
        p[f"l{l}.wd"] = jax.random.normal(next(ks), (f, d)) * (f ** -0.5)
    p["ln_f"] = jnp.ones((d,))
    return p


def flatten_params(cfg: ModelConfig, p: dict) -> list:
    return [p[n] for n in param_names(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> dict:
    return dict(zip(param_names(cfg), flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, base):
    """x: [B, H, T, D], pos: [B, T] (absolute ids). Rotates pairs."""
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,T,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def _split_heads(x, h, dh):
    b, t, _ = x.shape
    return x.reshape(b, t, h, dh).transpose(0, 2, 1, 3)  # [B,H,T,Dh]


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _attend(q, k, v, mask, use_pallas):
    if use_pallas:
        return pallas_attention(q, k, v, mask)
    return kref.attention_ref(q, k, v, mask)


# ---------------------------------------------------------------------------
# Attention masks
# ---------------------------------------------------------------------------

def block_id(pos, p0, block_size):
    """Generation-block index of absolute position `pos` (prompt → -1)."""
    rel = pos - p0[:, None]
    return jnp.where(rel < 0, -1, rel // block_size)


def self_mask(cfg: ModelConfig, pos, valid, p0=None):
    """[B, T, T] self-attention mask for prefill / full forward.

    full: every valid position attends every valid position.
    block_causal: row attends col iff block(col) <= block(row)
    (prompt = block -1, so prompt attends only prompt, generation block i
    attends prompt + blocks ≤ i; bidirectional inside a block).
    """
    b, t = pos.shape
    col_ok = jnp.arange(t)[None, :] < valid[:, None]          # [B, T]
    m = jnp.broadcast_to(col_ok[:, None, :], (b, t, t))
    if cfg.attn_mode == "block_causal":
        blk = block_id(pos, p0, cfg.block_size)               # [B, T]
        m = m & (blk[:, :, None] >= blk[:, None, :])
    return m


def cross_mask(p_bucket, q_pos, kv_valid, q_valid):
    """[B, Q, P+Q] mask for decode: bundle rows attend valid prefix cols
    and valid bundle cols (fully bidirectional within the bundle)."""
    b, qn = q_pos.shape
    prefix_ok = jnp.arange(p_bucket)[None, :] < kv_valid[:, None]   # [B, P]
    bundle_ok = jnp.arange(qn)[None, :] < q_valid[:, None]          # [B, Q]
    cols = jnp.concatenate([prefix_ok, bundle_ok], axis=1)          # [B, P+Q]
    return jnp.broadcast_to(cols[:, None, :], (b, qn, p_bucket + qn))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _block(cfg, params, l, h, q_pos, kv_pos, mask, use_pallas, kv_prefix=None):
    """One transformer layer. If kv_prefix is given (decode path), the
    bundle's K/V are appended to the cached prefix K/V."""
    x = rmsnorm(h, params[f"l{l}.ln1"], cfg.norm_eps)
    q = rope(_split_heads(x @ params[f"l{l}.wq"], cfg.n_heads, cfg.d_head), q_pos, cfg.rope_base)
    k = rope(_split_heads(x @ params[f"l{l}.wk"], cfg.n_heads, cfg.d_head), kv_pos, cfg.rope_base)
    v = _split_heads(x @ params[f"l{l}.wv"], cfg.n_heads, cfg.d_head)
    if kv_prefix is not None:
        k_all = jnp.concatenate([kv_prefix[0], k], axis=2)
        v_all = jnp.concatenate([kv_prefix[1], v], axis=2)
    else:
        k_all, v_all = k, v
    o = _attend(q, k_all, v_all, mask, use_pallas)
    h = h + _merge_heads(o) @ params[f"l{l}.wo"]
    x2 = rmsnorm(h, params[f"l{l}.ln2"], cfg.norm_eps)
    h = h + swiglu(x2, params[f"l{l}.wg"], params[f"l{l}.wu"], params[f"l{l}.wd"])
    return h, (k, v)


def _head(cfg: ModelConfig, params: dict, h, use_pallas):
    h = rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = h @ params["emb"].T
    if use_pallas:
        return pallas_confidence(logits)
    return kref.confidence_ref(logits)


def prefill(cfg: ModelConfig, params: dict, tokens, pos, valid, p0=None,
            use_pallas: bool = True):
    """Prefix forward → stacked post-RoPE KV [NL, 2, B, H, P, Dh]."""
    h = params["emb"][tokens]
    mask = self_mask(cfg, pos, valid, p0)
    kvs = []
    for l in range(cfg.n_layers):
        h, (k, v) = _block(cfg, params, l, h, pos, pos, mask, use_pallas)
        kvs.append(jnp.stack([k, v]))
    return jnp.stack(kvs)  # [NL, 2, B, H, P, Dh]


def decode(cfg: ModelConfig, params: dict, kv, q_tok, q_pos, kv_valid,
           q_valid, use_pallas: bool = True):
    """Cached-prefix decode step → packed [B, Q, 2] (id, confidence).

    kv: [NL, 2, B, H, P, Dh] from `prefill`; q_tok/q_pos: [B, Q] the query
    bundle; kv_valid/q_valid: [B] live lengths (padding is masked out).
    """
    h = params["emb"][q_tok]
    p_bucket = kv.shape[4]
    mask = cross_mask(p_bucket, q_pos, kv_valid, q_valid)
    for l in range(cfg.n_layers):
        h, _ = _block(cfg, params, l, h, q_pos, q_pos, mask, use_pallas,
                      kv_prefix=(kv[l, 0], kv[l, 1]))
    return _head(cfg, params, h, use_pallas)


def logits_full(cfg: ModelConfig, params: dict, tokens, pos, valid, p0=None,
                use_pallas: bool = True):
    """Full-sequence forward → packed [B, S, 2] — the vanilla path."""
    h = params["emb"][tokens]
    mask = self_mask(cfg, pos, valid, p0)
    for l in range(cfg.n_layers):
        h, _ = _block(cfg, params, l, h, pos, pos, mask, use_pallas)
    return _head(cfg, params, h, use_pallas)


def train_logits(cfg: ModelConfig, params: dict, tokens, pos, valid, p0=None):
    """Training forward: raw logits [B, S, V] (ref attention — fast jit)."""
    h = params["emb"][tokens]
    mask = self_mask(cfg, pos, valid, p0)
    for l in range(cfg.n_layers):
        h, _ = _block(cfg, params, l, h, pos, pos, mask, use_pallas=False)
    h = rmsnorm(h, params["ln_f"], cfg.norm_eps)
    return h @ params["emb"].T
