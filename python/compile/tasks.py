"""Synthetic task suites — the GSM8K / HumanEval / MBPP / MATH stand-ins.

Each suite is a deterministic generator over the shared tokenizer alphabet
with the same *shape* as the paper's benchmark: few-shot count, multi-step
structure, and an exact-match answer. The backbones are trained on the
generators' train split (seed-disjoint from eval), so accuracy has real
headroom: over-aggressive decoding measurably degrades it, reproducing the
paper's accuracy/throughput trade-off axis.

Suites
------
- ``gsm-mini``   (5-shot default): variable-assignment arithmetic chains
  with chain-of-thought answers, e.g. ``a=4;b=a+3;b?`` → ``a4;b7;7``
  (final answer = segment after the last ';'; values mod 100).
- ``humaneval-mini`` (0-shot): string-transform synthesis with op words the
  model must have *learned* (no in-context examples), e.g.
  ``rev:abcde>`` → ``edcba``.
- ``mbpp-mini``  (3-shot): list-manipulation programs,
  e.g. ``sort 3 1 2>`` → ``1 2 3``.
- ``math-mini``  (4-shot): modular arithmetic expressions with CoT,
  e.g. ``(3*4+2)%7?`` → ``12;14;0``.

CoT answers make every generated token *locally* predictable (from the
question plus earlier answer tokens), which a sub-million-parameter
backbone can learn, while still requiring multi-iteration resolution
under diffusion decoding — dependent tokens only become confident after
their antecedents commit, which is precisely the confidence-evolution
dynamic the paper's Figure 3 shows.
"""

from __future__ import annotations

import json
import os
import random

from . import tokenizer as tok

SUITES = ["gsm-mini", "humaneval-mini", "mbpp-mini", "math-mini"]

# Default few-shot counts (mirrors the paper's setups).
DEFAULT_SHOTS = {
    "gsm-mini": 5,
    "humaneval-mini": 0,
    "mbpp-mini": 3,
    "math-mini": 4,
}

VARS = "abcdefghij"


# ---------------------------------------------------------------------------
# Single-problem generators: return (question_text, answer_text).
# The question text always ends in the query glyph ('?' or '>').
# ---------------------------------------------------------------------------

def gen_gsm(rng: random.Random) -> tuple[str, str, str]:
    """Assignment chain with chain-of-thought answer.

    Question ``a=9;b=a*9;b?`` → CoT ``a9;b81;81`` (each variable's value,
    then the final answer). Every CoT token is locally predictable from
    the question plus *earlier CoT tokens*, which is exactly the
    structure block-wise diffusion decoding exploits (easy tokens commit
    first, dependent tokens resolve in later iterations)."""
    depth = rng.randint(2, 3)
    # Random starting letter: few-shot prompts would otherwise contain an
    # "a=..." in *every* shot, making the value-copy ambiguous (a small
    # backbone averages over all matches instead of binding to the
    # query's). Distinct variables make the copy target unique with high
    # probability — the same reason real GSM8K few-shot prompts don't
    # confuse large models: entity names differ across examples.
    start = rng.randint(0, len(VARS) - depth)
    parts = []
    vals: list[int] = []
    for i in range(depth):
        var = VARS[start + i]
        if i == 0:
            d = rng.randint(2, 9)
            parts.append(f"{var}={d}")
            vals.append(d)
        else:
            op = rng.choice("+-*")
            d = rng.randint(2, 9)
            prev = VARS[start + i - 1]
            if op == "+":
                v = (vals[-1] + d) % 100
            elif op == "-":
                v = (vals[-1] - d) % 100
            else:
                v = (vals[-1] * d) % 100
            parts.append(f"{var}={prev}{op}{d}")
            vals.append(v)
    q = ";".join(parts) + f";{VARS[start + depth - 1]}?"
    cot = ";".join(f"{VARS[start + i]}{vals[i]}" for i in range(depth))
    final = str(vals[-1])
    return q, cot + ";" + final, final


_HE_OPS = {
    "rev": lambda s: s[::-1],
    "dup": lambda s: "".join(ch * 2 for ch in s),
    "rot": lambda s: s[1:] + s[0],
    "swp": lambda s: "".join(
        s[i + 1] + s[i] if i + 1 < len(s) else s[i] for i in range(0, len(s), 2)
    ),
}


def gen_humaneval(rng: random.Random) -> tuple[str, str, str]:
    """String transform with a learned op word (0-shot). Every output
    character is a local function of the input — learnable without CoT."""
    op = rng.choice(sorted(_HE_OPS))
    n = rng.randint(3, 8)
    s = "".join(rng.choice(VARS) for _ in range(n))
    out = _HE_OPS[op](s)
    return f"{op}:{s}>", out, out


_MBPP_OPS = {
    "sort": lambda xs: sorted(xs),
    "desc": lambda xs: sorted(xs, reverse=True),
    "max": lambda xs: [max(xs)],
    "min": lambda xs: [min(xs)],
    "rev": lambda xs: xs[::-1],
}


def gen_mbpp(rng: random.Random) -> tuple[str, str, str]:
    """List-manipulation program over single-digit lists (all ops are
    positional/comparison — locally predictable)."""
    op = rng.choice(sorted(_MBPP_OPS))
    n = rng.randint(3, 6)
    xs = [rng.randint(0, 9) for _ in range(n)]
    q = f"{op} " + " ".join(str(x) for x in xs) + ">"
    out = " ".join(str(v) for v in _MBPP_OPS[op](xs))
    return q, out, out


def gen_math(rng: random.Random) -> tuple[str, str, str]:
    """Modular arithmetic with chain-of-thought:
    ``(3*4+2)%7?`` → ``12;14;0`` (inner value, outer value, residue)."""
    d1, d2, d3 = (rng.randint(2, 9) for _ in range(3))
    m = rng.randint(2, 9)
    op1, op2 = rng.choice("+*"), rng.choice("+-")
    inner = d1 * d2 if op1 == "*" else d1 + d2
    outer = inner + d3 if op2 == "+" else inner - d3
    final = str(outer % m)
    q = f"({d1}{op1}{d2}{op2}{d3}){'%'}{m}?"
    return q, f"{inner};{outer};{final}", final


GENERATORS = {
    "gsm-mini": gen_gsm,
    "humaneval-mini": gen_humaneval,
    "mbpp-mini": gen_mbpp,
    "math-mini": gen_math,
}


# ---------------------------------------------------------------------------
# Prompt assembly
# ---------------------------------------------------------------------------

def build_prompt_ids(shots: list[tuple[str, str, str]], query: str) -> list[int]:
    """[BOS] shot1 SEP shot2 SEP ... query — a shot is 'question cot'."""
    ids = [tok.BOS]
    for q, cot, _final in shots:
        ids.extend(tok.encode(q + cot))
        ids.append(tok.SEP)
    ids.extend(tok.encode(query))
    return ids


def extract_final(text: str) -> str:
    """Answer-extraction rule shared with the rust eval harness: the
    segment after the last ';' (GSM/MATH CoT answers), or the whole
    string when there is no ';' (HumanEval/MBPP direct answers)."""
    return text.rsplit(";", 1)[-1]


def make_example(suite: str, rng: random.Random, shots: int | None = None):
    """One eval/train example: (prompt_ids, cot_text, final_answer)."""
    gen = GENERATORS[suite]
    k = DEFAULT_SHOTS[suite] if shots is None else shots
    shot_triples = [gen(rng) for _ in range(k)]
    q, cot, final = gen(rng)
    return build_prompt_ids(shot_triples, q), cot, final


def training_sequence(suite: str, rng: random.Random, seq_len: int,
                      shots: int | None = None):
    """A full training sequence: prompt + CoT answer + EOS-fill.

    LLaDA-style: the generation region after the prompt is the answer
    followed by EOS padding, so the model learns that everything past the
    answer is EOS — the property the early-exit mechanism relies on.
    Returns (sequence, prompt_len) or None if it doesn't fit.
    """
    # Vary shot count during training so prefill-length generalizes
    # (Table 4 sweeps 3/5/8-shot at eval time).
    k = DEFAULT_SHOTS[suite] if shots is None else shots
    if k > 0:
        k = rng.randint(max(1, k - 2), k + 3)
    prompt, cot, _final = make_example(suite, rng, shots=k)
    ans_ids = tok.encode(cot) + [tok.EOS]
    seq = prompt + ans_ids
    if len(seq) > seq_len:
        return None  # caller retries; keeps lengths bounded
    seq = seq + [tok.EOS] * (seq_len - len(seq))
    return seq, len(prompt)


def write_eval_jsonl(path: str, suite: str, n: int, seed: int,
                     shots: int | None = None) -> None:
    """Emit the eval split the rust harness serves and scores."""
    rng = random.Random(seed)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        for _ in range(n):
            prompt, cot, final = make_example(suite, rng, shots=shots)
            f.write(json.dumps({"prompt": prompt, "answer": final,
                                "cot": cot}) + "\n")


def export_all_eval(out_dir: str, n: int = 200, seed: int = 7_000_000) -> list[str]:
    """All suites at default shots, plus the gsm-mini 3/8-shot variants
    Table 4 needs. Eval seeds are disjoint from training seeds (training
    uses seeds < 7_000_000)."""
    written = []
    for i, suite in enumerate(SUITES):
        p = os.path.join(out_dir, f"{suite}.jsonl")
        write_eval_jsonl(p, suite, n, seed + i)
        written.append(p)
    for j, k in enumerate([3, 8]):
        p = os.path.join(out_dir, f"gsm-mini-{k}shot.jsonl")
        write_eval_jsonl(p, "gsm-mini", n, seed + 100 + j, shots=k)
        written.append(p)
    return written
