"""Character-level tokenizer shared by training, AOT lowering and the rust
serving stack.

The vocabulary is a small, fixed alphabet: every synthetic suite
(`tasks.py`) is expressed over it. Keeping the vocab tiny keeps the
embedding and the L1 confidence kernel cheap, which is what lets the
backbones train from scratch at `make artifacts` time.

Special tokens occupy the first ids so the rust side can hard-code them
(mirrored in `rust/src/engine/config.rs` and asserted by the manifest):

    0 PAD   padding (never predicted, never attended as query)
    1 MASK  the diffusion mask token
    2 BOS   sequence start
    3 EOS   end-of-answer / suffix filler (LLaDA-style EOS padding)
    4 SEP   few-shot example separator
"""

from __future__ import annotations

PAD, MASK, BOS, EOS, SEP = 0, 1, 2, 3, 4
SPECIALS = ["<pad>", "<mask>", "<bos>", "<eos>", "<sep>"]

# Fixed alphabet: digits, lowercase letters (variable names + op words),
# and the task glyphs used by the synthetic suites.
ALPHABET = list("0123456789") + list("abcdefghijklmnopqrstuvwxyz") + list("+-*%=;?:>(), ")

VOCAB: list[str] = SPECIALS + ALPHABET
STOI: dict[str, int] = {s: i for i, s in enumerate(VOCAB)}
VOCAB_SIZE = len(VOCAB)


def encode(text: str) -> list[int]:
    """Encode a string; raises KeyError on out-of-alphabet characters."""
    return [STOI[ch] for ch in text]


def decode(ids) -> str:
    """Decode ids, skipping special tokens."""
    out = []
    for i in ids:
        i = int(i)
        if i < len(SPECIALS):
            continue
        out.append(VOCAB[i])
    return "".join(out)


def decode_until_eos(ids) -> str:
    """Decode ids, stopping at the first EOS (the answer-extraction rule
    used by the rust eval harness — kept in sync via tests)."""
    out = []
    for i in ids:
        i = int(i)
        if i == EOS:
            break
        if i < len(SPECIALS):
            continue
        out.append(VOCAB[i])
    return "".join(out)
