"""Build-time pretraining of the dLLM backbones (LLaDA objective).

This is the "substrate the paper depends on": Streaming-dLLM is
training-free, but it needs backbones that have genuinely learned their
task distribution so that (a) confidence dynamics look like Figure 3 and
(b) over-aggressive decoding measurably degrades exact-match accuracy.

Objective (LLaDA, Nie et al. 2025): per sequence sample a masking ratio
t ~ U(t_min, 1), independently replace generation-region tokens with
[MASK] with probability t, and minimize 1/t-weighted cross-entropy on the
masked positions. The prompt is never masked. The generation region is
the answer followed by EOS padding, so the model learns the
"everything after the answer is EOS" property that early exit exploits.

Backbones (paper → here):
- ``dream-mini``   : base run (stands in for Dream-v0-7B-Base)
- ``llada-mini``   : base + continued training, different mixture/seed
- ``llada15-mini`` : llada-mini + a further polish phase (LLaDA-1.5 is an
  RL-polished LLaDA; here "polish" = more steps on the eval mixture)
- ``pangu-mini``   : block-causal topology (Open Pangu 7B stand-in,
  §4.4): previous blocks clean, current block masked, block-causal mask.

Augmentations:
- random RoPE offset per example (positions are shifted by U(0, 560)) so
  decoding at long generation lengths sees familiar absolute positions;
- variable few-shot counts so Table 4's 3/5/8-shot prefills are in
  distribution.

Runs once at ``make artifacts``; params land in
``artifacts/models/<name>/params.npz`` (+ ``config.json``) and are
reloaded on later runs instead of retrained.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks, tokenizer as tok
from . import model as M

TRAIN_SEQ_LEN = 192
MAX_POS_OFFSET = 400
T_MIN = 0.05

# mixture weights per phase: suite -> prob
BASE_MIX = {"gsm-mini": 0.35, "humaneval-mini": 0.2, "mbpp-mini": 0.25, "math-mini": 0.2}
POLISH_MIX = {"gsm-mini": 0.4, "humaneval-mini": 0.15, "mbpp-mini": 0.25, "math-mini": 0.2}


def sample_batch(rng: random.Random, batch: int, seq_len: int, mix: dict):
    """→ tokens [B,T] i32, prompt_len [B] i32 (numpy)."""
    suites = list(mix)
    weights = [mix[s] for s in suites]
    toks = np.full((batch, seq_len), tok.EOS, np.int32)
    p0 = np.zeros((batch,), np.int32)
    for b in range(batch):
        while True:
            suite = rng.choices(suites, weights)[0]
            # cap shots so prompts fit comfortably in the train window
            shots = rng.randint(0, 6) if tasks.DEFAULT_SHOTS[suite] > 0 else 0
            out = tasks.training_sequence(suite, rng, seq_len, shots=shots)
            if out is not None:
                break
        seq, plen = out
        toks[b] = np.asarray(seq, np.int32)
        p0[b] = plen
    return toks, p0


def mask_batch(rng: np.random.Generator, toks: np.ndarray, p0: np.ndarray):
    """LLaDA masking: ratio t per example over the generation region."""
    b, t_len = toks.shape
    t = rng.uniform(T_MIN, 1.0, size=(b, 1)).astype(np.float32)
    is_gen = np.arange(t_len)[None, :] >= p0[:, None]
    mask = (rng.random((b, t_len)) < t) & is_gen
    # guarantee at least one masked position per example
    none = ~mask.any(axis=1)
    if none.any():
        mask[none, p0[none]] = True
    x = np.where(mask, tok.MASK, toks)
    return x.astype(np.int32), mask, t.squeeze(1)


# EOS-fill dominates the generation region (most masked targets are the
# EOS padding after the answer); downweight it so model capacity goes to
# content tokens while the "everything after the answer is EOS" property
# (needed by early exit) is still learned.
EOS_WEIGHT = 0.15


def masked_ce_loss(cfg, params, x, targets, mask, weight, pos, valid, p0):
    logits = M.train_logits(cfg, params, x, pos, valid,
                            p0 if cfg.attn_mode == "block_causal" else None)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    w = mask.astype(jnp.float32) * weight[:, None]
    w = w * jnp.where(targets == tok.EOS, EOS_WEIGHT, 1.0)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


@functools.partial(jax.jit, static_argnums=(0,))
def train_step(cfg, params, opt_m, opt_v, step, x, targets, mask, weight,
               pos, valid, p0, lr):
    loss, grads = jax.value_and_grad(masked_ce_loss, argnums=1)(
        cfg, params, x, targets, mask, weight, pos, valid, p0)
    b1, b2, eps = 0.9, 0.95, 1e-8
    t = step + 1
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m = b1 * opt_m[k] + (1 - b1) * g
        v = b2 * opt_v[k] + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m, v
    return new_p, new_m, new_v, loss


def lr_at(step, total, peak=3e-3, floor=3e-4, warmup=20):
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return floor + 0.5 * (peak - floor) * (1 + np.cos(np.pi * frac))


def mask_block_causal(rng: np.random.Generator, toks: np.ndarray,
                      p0: np.ndarray, block_size: int):
    """Pangu-style next-block objective: previous blocks clean, one
    target block masked at ratio t, everything after it dropped to EOS
    visibility (masked out of the loss; attention is block-causal so the
    model never sees forward of the target block anyway)."""
    b, t_len = toks.shape
    t = rng.uniform(T_MIN, 1.0, size=(b, 1)).astype(np.float32)
    x = toks.copy()
    mask = np.zeros_like(toks, bool)
    for i in range(b):
        n_blocks = max(1, (t_len - p0[i]) // block_size)
        # bias block choice toward the answer-bearing early blocks
        blk = min(int(abs(rng.normal(0, 1.2))), n_blocks - 1)
        lo = p0[i] + blk * block_size
        hi = min(lo + block_size, t_len)
        sel = rng.random(hi - lo) < t[i, 0]
        if not sel.any():
            sel[0] = True
        mask[i, lo:hi] = sel
        x[i, lo:hi][sel] = tok.MASK
    return x, mask, t.squeeze(1)


def probe_accuracy(cfg, params, rng_py: random.Random, n: int = 24) -> float:
    """Teacher-forced probe: fully mask the generation region and measure
    argmax accuracy on the *content* (non-EOS) answer tokens. Cheap (one
    forward) and tracks downstream exact-match well enough to steer
    training length."""
    toks, p0 = sample_batch(rng_py, n, TRAIN_SEQ_LEN, BASE_MIX)
    x = toks.copy()
    is_gen = np.arange(TRAIN_SEQ_LEN)[None, :] >= p0[:, None]
    x[is_gen] = tok.MASK
    pos = np.tile(np.arange(TRAIN_SEQ_LEN, dtype=np.int32), (n, 1))
    valid = np.full((n,), TRAIN_SEQ_LEN, np.int32)
    logits = M.train_logits(cfg, params, jnp.asarray(x), jnp.asarray(pos),
                            jnp.asarray(valid),
                            jnp.asarray(p0) if cfg.attn_mode == "block_causal" else None)
    pred = np.asarray(jnp.argmax(logits, axis=-1))
    sel = is_gen & (toks != tok.EOS)
    if sel.sum() == 0:
        return 0.0
    return float((pred[sel] == toks[sel]).mean())


def train_phase(cfg, params, steps, seed, mix, batch, log_every=50,
                label=""):
    rng_py = random.Random(seed)
    rng_np = np.random.default_rng(seed)
    probe_rng = random.Random(seed + 999)
    opt_m = {k: jnp.zeros_like(v) for k, v in params.items()}
    opt_v = {k: jnp.zeros_like(v) for k, v in params.items()}
    t0 = time.time()
    for step in range(steps):
        toks, p0 = sample_batch(rng_py, batch, TRAIN_SEQ_LEN, mix)
        if cfg.attn_mode == "block_causal":
            x, mask, t = mask_block_causal(rng_np, toks, p0, cfg.block_size)
        else:
            x, mask, t = mask_batch(rng_np, toks, p0)
        off = rng_np.integers(0, MAX_POS_OFFSET, size=(batch, 1))
        pos = (np.arange(TRAIN_SEQ_LEN)[None, :] + off).astype(np.int32)
        valid = np.full((batch,), TRAIN_SEQ_LEN, np.int32)
        lr = lr_at(step, steps)
        params, opt_m, opt_v, loss = train_step(
            cfg, params, opt_m, opt_v, step,
            jnp.asarray(x), jnp.asarray(toks), jnp.asarray(mask),
            jnp.asarray(1.0 / t), jnp.asarray(pos), jnp.asarray(valid),
            jnp.asarray(p0 + off.squeeze(1).astype(np.int32)), lr)
        if step % log_every == 0 or step == steps - 1:
            acc = probe_accuracy(cfg, params, probe_rng) if step % (log_every * 2) == 0 or step == steps - 1 else float("nan")
            print(f"[{label}] step {step:4d}/{steps} loss {float(loss):.4f} "
                  f"probe_acc {acc:.3f} ({time.time()-t0:.0f}s)", flush=True)
    return params


def save_model(out_dir: str, name: str, cfg: M.ModelConfig, params: dict):
    d = os.path.join(out_dir, "models", name)
    os.makedirs(d, exist_ok=True)
    np.savez(os.path.join(d, "params.npz"),
             **{k: np.asarray(v, np.float32) for k, v in params.items()})
    with open(os.path.join(d, "config.json"), "w") as f:
        f.write(cfg.to_json())
    print(f"saved {name} -> {d}")


def load_model(out_dir: str, name: str):
    d = os.path.join(out_dir, "models", name)
    cfg_path, npz_path = os.path.join(d, "config.json"), os.path.join(d, "params.npz")
    if not (os.path.exists(cfg_path) and os.path.exists(npz_path)):
        return None
    with open(cfg_path) as f:
        cfg = M.ModelConfig(**json.load(f))
    data = np.load(npz_path)
    params = {k: jnp.asarray(data[k]) for k in data.files}
    return cfg, params


def train_all(out_dir: str, base_steps: int, variant_steps: int,
              pangu_steps: int, batch: int):
    cfg = M.ModelConfig(d_model=128, n_layers=3, n_heads=4, d_head=32,
                        d_ff=256, block_size=8)
    if load_model(out_dir, "dream-mini") is None:
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        p = train_phase(cfg, p, base_steps, seed=100, mix=BASE_MIX,
                        batch=batch, label="dream-mini")
        save_model(out_dir, "dream-mini", cfg, p)
    if load_model(out_dir, "llada-mini") is None:
        _, p = load_model(out_dir, "dream-mini")
        p = train_phase(cfg, p, variant_steps, seed=200, mix=BASE_MIX,
                        batch=batch, label="llada-mini")
        save_model(out_dir, "llada-mini", cfg, p)
    if load_model(out_dir, "llada15-mini") is None:
        _, p = load_model(out_dir, "llada-mini")
        p = train_phase(cfg, p, variant_steps, seed=300, mix=POLISH_MIX,
                        batch=batch, label="llada15-mini")
        save_model(out_dir, "llada15-mini", cfg, p)
    if load_model(out_dir, "pangu-mini") is None:
        bc_cfg = M.ModelConfig(d_model=128, n_layers=3, n_heads=4, d_head=32,
                               d_ff=256, block_size=8,
                               attn_mode="block_causal")
        p = M.init_params(bc_cfg, jax.random.PRNGKey(4))
        p = train_phase(bc_cfg, p, pangu_steps, seed=400, mix=BASE_MIX,
                        batch=batch, label="pangu-mini")
        save_model(out_dir, "pangu-mini", bc_cfg, p)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--base-steps", type=int, default=700)
    ap.add_argument("--variant-steps", type=int, default=120)
    ap.add_argument("--pangu-steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=24)
    args = ap.parse_args()
    train_all(args.out, args.base_steps, args.variant_steps,
              args.pangu_steps, args.batch)


if __name__ == "__main__":
    main()
