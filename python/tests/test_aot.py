"""AOT lowering contract: HLO text is parseable/self-contained (no
custom calls, full parameter signature via keep_unused), bucket grids
cover the workloads, and the manifest schema matches what
rust/src/runtime/artifact.rs expects."""

import json
import random

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M, tasks, tokenizer as tok

CFG = M.ModelConfig(d_model=32, n_layers=2, n_heads=2, d_head=8, d_ff=48,
                    block_size=8)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_decode_lowering_keeps_full_signature(params):
    name, text, sig = aot.lower_one(CFG, params, "decode", 1, p=96, q=13)
    assert name == "decode_b1_p96_q13"
    # entry computation must take every param + 5 inputs
    n_expected = len(M.param_names(CFG)) + 5
    assert f"parameter({n_expected - 1})" in text
    assert f"parameter({n_expected})" not in text
    assert "custom-call" not in text.lower()
    assert len(sig) == 5
    assert sig[0]["shape"] == [CFG.n_layers, 2, 1, CFG.n_heads, 96, CFG.d_head]


def test_prefill_lowering_single_output(params):
    _, text, sig = aot.lower_one(CFG, params, "prefill", 1, p=96)
    assert "custom-call" not in text.lower()
    # root is the stacked KV tensor, not a tuple
    assert "ROOT" in text
    assert len(sig) == 3


def test_block_causal_signature_has_p0(params):
    bc = M.ModelConfig(d_model=32, n_layers=2, n_heads=2, d_head=8, d_ff=48,
                       block_size=8, attn_mode="block_causal")
    bc_params = M.init_params(bc, jax.random.PRNGKey(1))
    _, _, sig = aot.lower_one(bc, bc_params, "logits", 1, s=96)
    assert len(sig) == 4  # tokens, pos, valid, p0


def test_bucket_grids_cover_eval_workloads():
    """Every eval prompt + every bench gen length must fit the grid."""
    rng = random.Random(0)
    max_prompt = 0
    for suite in tasks.SUITES:
        for _ in range(50):
            ids, _, _ = tasks.make_example(suite, rng)
            max_prompt = max(max_prompt, len(ids))
    for shots in [3, 8]:
        for _ in range(50):
            ids, _, _ = tasks.make_example("gsm-mini", rng, shots=shots)
            max_prompt = max(max_prompt, len(ids))
    for gen_len in [64, 128, 256, 512]:
        # prefix = prompt + decoded blocks (≤ L - K)
        need_prefix = max_prompt + gen_len - 8
        assert any(b >= need_prefix for b in aot.PREFIX_GRID), (need_prefix, gen_len)
        # vanilla full sequence
        assert any(b >= max_prompt + gen_len for b in aot.SEQ_GRID)
        # full-suffix query bundle (prefix-cache / fast-dllm)
        assert any(b >= gen_len for b in aot.QUERY_GRID)
    # pruned bundles: K + w + 1 for the table-12 windows
    for w in [4, 8, 16, 24, 32, 48, 64, 128]:
        assert any(b >= 8 + w + 1 for b in aot.QUERY_GRID), w


def test_query_grid_sorted_unique():
    assert aot.QUERY_GRID == sorted(set(aot.QUERY_GRID))
    assert aot.PREFIX_GRID == sorted(set(aot.PREFIX_GRID))
    assert aot.SEQ_GRID == sorted(set(aot.SEQ_GRID))


def test_vocab_specials_match_rust_constants():
    # rust hard-codes these in SpecialTokens assertions
    assert (tok.PAD, tok.MASK, tok.BOS, tok.EOS, tok.SEP) == (0, 1, 2, 3, 4)
    assert len(tok.VOCAB) == tok.VOCAB_SIZE
    assert tok.VOCAB_SIZE < 128  # confidence kernel single-tile fast path


def test_manifest_roundtrips_as_json(params, tmp_path):
    """Schema smoke: build a manifest dict like export_model does and
    ensure required keys survive a json round-trip."""
    manifest = {
        "model": "test",
        "attn_mode": CFG.attn_mode,
        "wants_p0": False,
        "config": json.loads(CFG.to_json()),
        "special_tokens": {"pad": 0, "mask": 1, "bos": 2, "eos": 3, "sep": 4},
        "vocab": tok.VOCAB,
        "params_file": "params.npz",
        "param_order": [{"name": n, "shape": [1]} for n in M.param_names(CFG)],
        "kv_dims": {"layers": 2, "heads": 2, "d_head": 8},
        "buckets": {"batch": [1], "prefix": [96], "query": [13], "seq": [96]},
        "artifacts": [],
    }
    s = json.dumps(manifest)
    back = json.loads(s)
    for key in ["model", "attn_mode", "wants_p0", "special_tokens", "vocab",
                "params_file", "param_order", "kv_dims", "buckets", "artifacts"]:
        assert key in back
