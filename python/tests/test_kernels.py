"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

This is THE correctness signal for the compute layer — hypothesis sweeps
shapes, masking patterns and value ranges, asserting tight agreement.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention, KV_BLOCK
from compile.kernels.confidence import confidence

jax.config.update("jax_platform_name", "cpu")


def rand_attn(rng, b, h, q, s, d, mask_p):
    q_ = jnp.asarray(rng.normal(size=(b, h, q, d)), jnp.float32)
    k_ = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v_ = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    m_ = jnp.asarray(rng.random((b, q, s)) > mask_p)
    return q_, k_, v_, m_


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    q=st.integers(1, 24),
    s=st.integers(1, 300),
    d=st.sampled_from([4, 16, 32]),
    mask_p=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, q, s, d, mask_p, seed):
    rng = np.random.default_rng(seed)
    q_, k_, v_, m_ = rand_attn(rng, b, h, q, s, d, mask_p)
    out = attention(q_, k_, v_, m_)
    want = ref.attention_ref(q_, k_, v_, m_)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_fully_masked_rows_zero():
    rng = np.random.default_rng(0)
    q_, k_, v_, m_ = rand_attn(rng, 2, 2, 5, 40, 8, 0.5)
    m_ = m_.at[1, 3, :].set(False)
    out = np.asarray(attention(q_, k_, v_, m_))
    assert np.all(out[1, :, 3, :] == 0.0)
    assert not np.any(np.isnan(out))


def test_attention_tile_boundaries():
    """S exactly at / around the KV tile size."""
    rng = np.random.default_rng(1)
    for s in [KV_BLOCK - 1, KV_BLOCK, KV_BLOCK + 1, 2 * KV_BLOCK]:
        q_, k_, v_, m_ = rand_attn(rng, 1, 1, 3, s, 8, 0.2)
        out = attention(q_, k_, v_, m_)
        want = ref.attention_ref(q_, k_, v_, m_)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_single_valid_column():
    """Attention over one valid key = that key's value."""
    rng = np.random.default_rng(2)
    q_, k_, v_, m_ = rand_attn(rng, 1, 1, 2, 10, 4, 0.0)
    m_ = jnp.zeros_like(m_).at[:, :, 7].set(True)
    out = np.asarray(attention(q_, k_, v_, m_))
    want = np.broadcast_to(np.asarray(v_)[:, :, 7:8, :], out.shape)
    np.testing.assert_allclose(out, want, atol=1e-5)


def test_attention_extreme_values_no_overflow():
    rng = np.random.default_rng(3)
    q_ = jnp.asarray(rng.normal(size=(1, 1, 4, 8)) * 30, jnp.float32)
    k_ = jnp.asarray(rng.normal(size=(1, 1, 50, 8)) * 30, jnp.float32)
    v_ = jnp.asarray(rng.normal(size=(1, 1, 50, 8)), jnp.float32)
    m_ = jnp.ones((1, 4, 50), bool)
    out = np.asarray(attention(q_, k_, v_, m_))
    want = np.asarray(ref.attention_ref(q_, k_, v_, m_))
    assert not np.any(np.isnan(out))
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    q=st.integers(1, 40),
    v=st.sampled_from([7, 54, 128, 129, 300]),
    scale=st.floats(0.1, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_confidence_matches_ref(b, q, v, scale, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, q, v)) * scale, jnp.float32)
    out = np.asarray(confidence(logits))
    want = np.asarray(ref.confidence_ref(logits))
    np.testing.assert_allclose(out[..., 0], want[..., 0])  # argmax ids exact
    np.testing.assert_allclose(out[..., 1], want[..., 1], atol=1e-5, rtol=1e-5)


def test_confidence_onehot_certainty():
    v = 54
    logits = jnp.full((1, 3, v), -30.0).at[0, :, 7].set(30.0)
    out = np.asarray(confidence(logits))
    assert np.all(out[..., 0] == 7)
    np.testing.assert_allclose(out[..., 1], 1.0, atol=1e-6)


def test_confidence_uniform_low_confidence():
    v = 54
    logits = jnp.zeros((1, 2, v))
    out = np.asarray(confidence(logits))
    np.testing.assert_allclose(out[..., 1], 1.0 / v, atol=1e-6)
    assert np.all(out[..., 0] == 0)  # first max wins, matches jnp.argmax


def test_confidence_tie_breaks_like_argmax():
    logits = jnp.zeros((1, 1, 10)).at[0, 0, 3].set(5.0).at[0, 0, 8].set(5.0)
    out = np.asarray(confidence(logits))
    want = np.asarray(ref.confidence_ref(logits))
    assert out[0, 0, 0] == want[0, 0, 0] == 3
