"""L2 model invariants: shapes, cache consistency (decode-with-cache ==
full forward at the same positions), masking semantics, topology modes.
These pin the contract the rust engine relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tokenizer as tok

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(d_model=32, n_layers=2, n_heads=2, d_head=8, d_ff=48,
                    block_size=4)
BC_CFG = M.ModelConfig(d_model=32, n_layers=2, n_heads=2, d_head=8, d_ff=48,
                       block_size=4, attn_mode="block_causal")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def bc_params():
    return M.init_params(BC_CFG, jax.random.PRNGKey(1))


def seq_inputs(b, t, valid=None, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(5, tok.VOCAB_SIZE, size=(b, t)), jnp.int32)
    pos = jnp.tile(jnp.arange(t, dtype=jnp.int32)[None], (b, 1))
    v = jnp.asarray(valid if valid is not None else [t] * b, jnp.int32)
    return tokens, pos, v


def test_prefill_shape(params):
    tokens, pos, valid = seq_inputs(2, 16)
    kv = M.prefill(CFG, params, tokens, pos, valid, use_pallas=False)
    assert kv.shape == (2, 2, 2, 2, 16, 8)  # [NL,2,B,H,P,Dh]


def test_logits_full_shape_and_range(params):
    tokens, pos, valid = seq_inputs(2, 12)
    out = M.logits_full(CFG, params, tokens, pos, valid, use_pallas=False)
    assert out.shape == (2, 12, 2)
    ids = np.asarray(out[..., 0])
    conf = np.asarray(out[..., 1])
    assert ids.min() >= 0 and ids.max() < tok.VOCAB_SIZE
    assert conf.min() >= 0.0 and conf.max() <= 1.0 + 1e-6


def test_decode_equals_full_forward_one_layer():
    """With a single layer the prefix KV depends only on embeddings, so
    cached decode must *exactly* match the full bidirectional forward at
    the bundle positions. (With ≥2 layers the prefix KV is the
    Fast-dLLM approximation — prefix hidden states are computed without
    seeing the suffix — so equality intentionally does NOT hold; that
    semantic gap is the cache trade-off the paper builds on.)"""
    cfg1 = M.ModelConfig(d_model=32, n_layers=1, n_heads=2, d_head=8,
                         d_ff=48, block_size=4)
    params1 = M.init_params(cfg1, jax.random.PRNGKey(9))
    b, p, q = 1, 10, 6
    tokens, pos, valid = seq_inputs(b, p + q, seed=3)
    full = M.logits_full(cfg1, params1, tokens, pos, valid, use_pallas=False)

    kv = M.prefill(cfg1, params1, tokens[:, :p], pos[:, :p],
                   jnp.asarray([p], jnp.int32), use_pallas=False)
    out = M.decode(cfg1, params1, kv, tokens[:, p:], pos[:, p:],
                   jnp.asarray([p], jnp.int32), jnp.asarray([q], jnp.int32),
                   use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, p:, :]),
                               atol=1e-4, rtol=1e-4)


def test_decode_padding_invariance(params):
    """Growing the prefix bucket (with masked padding) must not change
    decode outputs — the bucketing contract of the rust runtime."""
    b, p, q, pad_to = 1, 7, 4, 16
    tokens, pos, valid = seq_inputs(b, p + q, seed=4)
    kv_tight = M.prefill(CFG, params, tokens[:, :p], pos[:, :p],
                         jnp.asarray([p], jnp.int32), use_pallas=False)
    out_tight = M.decode(CFG, params, kv_tight, tokens[:, p:], pos[:, p:],
                         jnp.asarray([p], jnp.int32), jnp.asarray([q], jnp.int32),
                         use_pallas=False)

    pad_tokens = jnp.zeros((b, pad_to), jnp.int32).at[:, :p].set(tokens[:, :p])
    pad_pos = jnp.tile(jnp.arange(pad_to, dtype=jnp.int32)[None], (b, 1))
    kv_pad = M.prefill(CFG, params, pad_tokens, pad_pos,
                       jnp.asarray([p], jnp.int32), use_pallas=False)
    out_pad = M.decode(CFG, params, kv_pad, tokens[:, p:], pos[:, p:],
                       jnp.asarray([p], jnp.int32), jnp.asarray([q], jnp.int32),
                       use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_tight), np.asarray(out_pad),
                               atol=1e-5, rtol=1e-5)


def test_query_padding_invariance(params):
    """Padding the query bundle (q_valid < Q) must not change the valid
    slots' outputs."""
    b, p, q = 1, 8, 5
    tokens, pos, valid = seq_inputs(b, p + q, seed=5)
    kv = M.prefill(CFG, params, tokens[:, :p], pos[:, :p],
                   jnp.asarray([p], jnp.int32), use_pallas=False)
    out = M.decode(CFG, params, kv, tokens[:, p:], pos[:, p:],
                   jnp.asarray([p], jnp.int32), jnp.asarray([q], jnp.int32),
                   use_pallas=False)
    q_pad = q + 3
    qt = jnp.full((b, q_pad), tok.MASK, jnp.int32).at[:, :q].set(tokens[:, p:])
    qp = jnp.zeros((b, q_pad), jnp.int32).at[:, :q].set(pos[:, p:])
    out_pad = M.decode(CFG, params, kv, qt, qp,
                       jnp.asarray([p], jnp.int32), jnp.asarray([q], jnp.int32),
                       use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_pad[:, :q]),
                               atol=1e-5, rtol=1e-5)


def test_pallas_and_ref_paths_agree(params):
    tokens, pos, valid = seq_inputs(1, 12, seed=6)
    a = M.logits_full(CFG, params, tokens, pos, valid, use_pallas=True)
    b_ = M.logits_full(CFG, params, tokens, pos, valid, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5, rtol=2e-5)


def test_block_causal_hides_future_blocks(bc_params):
    """In block-causal mode, changing tokens in a *later* block must not
    affect earlier blocks' outputs (it does in full mode)."""
    b, t = 1, 12
    p0 = jnp.asarray([4], jnp.int32)  # prompt 4, then blocks of 4
    tokens, pos, valid = seq_inputs(b, t, seed=7)
    out1 = M.logits_full(BC_CFG, bc_params, tokens, pos, valid, p0, use_pallas=False)
    tokens2 = tokens.at[0, 9].set((tokens[0, 9] + 1) % tok.VOCAB_SIZE)
    out2 = M.logits_full(BC_CFG, bc_params, tokens2, pos, valid, p0, use_pallas=False)
    # positions < 8 (prompt + block 0) unchanged
    np.testing.assert_allclose(np.asarray(out1[:, :8]), np.asarray(out2[:, :8]),
                               atol=1e-6)
    # full mode: the same perturbation propagates backwards
    f1 = M.logits_full(CFG, bc_params, tokens, pos, valid, use_pallas=False)
    f2 = M.logits_full(CFG, bc_params, tokens2, pos, valid, use_pallas=False)
    assert np.abs(np.asarray(f1[:, :8, 1]) - np.asarray(f2[:, :8, 1])).max() > 0


def test_valid_masking_hides_padding(params):
    """Tokens beyond `valid` must not influence outputs."""
    b, t = 1, 10
    tokens, pos, _ = seq_inputs(b, t, seed=8)
    v = jnp.asarray([6], jnp.int32)
    out1 = M.logits_full(CFG, params, tokens, pos, v, use_pallas=False)
    tokens2 = tokens.at[0, 8].set((tokens[0, 8] + 3) % tok.VOCAB_SIZE)
    out2 = M.logits_full(CFG, params, tokens2, pos, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out1[:, :6]), np.asarray(out2[:, :6]),
                               atol=1e-6)


def test_param_flatten_roundtrip(params):
    flat = M.flatten_params(CFG, params)
    rebuilt = M.unflatten_params(CFG, flat)
    assert set(rebuilt) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(rebuilt[k]))


def test_rope_relative_position_semantics(params):
    """RoPE encodes *relative* offsets: a global shift of all position
    ids is a no-op (this is what makes the offset augmentation in
    training and the bucketed absolute ids at serving time mutually
    consistent), while changing the *gaps* between positions must change
    the outputs."""
    tokens, pos, valid = seq_inputs(1, 8, seed=9)
    l1 = np.asarray(M.train_logits(CFG, params, tokens, pos, valid))
    # global shift → identical logits (up to fp noise)
    l_shift = np.asarray(M.train_logits(CFG, params, tokens, pos + 57, valid))
    np.testing.assert_allclose(l1, l_shift, atol=1e-4, rtol=1e-4)
    # stretching the gaps → different logits
    l_stretch = np.asarray(M.train_logits(CFG, params, tokens, pos * 3, valid))
    assert np.abs(l1 - l_stretch).max() > 1e-4
