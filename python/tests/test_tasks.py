"""Task-suite generators: correctness of the synthetic semantics, prompt
assembly, determinism, and the answer-extraction contract shared with the
rust eval harness."""

import json
import random

import pytest
from hypothesis import given, settings, strategies as st

from compile import tasks, tokenizer as tok


@pytest.mark.parametrize("suite", tasks.SUITES)
def test_generators_encodable(suite):
    rng = random.Random(0)
    for _ in range(50):
        q, cot, final = tasks.GENERATORS[suite](rng)
        tok.encode(q)      # raises on out-of-alphabet
        tok.encode(cot)
        assert final == tasks.extract_final(cot)


def test_gsm_semantics():
    rng = random.Random(1)
    for _ in range(100):
        q, cot, final = tasks.gen_gsm(rng)
        # replay the chain: parse assignments from the question
        env = {}
        parts = q[:-1].split(";")  # strip trailing '?'
        query_var = parts[-1]
        for p in parts[:-1]:
            var, expr = p.split("=")
            if expr.isdigit():
                env[var] = int(expr)
            else:
                prev, op, d = expr[0], expr[1], int(expr[2:])
                if op == "+":
                    env[var] = (env[prev] + d) % 100
                elif op == "-":
                    env[var] = (env[prev] - d) % 100
                else:
                    env[var] = (env[prev] * d) % 100
        assert str(env[query_var]) == final
        # CoT lists every variable in order with its value
        steps = cot.split(";")
        assert steps[-1] == final
        assert len(steps) == len(env) + 1


def test_humaneval_semantics():
    rng = random.Random(2)
    for _ in range(100):
        q, out, final = tasks.gen_humaneval(rng)
        op, rest = q.split(":")
        s = rest[:-1]  # strip '>'
        assert out == tasks._HE_OPS[op](s)
        assert final == out


def test_mbpp_semantics():
    rng = random.Random(3)
    for _ in range(100):
        q, out, final = tasks.gen_mbpp(rng)
        op, rest = q[:-1].split(" ", 1)
        xs = [int(x) for x in rest.split()]
        want = tasks._MBPP_OPS[op](xs)
        assert out == " ".join(str(v) for v in want)


def test_math_semantics():
    rng = random.Random(4)
    for _ in range(100):
        q, cot, final = tasks.gen_math(rng)
        inner, outer, res = cot.split(";")
        m = int(q[q.index("%") + 1:q.index("?")])
        assert int(res) == int(outer) % m
        assert final == res


def test_prompt_layout():
    rng = random.Random(5)
    ids, cot, final = tasks.make_example("gsm-mini", rng, shots=3)
    assert ids[0] == tok.BOS
    assert ids.count(tok.SEP) == 3
    text = tok.decode(ids)
    assert text.endswith("?")


def test_zero_shot_prompt_has_no_sep():
    rng = random.Random(6)
    ids, _, _ = tasks.make_example("humaneval-mini", rng)
    assert tok.SEP not in ids
    assert ids[0] == tok.BOS


def test_training_sequence_layout():
    rng = random.Random(7)
    out = None
    while out is None:
        out = tasks.training_sequence("gsm-mini", rng, 192)
    seq, p0 = out
    assert len(seq) == 192
    assert seq[-1] == tok.EOS
    # generation region = cot + EOS fill; prompt region has no EOS
    assert tok.EOS not in seq[:p0]
    gen = seq[p0:]
    first_eos = gen.index(tok.EOS)
    assert all(t == tok.EOS for t in gen[first_eos:])


def test_eval_export_deterministic(tmp_path):
    p1 = tmp_path / "a.jsonl"
    p2 = tmp_path / "b.jsonl"
    tasks.write_eval_jsonl(str(p1), "math-mini", 20, seed=42)
    tasks.write_eval_jsonl(str(p2), "math-mini", 20, seed=42)
    assert p1.read_text() == p2.read_text()
    lines = p1.read_text().strip().split("\n")
    assert len(lines) == 20
    row = json.loads(lines[0])
    assert {"prompt", "answer", "cot"} <= set(row)
    assert tasks.extract_final(row["cot"]) == row["answer"]


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), suite=st.sampled_from(tasks.SUITES))
def test_examples_fit_prefix_buckets(seed, suite):
    """Eval prompts must fit the smallest AOT prefix bucket headroom."""
    rng = random.Random(seed)
    ids, _, final = tasks.make_example(suite, rng)
    assert len(ids) <= 176  # default-shot prompts must leave room in the 224 bucket
    assert 1 <= len(final) <= 24


def test_extract_final_matches_rust_rule():
    # mirrored in rust/src/eval/mod.rs::extract_final tests
    assert tasks.extract_final("a9;b81;81") == "81"
    assert tasks.extract_final("edcba") == "edcba"
    assert tasks.extract_final("1 2 3") == "1 2 3"
    assert tasks.extract_final("x;") == ""


def test_tokenizer_roundtrip():
    s = "a=4;b=a+3;b?a4;b7;7 (2*3+1)%5? rev:abc>cba sort 1 2>"
    assert tok.decode(tok.encode(s)) == s


def test_decode_until_eos_stops():
    ids = tok.encode("a9;81") + [tok.EOS] + tok.encode("junk")
    assert tok.decode_until_eos(ids) == "a9;81"
