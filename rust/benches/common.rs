//! Shared bench plumbing for all tableN/figN targets: backend/suite
//! setup, the main accuracy+throughput grid (Tables 1/2/8 and the
//! latency Tables 9/10/11), and sweep helpers.
//!
//! Backend selection mirrors the CLI: PJRT when the build carries it
//! *and* `artifacts/index.json` exists; the deterministic pure-Rust
//! reference model otherwise — so every bench runs (and CI's bench
//! smoke accumulates `BENCH_*.json` trajectories) on a bare checkout.
//!
//! Knobs (env): SDLLM_BENCH_N (items per cell, default 12),
//! SDLLM_ARTIFACTS (artifacts dir), SDLLM_SYNTH_N (synthetic suite
//! size, default 64), SDLLM_REF_MODE (reference mode toy|causal —
//! causal makes the accuracy axis schedule-dependent, so the
//! accuracy-vs-NFE curves actually bend).

#![allow(dead_code)]

use streaming_dllm::engine::{table12_config, AnyBackend, DecodePolicy, GenConfig, Method};
use streaming_dllm::eval::{load_suite, run_suite, suite_for, EvalItem, SuiteResult};
use streaming_dllm::runtime::ArtifactsIndex;
use streaming_dllm::util::bench::{print_latency_table, print_table, save_rows, Cell, Row};

pub const SUITES: [(&str, &str); 4] = [
    ("humaneval-mini", "HumanEval-mini (0-shot)"),
    ("gsm-mini", "GSM8K-mini (5-shot)"),
    ("mbpp-mini", "MBPP-mini (3-shot)"),
    ("math-mini", "MATH-mini (4-shot)"),
];

/// Paper gen lengths {256, 512} scaled ÷4 (DESIGN.md).
pub const GEN_LENS: [usize; 2] = [64, 128];

pub fn bench_n() -> usize {
    std::env::var("SDLLM_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(12)
}

pub struct Setup {
    pub root: std::path::PathBuf,
    /// loaded once when serving over PJRT; None on reference runs
    index: Option<ArtifactsIndex>,
}

impl Setup {
    /// Always succeeds: the reference backend needs nothing. `Option`
    /// is kept so bench mains read as before (`let Some(setup) = …`).
    pub fn new() -> Option<Setup> {
        let root = streaming_dllm::artifacts_root();
        let index = if AnyBackend::pjrt_available(&root) {
            Some(ArtifactsIndex::load(&root).expect("artifacts index"))
        } else {
            println!(
                "[no PJRT artifacts at {}; running the reference backend (mode: {})]",
                root.display(),
                ref_mode()
            );
            None
        };
        Some(Setup { root, index })
    }

    pub fn model(&self, name: &str) -> AnyBackend {
        AnyBackend::auto(&self.root, name).expect("backend")
    }

    /// Whether this setup serves the reference backend (no artifacts).
    pub fn is_reference(&self) -> bool {
        self.index.is_none()
    }

    pub fn suite(&self, name: &str) -> Vec<EvalItem> {
        self.suite_file(&format!("{name}.jsonl"))
    }

    pub fn suite_file(&self, file: &str) -> Vec<EvalItem> {
        match &self.index {
            Some(index) => load_suite(&index.eval_dir.join(file)).expect("suite"),
            None => {
                let name = file.trim_end_matches(".jsonl");
                // mode-matched suite: a causal backend must be scored
                // against the sequential-chain oracle, not the toy one
                suite_for(&AnyBackend::reference_from_env(), &self.root, name).expect("suite")
            }
        }
    }
}

/// Active reference mode (env `SDLLM_REF_MODE`), for labels/banners.
pub fn ref_mode() -> &'static str {
    AnyBackend::env_ref_mode().name()
}

/// Method config for a (model, suite, len) cell: Streaming uses the
/// Table-12 per-benchmark hyperparameters; baselines use presets.
pub fn cell_config(method: Method, model: &str, suite: &str, gen_len: usize) -> GenConfig {
    match method {
        Method::Streaming => table12_config(model, suite, gen_len),
        _ => GenConfig::preset(method, gen_len),
    }
}

pub fn run_cell(
    be: &AnyBackend,
    method: Method,
    model: &str,
    suite: &str,
    gen_len: usize,
    items: &[EvalItem],
) -> SuiteResult {
    let cfg = cell_config(method, model, suite, gen_len);
    run_suite(be, &cfg, items, None).expect("run_suite")
}

/// A policy-swept cell: the Streaming method decoding under a named
/// decode policy preset instead of its tuned per-benchmark schedule.
pub fn run_policy_cell(
    be: &AnyBackend,
    policy: &str,
    model: &str,
    suite: &str,
    gen_len: usize,
    items: &[EvalItem],
) -> SuiteResult {
    let mut cfg = cell_config(Method::Streaming, model, suite, gen_len);
    cfg.policy = DecodePolicy::parse(policy).expect("known policy preset");
    run_suite(be, &cfg, items, None).expect("run_suite")
}

/// The paper's main-table grid: 4 suites × 2 gen lengths × 5 methods.
/// Prints both the throughput table (Tables 1/2/8) and the latency table
/// (Tables 9/10/11) and saves JSON for fig1.
pub fn main_table(model: &str, title: &str) {
    let Some(setup) = Setup::new() else { return };
    let be = setup.model(model);
    let n = bench_n();
    let mut rows = vec![];
    for (suite, label) in SUITES {
        let items = setup.suite(suite);
        for gen_len in GEN_LENS {
            let items = &items[..n.min(items.len())];
            let mut cells: Vec<(String, Cell)> = vec![];
            for method in Method::all() {
                let res = run_cell(&be, method, model, suite, gen_len, items);
                cells.push((method.name().to_string(), res.to_cell()));
            }
            rows.push(Row { label: format!("{label} L={gen_len}"), cells });
        }
    }
    print_table(title, &rows);
    print_latency_table(title, &rows);
    save_rows(&format!("main_{model}"), &rows);
    println!("\n(n={n}/cell; paper scale: L=64↔256, L=128↔512; speedups are vs vanilla)");
}
