//! Shared bench plumbing for all tableN/figN targets: artifact setup,
//! the main accuracy+throughput grid (Tables 1/2/8 and the latency
//! Tables 9/10/11), and sweep helpers.
//!
//! Knobs (env): SDLLM_BENCH_N (items per cell, default 12),
//! SDLLM_ARTIFACTS (artifacts dir).

#![allow(dead_code)]


use streaming_dllm::engine::{table12_config, GenConfig, Method};
use streaming_dllm::eval::{load_suite, run_suite, EvalItem, SuiteResult};
use streaming_dllm::runtime::{ArtifactsIndex, ModelRuntime, Runtime};
use streaming_dllm::util::bench::{print_latency_table, print_table, save_rows, Cell, Row};

pub const SUITES: [(&str, &str); 4] = [
    ("humaneval-mini", "HumanEval-mini (0-shot)"),
    ("gsm-mini", "GSM8K-mini (5-shot)"),
    ("mbpp-mini", "MBPP-mini (3-shot)"),
    ("math-mini", "MATH-mini (4-shot)"),
];

/// Paper gen lengths {256, 512} scaled ÷4 (DESIGN.md).
pub const GEN_LENS: [usize; 2] = [64, 128];

pub fn bench_n() -> usize {
    std::env::var("SDLLM_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(12)
}

pub struct Setup {
    pub index: ArtifactsIndex,
    pub rt: Runtime,
}

impl Setup {
    pub fn new() -> Option<Setup> {
        let root = streaming_dllm::artifacts_root();
        if !root.join("index.json").exists() {
            println!("SKIP: no artifacts at {} (run `make artifacts`)", root.display());
            return None;
        }
        let index = ArtifactsIndex::load(&root).expect("artifacts index");
        let rt = Runtime::cpu().expect("PJRT cpu client");
        Some(Setup { index, rt })
    }

    pub fn model(&self, name: &str) -> ModelRuntime {
        ModelRuntime::load(&self.rt, &self.index.model_dir(name)).expect("model runtime")
    }

    pub fn suite(&self, name: &str) -> Vec<EvalItem> {
        load_suite(&self.index.eval_dir.join(format!("{name}.jsonl"))).expect("suite")
    }

    pub fn suite_file(&self, file: &str) -> Vec<EvalItem> {
        load_suite(&self.index.eval_dir.join(file)).expect("suite")
    }
}

/// Method config for a (model, suite, len) cell: Streaming uses the
/// Table-12 per-benchmark hyperparameters; baselines use presets.
pub fn cell_config(method: Method, model: &str, suite: &str, gen_len: usize) -> GenConfig {
    match method {
        Method::Streaming => table12_config(model, suite, gen_len),
        _ => GenConfig::preset(method, gen_len),
    }
}

pub fn run_cell(
    mrt: &ModelRuntime,
    method: Method,
    model: &str,
    suite: &str,
    gen_len: usize,
    items: &[EvalItem],
) -> SuiteResult {
    let cfg = cell_config(method, model, suite, gen_len);
    run_suite(mrt, &cfg, items, None).expect("run_suite")
}

/// The paper's main-table grid: 4 suites × 2 gen lengths × 5 methods.
/// Prints both the throughput table (Tables 1/2/8) and the latency table
/// (Tables 9/10/11) and saves JSON for fig1.
pub fn main_table(model: &str, title: &str) {
    let Some(setup) = Setup::new() else { return };
    let mrt = setup.model(model);
    let n = bench_n();
    let mut rows = vec![];
    for (suite, label) in SUITES {
        let items = setup.suite(suite);
        for gen_len in GEN_LENS {
            let items = &items[..n.min(items.len())];
            let mut cells: Vec<(String, Cell)> = vec![];
            for method in Method::all() {
                let res = run_cell(&mrt, method, model, suite, gen_len, items);
                cells.push((method.name().to_string(), res.to_cell()));
            }
            rows.push(Row { label: format!("{label} L={gen_len}"), cells });
        }
    }
    print_table(title, &rows);
    print_latency_table(title, &rows);
    save_rows(&format!("main_{model}"), &rows);
    println!("\n(n={n}/cell; paper scale: L=64↔256, L=128↔512; speedups are vs vanilla)");
}
