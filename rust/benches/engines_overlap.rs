//! `engines_overlap` smoke bench: proof that per-engine worker threads
//! genuinely decode in parallel. Two method groups (streaming +
//! vanilla) run on two workers over a deliberately slow reference
//! backend; if their decode loops overlap, the sum of per-engine busy
//! time must exceed the router's wall-clock elapsed — a single-threaded
//! scheduler can never satisfy `busy_sum > elapsed`.
//!
//! Saves `target/bench-results/BENCH_engines_overlap.json` with the
//! elapsed/busy split and the overlap ratio (CI uploads it).

use std::time::{Duration, Instant};

use streaming_dllm::coordinator::{Request, RouterHandle, RouterOptions};
use streaming_dllm::engine::{Backend, DecodeOut, Method, RefKv, ReferenceBackend, SpecialTokens};
use streaming_dllm::util::json::Json;

/// Reference backend whose compute entry points (decode *and* logits,
/// so every method preset is covered) cost a fixed wall-clock delay —
/// makes engine busy time dominate scheduling overhead.
struct SlowBackend {
    inner: ReferenceBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.inner.special()
    }

    fn wants_p0(&self) -> bool {
        self.inner.wants_p0()
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.inner.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.inner.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.inner.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.inner.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<RefKv> {
        self.inner.prefill(batch, p_bucket, tokens, pos, valid, p0)
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> anyhow::Result<DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.decode(kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.logits(batch, s_bucket, tokens, pos, valid, p0)
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        self.inner.detokenize(ids)
    }
}

fn main() {
    // content past the whole generation region → no early exit, every
    // row decodes its full 32-block budget
    let boundary = 300usize;
    let router = RouterHandle::spawn_opts(
        move || {
            Ok(SlowBackend {
                inner: ReferenceBackend::scripted(boundary),
                delay: Duration::from_millis(2),
            })
        },
        RouterOptions {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            max_engines: 2,
            ..RouterOptions::default()
        },
    );
    let metrics = router.metrics.clone();

    println!("=== engines_overlap — two method groups on two worker threads ===");
    let plan = [
        (1u64, Method::Streaming),
        (2, Method::Streaming),
        (3, Method::Vanilla),
        (4, Method::Vanilla),
    ];
    let t0 = Instant::now();
    let rxs: Vec<_> = plan
        .iter()
        .map(|&(id, method)| {
            router.submit(Request {
                id,
                prompt: vec![2; 4],
                method,
                policy: None,
                gen_len: 256,
                deadline_ms: None,
                park_on_miss: false,
            })
        })
        .collect();
    for (rx, &(id, _)) in rxs.iter().zip(plan.iter()) {
        let resp = rx
            .recv_timeout(Duration::from_secs(300))
            .unwrap_or_else(|_| panic!("request {id} never completed"));
        assert!(resp.error.is_none(), "request {id} failed: {:?}", resp.error);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    router.shutdown().expect("router shutdown");

    let snap = metrics.snapshot();
    let busy = snap.get("busy_s").and_then(|j| j.as_f64()).expect("busy_s metric");
    let by_method =
        snap.get("busy_by_method").cloned().unwrap_or_else(|| Json::obj(vec![]));
    let engines_peak =
        snap.get("max_engines_active").and_then(|j| j.as_usize()).unwrap_or(0);
    let ratio = busy / elapsed.max(1e-9);

    println!("elapsed wall:     {elapsed:.3}s");
    println!("busy-time sum:    {busy:.3}s  (per method: {by_method})");
    println!("overlap ratio:    {ratio:.2}x (engines peak: {engines_peak})");

    let json = Json::obj(vec![
        ("workload", Json::Str("2x streaming + 2x vanilla, L=256, slow reference".into())),
        ("elapsed_s", Json::Num(elapsed)),
        ("busy_s", Json::Num(busy)),
        ("busy_by_method", by_method),
        ("overlap_ratio", Json::Num(ratio)),
        ("engines_peak", Json::Num(engines_peak as f64)),
    ]);
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join("BENCH_engines_overlap.json");
    let _ = std::fs::write(&path, json.to_string());
    println!("[saved {}]", path.display());

    assert!(
        busy > elapsed,
        "engines did not overlap: busy-time sum {busy:.3}s <= elapsed {elapsed:.3}s"
    );
    println!("(acceptance: busy-time sum > elapsed — decode loops genuinely run in parallel)");
}
