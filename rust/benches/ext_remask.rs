//! Extension study (beyond the paper's tables): ReMDM-style
//! inference-time remasking (Wang et al. 2025, cited in paper §2.2)
//! layered on top of Streaming-dLLM. Each committed token whose
//! confidence was below τ_remask may be re-masked once for revision —
//! the cost/quality trade-off the ReMDM paper describes, here measured
//! on the same harness as every other table (exact match, partial-credit
//! CoT similarity, tok/s, NFE).
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::run_suite;

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let mrt = setup.model(model);
    let n = common::bench_n();
    let gen_len = 64;
    let items = setup.suite("gsm-mini");
    let items = &items[..n.min(items.len())];

    println!("=== Extension — ReMDM remasking on Streaming-dLLM (gsm-mini, L={gen_len}) ===");
    println!(
        "{:<14}{:>10}{:>10}{:>14}{:>8}",
        "remask_tau", "Acc.(%)", "CoTsim", "Th.(tok/s)", "NFE"
    );
    for tau in [0.0f32, 0.3, 0.5, 0.7] {
        let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
        cfg.remask = tau > 0.0;
        cfg.remask_tau = tau;
        let res = run_suite(&mrt, &cfg, items, None).expect("suite");
        println!(
            "{:<14}{:>10.1}{:>10.1}{:>14.1}{:>8.1}",
            if tau == 0.0 { "off".to_string() } else { format!("{tau}") },
            res.accuracy(),
            res.cot_similarity(),
            res.tokens_per_sec(),
            res.steps as f64 / items.len() as f64
        );
    }
    println!("(n={n}; expected: NFE rises with remask_tau while quality stays flat-or-better)");
}
