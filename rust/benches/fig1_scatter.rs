//! Paper Figure 1: accuracy-vs-throughput scatter across acceleration
//! strategies. Runs the five methods over gsm-mini and prints the
//! scatter series with accuracy, throughput and NFE per method, saving
//! `BENCH_fig1_scatter.json` (uploaded by CI's bench-smoke job).
//!
//! Under the toy reference mode every method sits at 100% accuracy and
//! only throughput moves; under `SDLLM_REF_MODE=causal` premature
//! commits corrupt dependent tokens, so the scatter reproduces the
//! paper's actual quality/throughput frontier on a bare checkout.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::Method;
use streaming_dllm::util::bench::{save_rows, Cell, Row};

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let n = common::bench_n();
    let gen_len = 64;
    let suite = "gsm-mini";
    let items = setup.suite(suite);
    let items = &items[..n.min(items.len())];

    let label = if setup.is_reference() {
        format!("{suite} L={gen_len} [{}]", common::ref_mode())
    } else {
        format!("{suite} L={gen_len}")
    };
    println!("=== Figure 1 — accuracy vs throughput scatter ({label}) ===");
    println!("{:<16}{:>10}{:>10}{:>14}{:>10}", "method", "acc(%)", "cot(%)", "tok/s", "NFE");
    let mut cells: Vec<(String, Cell)> = vec![];
    for method in Method::all() {
        // fresh backend per method: under causal mode the emit call
        // counter seeds guess/jitter draws, so sharing one backend
        // would let each method's result depend on its predecessors
        let mrt = setup.model(model);
        let res = common::run_cell(&mrt, method, model, suite, gen_len, items);
        let cell = res.to_cell();
        println!(
            "{:<16}{:>10.1}{:>10.1}{:>14.1}{:>10.1}",
            method.name(),
            cell.accuracy,
            cell.cot_sim,
            cell.tokens_per_s,
            cell.nfe
        );
        cells.push((method.name().to_string(), cell));
    }
    // composable-policy sweep: the same Streaming engine decoding under
    // the new spatial×temporal presets — the extra frontier points the
    // per-request policy API adds beyond the five named methods
    for policy in ["attenuating", "extrapolating"] {
        let mrt = setup.model(model);
        let res = common::run_policy_cell(&mrt, policy, model, suite, gen_len, items);
        let cell = res.to_cell();
        println!(
            "{:<16}{:>10.1}{:>10.1}{:>14.1}{:>10.1}",
            policy, cell.accuracy, cell.cot_sim, cell.tokens_per_s, cell.nfe
        );
        cells.push((policy.to_string(), cell));
    }
    save_rows("fig1_scatter", &[Row { label, cells }]);
    println!("(expected: ours sits on the top-right frontier of accuracy vs throughput)");
}
