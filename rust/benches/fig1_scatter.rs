//! Paper Figure 1: accuracy-vs-throughput scatter across acceleration
//! strategies. Aggregates the saved main-table JSON (run table2 first)
//! or recomputes a small grid, then prints the scatter series.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::Method;
use streaming_dllm::util::json::Json;

fn main() {
    let saved = std::path::Path::new("target/bench-results/BENCH_main_llada15-mini.json");
    let rows: Vec<(String, Vec<(String, f64, f64)>)> = if saved.exists() {
        let j = Json::parse(&std::fs::read_to_string(saved).unwrap()).unwrap();
        j.as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                let label = r.get("label").unwrap().as_str().unwrap().to_string();
                let cells = r
                    .get("cells")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|c| {
                        (
                            c.get("method").unwrap().as_str().unwrap().to_string(),
                            c.get("accuracy").unwrap().as_f64().unwrap(),
                            c.get("tokens_per_s").unwrap().as_f64().unwrap(),
                        )
                    })
                    .collect();
                (label, cells)
            })
            .collect()
    } else {
        println!("(no saved main-table results; computing a reduced grid — run table2 first)");
        let Some(setup) = common::Setup::new() else { return };
        let model = "llada15-mini";
        let mrt = setup.model(model);
        let n = common::bench_n().min(8);
        let items = setup.suite("gsm-mini");
        let items = &items[..n.min(items.len())];
        let cells = Method::all()
            .into_iter()
            .map(|m| {
                let res = common::run_cell(&mrt, m, model, "gsm-mini", 64, items);
                (m.name().to_string(), res.accuracy(), res.tokens_per_sec())
            })
            .collect();
        vec![("gsm-mini L=64".to_string(), cells)]
    };

    println!("=== Figure 1 — accuracy vs throughput scatter ===");
    println!("{:<28}{:<16}{:>10}{:>14}", "setting", "method", "acc(%)", "tok/s");
    for (label, cells) in &rows {
        for (method, acc, tps) in cells {
            println!("{:<28}{:<16}{:>10.1}{:>14.1}", label, method, acc, tps);
        }
    }
    println!("(expected: ours sits on the top-right frontier of accuracy vs throughput)");
}
