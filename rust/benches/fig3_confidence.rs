//! Paper Figure 3 (and appendix Figures 7–14): token-confidence
//! distribution across diffusion steps, per generation block. Traces the
//! mean + IQR(25–75%) of masked-token confidences at each step of the
//! fixed-threshold decode (the paper's Fast-dLLM setting) over GSM-mini
//! prompts — the motivation plot for the dynamic threshold.
//!
//! Part B sweeps the static threshold τ ∈ {1.0, 0.9, 0.7, 0.5} and
//! reports accuracy vs NFE. Under `SDLLM_REF_MODE=causal` the curve
//! actually bends: lower τ commits guesses whose masked predecessors
//! make them wrong, trading accuracy for steps — the trade-off the
//! paper's dynamic threshold (Eq. 10) navigates. Saves
//! `BENCH_fig3_tau_sweep.json` alongside the confidence-trace CSV.
#[path = "common.rs"]
mod common;

use std::collections::BTreeMap;

use streaming_dllm::engine::{Backend, GenConfig, Generator, Method, SeqState, StepEvent};
use streaming_dllm::eval::run_suite;
use streaming_dllm::util::bench::{save_rows, Cell, Row};
use streaming_dllm::util::stats::mean_iqr;

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let mrt = setup.model(model);
    // paper: 100 samples, gen length 256 (÷4 → 64)
    let n = std::env::var("SDLLM_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(40);
    let gen_len = 64;
    let items = setup.suite("gsm-mini");
    let items = &items[..n.min(items.len())];

    // (block, step) -> confidences of still-masked tokens
    let mut traces: BTreeMap<(usize, usize), Vec<f64>> = BTreeMap::new();
    let cfg = GenConfig::preset(Method::FastDllm, gen_len);
    let mut generator = Generator::new(&mrt, cfg.clone()).expect("generator");
    for item in items {
        let mut hook = |ev: StepEvent| {
            traces
                .entry((ev.block, ev.step_in_block))
                .or_default()
                .extend(ev.masked_confs.iter().map(|&c| c as f64));
        };
        let mut seqs = vec![SeqState::new(&item.prompt, gen_len, &mrt.special())];
        generator.generate(&mut seqs, Some(&mut hook)).expect("generate");
    }

    println!(
        "=== Figure 3 / 7-14 — confidence evolution (gsm-mini, {} samples, tau0={}) ===",
        items.len(),
        cfg.tau0()
    );
    println!("{:<8}{:<8}{:>8}{:>10}{:>10}{:>10}", "block", "step", "n", "mean", "q25", "q75");
    let mut csv = String::from("block,step,n,mean,q25,q75\n");
    for ((block, step), confs) in &traces {
        let (mean, q25, q75) = mean_iqr(confs);
        println!(
            "{:<8}{:<8}{:>8}{:>10.3}{:>10.3}{:>10.3}",
            block,
            step,
            confs.len(),
            mean,
            q25,
            q75
        );
        csv.push_str(&format!("{block},{step},{},{mean:.4},{q25:.4},{q75:.4}\n", confs.len()));
    }
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write("target/bench-results/fig3_confidence.csv", csv);
    println!("[saved target/bench-results/fig3_confidence.csv]");
    println!("(expected: confidence rises with step in a block; later blocks start higher)");

    // Part B — the accuracy/NFE trade-off as the static threshold drops.
    let label = if setup.is_reference() {
        format!("gsm-mini L={gen_len} fast-dllm [{}]", common::ref_mode())
    } else {
        format!("gsm-mini L={gen_len} fast-dllm")
    };
    println!("\n=== Figure 3b — τ sweep, accuracy vs NFE ({label}) ===");
    println!("{:<10}{:>10}{:>10}{:>10}{:>14}", "tau", "acc(%)", "cot(%)", "NFE", "tok/s");
    let mut cells: Vec<(String, Cell)> = vec![];
    for tau in [1.0f32, 0.9, 0.7, 0.5] {
        // fresh backend per point: call-counter state stays comparable
        let be = setup.model(model);
        let mut cfg = GenConfig::preset(Method::FastDllm, gen_len);
        cfg.set_tau0(tau);
        let res = run_suite(&be, &cfg, items, None).expect("suite");
        let cell = res.to_cell();
        println!(
            "{:<10.1}{:>10.1}{:>10.1}{:>10.1}{:>14.1}",
            tau, cell.accuracy, cell.cot_sim, cell.nfe, cell.tokens_per_s
        );
        cells.push((format!("tau={tau:.1}"), cell));
    }
    save_rows("fig3_tau_sweep", &[Row { label, cells }]);
    println!("(expected under causal mode: NFE falls and accuracy degrades as τ drops)");
}
