//! Paper Figure 5: sliding-window-size sweep. Accuracy and throughput
//! vs w — throughput falls as the window grows (more compute per step),
//! accuracy saturates early; the knee is the paper's w=128-of-512 point
//! (here w=32-of-128 after ÷4 scaling).
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::run_suite;
use streaming_dllm::util::bench::{save_rows, Row};

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let mrt = setup.model(model);
    let n = common::bench_n();
    let gen_len = 128;
    let items = setup.suite("gsm-mini");
    let items = &items[..n.min(items.len())];

    println!(
        "=== Figure 5 — window sweep (gsm-mini, L={gen_len}, mode {}; paper w = 4x these) ===",
        common::ref_mode()
    );
    println!("{:<10}{:>10}{:>14}{:>10}", "w", "Acc.(%)", "Th.(tok/s)", "NFE");
    let mut rows = vec![];
    // full window = whole suffix (120) — the paper's "no suffix windows, mean size=512" anchor
    for w in [4usize, 8, 16, 32, 64, 120] {
        let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
        cfg.set_window(w);
        cfg.early_exit = false; // isolate the spatial axis like the paper
        cfg.set_dynamic_threshold(false);
        let res = run_suite(&mrt, &cfg, items, None).expect("suite");
        println!(
            "{:<10}{:>10.1}{:>14.1}{:>10.1}",
            w,
            res.accuracy(),
            res.tokens_per_sec(),
            res.steps as f64 / items.len() as f64
        );
        let cells = vec![("streaming".to_string(), res.to_cell())];
        rows.push(Row { label: format!("w={w}"), cells });
    }
    // under SDLLM_REF_MODE=causal this charts the paper's window/quality
    // sensitivity on a bare checkout; CI bench-smoke uploads it
    save_rows("fig5_window", &rows);
    println!("(n={n}; expected: throughput decays with w, accuracy saturates at the knee)");
}
