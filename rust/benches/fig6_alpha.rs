//! Paper Figure 6: α sweep for dynamic confidence-aware decoding
//! (Eq. 10). Throughput rises with α (lower late-stage thresholds →
//! more parallel commits); past the knee accuracy degrades — premature
//! commits of unconverged tokens (paper: α≈0.6 knee).
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::run_suite;
use streaming_dllm::util::bench::{save_rows, Row};

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let mrt = setup.model(model);
    let n = common::bench_n();
    let gen_len = 128;
    let items = setup.suite("gsm-mini");
    let items = &items[..n.min(items.len())];

    let mode = common::ref_mode();
    println!("=== Figure 6 — alpha sweep (gsm-mini, L={gen_len}, mode {mode}) ===");
    println!("{:<10}{:>10}{:>14}{:>10}", "alpha", "Acc.(%)", "Th.(tok/s)", "NFE");
    let mut rows = vec![];
    for alpha in [0.0f32, 0.1, 0.2, 0.3, 0.4, 0.6, 0.8, 0.9] {
        let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
        cfg.set_alpha(alpha);
        cfg.early_exit = false; // isolate the temporal-threshold axis
        let res = run_suite(&mrt, &cfg, items, None).expect("suite");
        println!(
            "{:<10}{:>10.1}{:>14.1}{:>10.1}",
            alpha,
            res.accuracy(),
            res.tokens_per_sec(),
            res.steps as f64 / items.len() as f64
        );
        rows.push(Row {
            label: format!("alpha={alpha}"),
            cells: vec![("streaming".into(), res.to_cell())],
        });
    }
    // under SDLLM_REF_MODE=causal this charts the paper's α/quality
    // sensitivity on a bare checkout; CI bench-smoke uploads it
    save_rows("fig6_alpha", &rows);
    println!("(n={n}; alpha=0 = static threshold; NFE falls with alpha, knee past ~0.6)");
}
