//! Host-overhead bench: how much wall time the scheduling layer costs
//! per decode step, and what the zero-allocation workspace core plus
//! the vectorized selection kernels buy.
//!
//! Runs the fig1/table3-style workload (gsm-mini synthetic suite,
//! Streaming) at batch ≥ 4 through two drivers, in *both* reference
//! modes — toy (schedule-independent, model nearly free) and causal
//! (schedule-dependent, per-row hash chains dominate the backend):
//!
//! - `before` — a faithful replica of the seed hot path: fresh bundle /
//!   candidate / host-buffer allocations every step plus the `SeqState`
//!   clone round-trip per batch (the code the workspace PR deleted);
//! - `after`  — the production `Generator` over its reused
//!   `StepWorkspace`, with the chunked SoA selection kernels and
//!   `SDLLM_DECODE_THREADS` row fan-out (default 1).
//!
//! On the reference backend the "model" is cheap, so host overhead
//! dominates the wall — the per-mode speedup column is the acceptance
//! metric. Saves `BENCH_host_overhead.json` with one entry per mode:
//! before/after fields, per-phase µs/step (including the *measured*
//! selection bucket) and the allocs-per-step proxy.
#[path = "common.rs"]
mod common;
/// The seed-path replica shared with `tests/parity.rs` (which pins the
/// production core bit-identical to it) — one copy, two consumers.
#[path = "../tests/common/seed_path.rs"]
mod seed_path;

use std::time::Instant;

use streaming_dllm::engine::{
    Backend, GenConfig, Generator, Method, RefMode, ReferenceBackend, SeqState, REFERENCE_SEED,
};
use streaming_dllm::eval::{synthetic_suite, EvalItem};
use streaming_dllm::util::json::Json;

const BATCH: usize = 4;
const GEN_LEN: usize = 64;

fn decode_threads() -> usize {
    std::env::var("SDLLM_DECODE_THREADS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

fn backend(mode: RefMode) -> ReferenceBackend {
    match mode {
        RefMode::Causal => ReferenceBackend::causal(REFERENCE_SEED),
        _ => ReferenceBackend::toy(REFERENCE_SEED),
    }
}

fn main() {
    let n = (common::bench_n() * 4).max(16);
    let threads = decode_threads();
    println!("=== host_overhead — scheduling layer cost at batch {BATCH} (reference) ===");
    println!(
        "workload: {n} requests per mode, Streaming L={GEN_LEN}, chunks of {BATCH}, \
         decode_threads={threads}"
    );

    let mut mode_rows = vec![];
    for mode in [RefMode::Toy, RefMode::Causal] {
        let oracle = backend(mode);
        let items = synthetic_suite(&oracle, n, 0x05e0);
        let mut cfg = GenConfig::preset(Method::Streaming, GEN_LEN);

        // warmup + timed run per arm, fresh backend each so call
        // counters and any lazy state start identical
        let before = run_arm(mode, &items, &cfg, false);
        cfg.decode_threads = threads;
        let after = run_arm(mode, &items, &cfg, true);

        let speedup = if before.tok_s > 0.0 { after.tok_s / before.tok_s } else { 0.0 };
        println!("--- mode: {} ---", mode.name());
        println!("{:<26}{:>14}{:>14}", "", "before(seed)", "after(ws)");
        println!("{:<26}{:>14.1}{:>14.1}", "non-EOS tok/s", before.tok_s, after.tok_s);
        println!(
            "{:<26}{:>14.2}{:>14.2}",
            "host µs/step", before.host_us_step, after.host_us_step
        );
        println!("{:<26}{:>14}{:>14}", "steps", before.steps, after.steps);
        println!("speedup (after/before): {speedup:.2}x");
        println!(
            "after per-phase µs/step: prefill {:.2} | decode {:.2} | select {:.2} | host {:.2}",
            after.prefill_us_step, after.decode_us_step, after.select_us_step, after.host_us_step
        );
        println!(
            "workspace allocs-per-step proxy: {} grows / {} steps = {:.4}",
            after.ws_grows,
            after.ws_steps,
            after.ws_grows as f64 / after.ws_steps.max(1) as f64
        );

        mode_rows.push(Json::obj(vec![
            ("label", Json::Str(mode.name().to_string())),
            ("before", arm_json(&before)),
            ("after", arm_json(&after)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    let json = Json::obj(vec![
        ("workload", Json::Str(format!("gsm-mini-style synth n={n} streaming L={GEN_LEN}"))),
        ("batch", Json::Num(BATCH as f64)),
        ("decode_threads", Json::Num(threads as f64)),
        ("modes", Json::Arr(mode_rows)),
    ]);
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join("BENCH_host_overhead.json");
    let _ = std::fs::write(&path, json.to_string());
    println!("[saved {}]", path.display());
    println!("(acceptance: speedup ≥ 1.5x at batch ≥ 4 in both modes)");
}

#[derive(Default)]
struct Arm {
    tok_s: f64,
    wall_s: f64,
    steps: u64,
    prefill_us_step: f64,
    decode_us_step: f64,
    select_us_step: f64,
    host_us_step: f64,
    ws_grows: u64,
    ws_steps: u64,
}

fn arm_json(a: &Arm) -> Json {
    Json::obj(vec![
        ("tokens_per_s", Json::Num(a.tok_s)),
        ("wall_s", Json::Num(a.wall_s)),
        ("steps", Json::Num(a.steps as f64)),
        ("prefill_us_per_step", Json::Num(a.prefill_us_step)),
        ("decode_us_per_step", Json::Num(a.decode_us_step)),
        ("select_us_per_step", Json::Num(a.select_us_step)),
        ("host_us_per_step", Json::Num(a.host_us_step)),
        ("ws_grows", Json::Num(a.ws_grows as f64)),
        ("ws_steps", Json::Num(a.ws_steps as f64)),
    ])
}

fn run_arm(mode: RefMode, items: &[EvalItem], cfg: &GenConfig, workspace: bool) -> Arm {
    let be = backend(mode);
    let special = be.special();
    let mut arm = Arm::default();
    // one generator across both passes: the unmeasured warmup pass lets
    // the workspace reach its high-water mark so the timed pass is
    // steady-state (the whole point of the reuse)
    let mut generator = Generator::new(&be, cfg.clone()).expect("generator");
    for pass in 0..2 {
        let timed = pass == 1;
        let t0 = Instant::now();
        let mut tokens = 0u64;
        let mut steps = 0u64;
        let mut prefill_s = 0.0;
        let mut decode_s = 0.0;
        let mut select_s = 0.0;
        for chunk in items.chunks(BATCH) {
            let mut seqs: Vec<SeqState> =
                chunk.iter().map(|it| SeqState::new(&it.prompt, cfg.gen_len, &special)).collect();
            if workspace {
                let report = generator.generate(&mut seqs, None).expect("generate");
                tokens += report.non_eos_tokens;
                steps += report.steps;
                prefill_s += report.prefill_secs;
                decode_s += report.decode_secs;
                select_s += report.select_secs;
            } else {
                let report = seed_path::generate(&be, cfg, &mut seqs).expect("seed generate");
                tokens += seqs.iter().map(|s| s.non_eos_tokens() as u64).sum::<u64>();
                steps += report.steps;
            }
        }
        if timed {
            arm.wall_s = t0.elapsed().as_secs_f64();
            arm.tok_s = tokens as f64 / arm.wall_s.max(1e-9);
            arm.steps = steps;
            let per_step = |s: f64| s * 1e6 / steps.max(1) as f64;
            arm.prefill_us_step = per_step(prefill_s);
            arm.decode_us_step = per_step(decode_s);
            arm.select_us_step = per_step(select_s);
            arm.host_us_step = per_step((arm.wall_s - prefill_s - decode_s).max(0.0));
            if workspace {
                let ws = generator.workspace_stats();
                arm.ws_grows = ws.grows;
                arm.ws_steps = ws.steps;
            }
            // for the seed arm prefill_s/decode_s stay 0 (its hot path
            // isn't instrumented), so host µs/step is the whole wall —
            // the honest pre-PR scheduling cost per step
        }
    }
    arm
}
