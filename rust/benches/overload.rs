//! `overload` smoke bench: admitted-request latency under saturation.
//!
//! A single slow worker (capacity 2) with a small bounded queue is hit
//! with a burst several times its total capacity. The bounded admission
//! path must (a) answer the overflow instantly with typed rejects that
//! carry a `retry_after_ms` hint, and (b) keep the latency of the rows
//! it *did* admit proportional to their queue position — overload slows
//! nobody down retroactively because the queue cannot grow unboundedly.
//!
//! Saves `target/bench-results/BENCH_overload.json` with the admitted
//! p50/p95 latency, reject counts and the mean retry hint (CI uploads
//! it).

use std::time::{Duration, Instant};

use streaming_dllm::coordinator::{Request, RouterHandle, RouterOptions};
use streaming_dllm::engine::{Backend, DecodeOut, Method, RefKv, ReferenceBackend, SpecialTokens};
use streaming_dllm::util::json::Json;

/// Reference backend whose compute entry points cost a fixed wall-clock
/// delay, so service time dominates scheduling overhead and the queue
/// genuinely backs up.
struct SlowBackend {
    inner: ReferenceBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.inner.special()
    }

    fn wants_p0(&self) -> bool {
        self.inner.wants_p0()
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.inner.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.inner.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.inner.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.inner.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<RefKv> {
        self.inner.prefill(batch, p_bucket, tokens, pos, valid, p0)
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> anyhow::Result<DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.decode(kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.logits(batch, s_bucket, tokens, pos, valid, p0)
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        self.inner.detokenize(ids)
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p / 100.0).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    // content past the whole generation region → no early exit, every
    // admitted row decodes its full 16-block budget (~16 * 4ms)
    let boundary = 300usize;
    let depth = 8usize;
    let burst = 4 * depth; // well above queue + worker capacity
    let router = RouterHandle::spawn_opts(
        move || {
            Ok(SlowBackend {
                inner: ReferenceBackend::scripted(boundary),
                delay: Duration::from_millis(4),
            })
        },
        RouterOptions {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            max_engines: 1,
            max_queue_depth: depth,
            ..RouterOptions::default()
        },
    );
    let metrics = router.metrics.clone();

    println!("=== overload — burst of {burst} onto 1 slow worker, queue depth {depth} ===");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..burst)
        .map(|i| {
            router.submit(Request {
                id: i as u64,
                prompt: vec![2; 4],
                method: Method::Streaming,
                policy: None,
                gen_len: 128,
                deadline_ms: None,
                park_on_miss: false,
            })
        })
        .collect();

    let mut admitted_lat = Vec::new();
    let mut retry_hints = Vec::new();
    for (i, rx) in rxs.iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(300))
            .unwrap_or_else(|_| panic!("request {i} never resolved"));
        if resp.rejected {
            retry_hints.push(resp.retry_after_ms.unwrap_or(0) as f64);
        } else {
            assert!(resp.error.is_none(), "request {i} failed: {:?}", resp.error);
            admitted_lat.push(resp.latency_s);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    router.shutdown().expect("router shutdown");

    admitted_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let admitted = admitted_lat.len();
    let rejected = retry_hints.len();
    let p50 = percentile(&admitted_lat, 50.0);
    let p95 = percentile(&admitted_lat, 95.0);
    let hint_mean = retry_hints.iter().sum::<f64>() / rejected.max(1) as f64;
    let snap = metrics.snapshot();
    let peak = snap.get("queue_depth_peak").and_then(|j| j.as_usize()).unwrap_or(0);

    println!("admitted:         {admitted} (p50 {p50:.3}s, p95 {p95:.3}s)");
    println!("rejected:         {rejected} (mean retry hint {hint_mean:.0}ms)");
    println!("queue depth peak: {peak} (bound {depth})");
    println!("drained in:       {elapsed:.3}s");

    let json = Json::obj(vec![
        (
            "workload",
            Json::Str(format!("burst {burst}, 1 slow worker x batch 2, queue depth {depth}")),
        ),
        ("burst", Json::Num(burst as f64)),
        ("queue_depth", Json::Num(depth as f64)),
        ("admitted", Json::Num(admitted as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("admitted_latency_p50_s", Json::Num(p50)),
        ("admitted_latency_p95_s", Json::Num(p95)),
        ("retry_hint_mean_ms", Json::Num(hint_mean)),
        ("queue_depth_peak", Json::Num(peak as f64)),
        ("elapsed_s", Json::Num(elapsed)),
    ]);
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join("BENCH_overload.json");
    let _ = std::fs::write(&path, json.to_string());
    println!("[saved {}]", path.display());

    assert!(rejected > 0, "the burst never overflowed the bounded queue");
    assert!(admitted >= depth, "fewer admitted rows than the queue can hold");
    assert!(p50.is_finite() && p50 > 0.0, "admitted p50 latency must be measurable");
    assert!(peak <= depth, "queue depth peak {peak} exceeded the bound {depth}");
    println!(
        "(acceptance: overflow rejected with retry hints; admitted p50 stays bounded \
         by queue position, not burst size)"
    );
}
