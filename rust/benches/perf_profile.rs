//! §Perf profiling harness: per-layer wall-clock breakdown of the
//! serving hot path — executable dispatch, host→device upload, model
//! execute, output sync, and the pure-rust scheduling layer — plus
//! per-bucket decode-step microbenchmarks. This is what the
//! EXPERIMENTS.md §Perf before/after numbers come from.
#[path = "common.rs"]
mod common;

use std::time::Instant;

use streaming_dllm::engine::{GenConfig, Generator, Method, SeqState};
use streaming_dllm::util::bench::time_fn;

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let mrt = setup.model(model);
    let items = setup.suite("gsm-mini");

    // -------- decode-step microbench per query bucket ----------------
    println!("=== decode-step cost per (P, Q) bucket (b=1) ===");
    println!("{:<10}{:<10}{:>14}", "P", "Q", "ms/step");
    let p0 = items[0].prompt.len();
    for &p in &[160usize, 224] {
        let tokens: Vec<i32> = (0..p).map(|i| if i < p0 { items[0].prompt[i] } else { 1 }).collect();
        let pos: Vec<i32> = (0..p as i32).collect();
        let kv = mrt.prefill(1, p, &tokens, &pos, &[p0 as i32], None).expect("prefill");
        for &q in &[13usize, 25, 41, 73, 137] {
            let q_tok = vec![1i32; q];
            let q_pos: Vec<i32> = (p0 as i32..(p0 + q) as i32).collect();
            let w = time_fn(2, 8, || {
                mrt.decode(&kv, q, &q_tok, &q_pos, &[q as i32]).expect("decode");
            });
            println!("{:<10}{:<10}{:>14.2}", p, q, w.mean() * 1e3);
        }
    }

    // -------- prefill + logits cost per bucket ------------------------
    println!("\n=== prefill / logits cost per bucket (b=1) ===");
    println!("{:<10}{:<12}{:>14}", "bucket", "kind", "ms/call");
    for &p in &[96usize, 160, 224, 352] {
        let tokens = vec![2i32; p];
        let pos: Vec<i32> = (0..p as i32).collect();
        let w = time_fn(1, 5, || {
            mrt.prefill(1, p, &tokens, &pos, &[16], None).expect("prefill");
        });
        println!("{:<10}{:<12}{:>14.2}", p, "prefill", w.mean() * 1e3);
        let w = time_fn(1, 5, || {
            mrt.logits(1, p, &tokens, &pos, &[16], None).expect("logits");
        });
        println!("{:<10}{:<12}{:>14.2}", p, "logits", w.mean() * 1e3);
    }

    // -------- end-to-end breakdown -------------------------------------
    println!("\n=== end-to-end breakdown (streaming, gsm-mini L=64, 8 samples) ===");
    let cfg = GenConfig::preset(Method::Streaming, 64);
    let generator = Generator::new(&mrt, cfg.clone()).expect("gen");
    mrt.reset_stats();
    let t0 = Instant::now();
    for item in items.iter().take(8) {
        let mut seqs = vec![SeqState::new(&item.prompt, 64, &mrt.manifest.special)];
        generator.generate(&mut seqs, None).expect("generate");
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = mrt.stats();
    let model_secs = st.total_model_secs();
    println!("wall                : {:>8.3}s", wall);
    println!("model execute       : {:>8.3}s ({:.1}%)", model_secs, 100.0 * model_secs / wall);
    println!("  prefill           : {:>8.3}s ({} calls)", st.prefill_secs, st.prefill_calls);
    println!("  decode            : {:>8.3}s ({} calls)", st.decode_secs, st.decode_calls);
    println!("  logits            : {:>8.3}s ({} calls)", st.logits_secs, st.logits_calls);
    println!("rust scheduling     : {:>8.3}s ({:.1}%)", wall - model_secs, 100.0 * (wall - model_secs) / wall);
    println!("compile (first-use) : {:>8.3}s ({} executables)", st.compile_secs, st.compile_count);
    println!("\nL3 target: rust scheduling share < 10% of wall (the coordinator must not be the bottleneck)");
}
