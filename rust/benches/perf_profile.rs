//! §Perf profiling harness: per-call wall-clock microbenchmarks of the
//! serving hot path (prefill / decode / logits per bucket) plus an
//! end-to-end breakdown of a streaming run — model-call time vs the
//! pure-rust scheduling layer. Runs against whichever backend the
//! checkout provides (PJRT artifacts or the reference model), so the
//! EXPERIMENTS.md §Perf before/after numbers accumulate either way.
#[path = "common.rs"]
mod common;

use std::time::Instant;

use streaming_dllm::engine::{Backend, GenConfig, Generator, Method, SeqState};
use streaming_dllm::util::bench::time_fn;

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let be = setup.model(model);
    let items = setup.suite("gsm-mini");

    // -------- decode-step microbench per query bucket ----------------
    println!("=== decode-step cost per (P, Q) bucket (b=1) ===");
    println!("{:<10}{:<10}{:>14}", "P", "Q", "ms/step");
    let p0 = items[0].prompt.len();
    for &p in &[160usize, 224] {
        let tokens: Vec<i32> =
            (0..p).map(|i| if i < p0 { items[0].prompt[i] } else { 1 }).collect();
        let pos: Vec<i32> = (0..p as i32).collect();
        let valid = [p0 as i32];
        let p0s = [p0 as i32];
        let p0_arg = if be.wants_p0() { Some(&p0s[..]) } else { None };
        let kv = be.prefill(1, p, &tokens, &pos, &valid, p0_arg).expect("prefill");
        for &q in &[13usize, 25, 41, 73, 137] {
            let q_tok = vec![1i32; q];
            let q_pos: Vec<i32> = (p0 as i32..(p0 + q) as i32).collect();
            let q_valid = [q as i32];
            let w = time_fn(2, 8, || {
                be.decode(&kv, q, &q_tok, &q_pos, &q_valid).expect("decode");
            });
            println!("{:<10}{:<10}{:>14.3}", p, q, w.mean() * 1e3);
        }
    }

    // -------- prefill + logits cost per bucket ------------------------
    println!("\n=== prefill / logits cost per bucket (b=1) ===");
    println!("{:<10}{:<12}{:>14}", "bucket", "kind", "ms/call");
    for &p in &[96usize, 160, 224, 352] {
        let tokens = vec![2i32; p];
        let pos: Vec<i32> = (0..p as i32).collect();
        let valid = [16i32];
        let p0s = [16i32];
        let p0_arg = if be.wants_p0() { Some(&p0s[..]) } else { None };
        let w = time_fn(1, 5, || {
            be.prefill(1, p, &tokens, &pos, &valid, p0_arg).expect("prefill");
        });
        println!("{:<10}{:<12}{:>14.3}", p, "prefill", w.mean() * 1e3);
        let w = time_fn(1, 5, || {
            be.logits(1, p, &tokens, &pos, &valid, p0_arg).expect("logits");
        });
        println!("{:<10}{:<12}{:>14.3}", p, "logits", w.mean() * 1e3);
    }

    // -------- end-to-end breakdown -------------------------------------
    println!("\n=== end-to-end breakdown (streaming, gsm-mini L=64, 8 samples) ===");
    let cfg = GenConfig::preset(Method::Streaming, 64);
    let mut generator = Generator::new(&be, cfg.clone()).expect("gen");
    let special = be.special();
    let compile_before = be.compile_secs();
    let t0 = Instant::now();
    let mut steps = 0u64;
    let mut prefills = 0u64;
    let mut tokens = 0u64;
    let mut prefill_s = 0.0;
    let mut decode_s = 0.0;
    let mut select_s = 0.0;
    let mut host_s = 0.0;
    for item in items.iter().take(8) {
        let mut seqs = vec![SeqState::new(&item.prompt, 64, &special)];
        let report = generator.generate(&mut seqs, None).expect("generate");
        steps += report.steps;
        prefills += report.prefills;
        tokens += report.non_eos_tokens;
        prefill_s += report.prefill_secs;
        decode_s += report.decode_secs;
        select_s += report.select_secs;
        host_s += report.host_secs;
    }
    let wall = t0.elapsed().as_secs_f64();
    let compile = be.compile_secs() - compile_before;
    println!("wall                : {:>8.3}s", wall);
    println!("compile (first-use) : {:>8.3}s", compile);
    println!("decode steps        : {steps:>8}");
    println!("prefills            : {prefills:>8}");
    println!("non-EOS tokens      : {tokens:>8}");
    println!("throughput          : {:>8.1} tok/s", tokens as f64 / (wall - compile).max(1e-9));
    println!("\n--- per-phase breakdown (GenReport timers) ---");
    let share = |s: f64| 100.0 * s / wall.max(1e-9);
    println!("prefill (backend)   : {:>8.3}s ({:>5.1}%)", prefill_s, share(prefill_s));
    println!("decode  (backend)   : {:>8.3}s ({:>5.1}%)", decode_s, share(decode_s));
    println!("host (scheduling)   : {:>8.3}s ({:>5.1}%)", host_s, share(host_s));
    // measured sub-bucket of host: the candidate-gather/selection/commit
    // inner loops the vectorized kernels target
    println!("  └ select (kernels): {:>8.3}s ({:>5.1}%)", select_s, share(select_s));
    let ws = generator.workspace_stats();
    println!(
        "workspace           : {} buffer grows / {} steps ({:.4} allocs-per-step proxy)",
        ws.grows,
        ws.steps,
        ws.grows as f64 / ws.steps.max(1) as f64
    );
    println!("\n(per-call model costs above vs this wall give the scheduling share;");
    println!(" L3 target: rust scheduling < 10% of wall on the PJRT backend)");
}
