//! `prefix_reuse` smoke bench: cross-request radix prefix cache.
//!
//! Four requests share a 256-token prompt template and differ only in
//! an 8-token suffix — the shared-system-prompt serving shape. The
//! backend is a reference backend wrapped in a wall-clock cost model
//! where prefill costs a fixed delay per prompt token *not* covered by
//! a cached span, so the measured prefill seconds track the compute a
//! real model would skip.
//!
//! Cold run: empty cache, every prompt prefilled in full (intra-batch
//! sig-window dedup still collapses the shared template hash to one).
//! Warm run: a second backend instance — another worker, in serving
//! terms — replays the same prompts against the populated cache and
//! must (a) spend ≤ 0.5× the cold prefill seconds and (b) produce
//! byte-identical texts, the bit-identity contract the parity suite
//! pins.
//!
//! Saves `target/bench-results/BENCH_prefix_reuse.json` (CI uploads
//! it). Honors `SDLLM_REF_MODE` (toy|causal) like the serving stack.

use std::time::Duration;

use streaming_dllm::engine::{
    prefix_scope_for, Backend, BatchEngine, CachedSpan, DecodeOut, GenConfig, Method,
    PrefixCapture, PrefixHandle, RefKv, RefStats, ReferenceBackend, SharedPrefixCache,
    SpecialTokens, REFERENCE_SEED,
};
use streaming_dllm::util::json::Json;

/// Modeled prefill cost per uncovered prompt token.
const PER_TOKEN: Duration = Duration::from_micros(20);

const BATCH: usize = 4;
const TEMPLATE_TOKENS: usize = 256;
const SUFFIX_TOKENS: usize = 8;

/// Reference backend under a prefill cost model: each prefill sleeps
/// proportionally to the prompt tokens it actually has to compute
/// (cached spans are trusted the way a real KV restore would be), so
/// cold-vs-warm prefill seconds measure the cache, not the scheduler.
struct CostModelBackend {
    inner: ReferenceBackend,
}

impl CostModelBackend {
    fn new(mode: &str) -> CostModelBackend {
        let inner = if mode == "causal" {
            ReferenceBackend::causal(REFERENCE_SEED)
        } else {
            ReferenceBackend::toy(REFERENCE_SEED)
        };
        CostModelBackend { inner }
    }

    fn stats(&self) -> RefStats {
        self.inner.stats()
    }
}

/// Sleep for the uncovered token count: each row pays its forwarded
/// prefix length minus whatever a cached span restores.
fn prefill_cost(valid: &[i32], cached: Option<&[CachedSpan]>) {
    let mut uncovered = 0u64;
    for (b, &v) in valid.iter().enumerate() {
        let plen = v.max(0) as u64;
        let covered = cached
            .and_then(|c| c.get(b))
            .filter(|s| s.capture.is_some())
            .map(|s| (s.len as u64).min(plen))
            .unwrap_or(0);
        uncovered += plen - covered;
    }
    std::thread::sleep(PER_TOKEN * uncovered as u32);
}

impl Backend for CostModelBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.inner.special()
    }

    fn wants_p0(&self) -> bool {
        self.inner.wants_p0()
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.inner.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.inner.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.inner.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.inner.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<RefKv> {
        prefill_cost(valid, None);
        self.inner.prefill(batch, p_bucket, tokens, pos, valid, p0)
    }

    #[allow(clippy::too_many_arguments)]
    fn prefill_cached(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
        cached: &[CachedSpan],
    ) -> anyhow::Result<RefKv> {
        prefill_cost(valid, Some(cached));
        self.inner.prefill_cached(batch, p_bucket, tokens, pos, valid, p0, cached)
    }

    fn capture_prefix(&self, kv: &RefKv, row: usize, prefix_len: usize) -> Option<PrefixCapture> {
        self.inner.capture_prefix(kv, row, prefix_len)
    }

    fn prefix_scope(&self) -> u64 {
        self.inner.prefix_scope()
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> anyhow::Result<DecodeOut> {
        self.inner.decode(kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<DecodeOut> {
        self.inner.logits(batch, s_bucket, tokens, pos, valid, p0)
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        self.inner.detokenize(ids)
    }
}

/// Drive one engine over the whole batch against the shared cache,
/// returning the prefill seconds spent and each row's final text.
fn run_batch(
    be: &CostModelBackend,
    prompts: &[Vec<i32>],
    gen_len: usize,
    cache: &SharedPrefixCache,
) -> (f64, Vec<String>) {
    let cfg = GenConfig::preset(Method::Streaming, gen_len);
    let mut engine = BatchEngine::new(be, cfg, prompts.len()).expect("engine");
    let scope = prefix_scope_for(be, engine.config());
    engine.set_prefix_cache(PrefixHandle { cache: cache.clone(), scope });
    for (i, p) in prompts.iter().enumerate() {
        assert!(engine.admit(i as u64, p, gen_len), "row {i} failed to admit");
    }
    let mut texts = vec![String::new(); prompts.len()];
    let mut guard = 0;
    while engine.active() > 0 {
        guard += 1;
        assert!(guard < 1000, "engine failed to drain");
        for f in engine.step_block().expect("step_block") {
            texts[f.tag as usize] = be.detokenize(f.seq.generated());
        }
    }
    (engine.report().prefill_secs, texts)
}

fn main() {
    let mode_env = std::env::var("SDLLM_REF_MODE").unwrap_or_default();
    let mode =
        if mode_env.trim().eq_ignore_ascii_case("causal") { "causal" } else { "toy" };

    // one decode block per request keeps the run to a single prefill,
    // the phase the cache targets
    let gen_len = GenConfig::preset(Method::Streaming, 64).block_size;
    let template: Vec<i32> = (0..TEMPLATE_TOKENS).map(|i| 10 + ((i * 7) % 48) as i32).collect();
    let prompts: Vec<Vec<i32>> = (0..BATCH)
        .map(|r| {
            let mut p = template.clone();
            p.extend((0..SUFFIX_TOKENS).map(|j| 70 + (r * SUFFIX_TOKENS + j) as i32));
            p
        })
        .collect();
    let prompt_tokens = prompts[0].len();

    println!(
        "=== prefix_reuse ({mode}) — {BATCH} prompts sharing a {TEMPLATE_TOKENS}-token \
         template, {}us/token prefill model ===",
        PER_TOKEN.as_micros()
    );

    // dedup yardstick: one row alone, on its own backend and cache,
    // hashes exactly one sig window — the shared-template batch below
    // must not hash more than that
    let probe_be = CostModelBackend::new(mode);
    let _ = run_batch(&probe_be, &prompts[..1], gen_len, &SharedPrefixCache::new(1 << 20));
    let hashed_single = probe_be.stats().prefix_tokens_hashed;

    let cache = SharedPrefixCache::new(32 * 1024 * 1024);

    let cold_be = CostModelBackend::new(mode);
    let (cold_prefill, cold_texts) = run_batch(&cold_be, &prompts, gen_len, &cache);
    let hashed_cold = cold_be.stats().prefix_tokens_hashed;

    // a second backend instance — fresh call counters, same seed, so
    // in serving terms another worker thread sharing the router cache
    let warm_be = CostModelBackend::new(mode);
    let (warm_prefill, warm_texts) = run_batch(&warm_be, &prompts, gen_len, &cache);
    let hashed_warm = warm_be.stats().prefix_tokens_hashed;

    cache.check_invariants();
    let stats = cache.stats();
    let ratio = warm_prefill / cold_prefill.max(1e-9);

    println!("cold prefill:    {cold_prefill:.4}s  (sig tokens hashed: {hashed_cold})");
    println!("warm prefill:    {warm_prefill:.4}s  (sig tokens hashed: {hashed_warm})");
    println!("warm/cold:       {ratio:.3}x");
    println!(
        "cache:           {} hits / {} misses / {} inserts, {} tokens reused",
        stats.hits, stats.misses, stats.inserts, stats.reused_tokens
    );

    let json = Json::obj(vec![
        (
            "workload",
            Json::Str(format!(
                "{BATCH} prompts = {TEMPLATE_TOKENS}-token shared template + \
                 {SUFFIX_TOKENS}-token suffix, cold vs warm engine"
            )),
        ),
        ("mode", Json::Str(mode.to_string())),
        ("batch", Json::Num(BATCH as f64)),
        ("prompt_tokens", Json::Num(prompt_tokens as f64)),
        ("shared_template_tokens", Json::Num(TEMPLATE_TOKENS as f64)),
        ("cold_prefill_s", Json::Num(cold_prefill)),
        ("warm_prefill_s", Json::Num(warm_prefill)),
        ("warm_over_cold", Json::Num(ratio)),
        ("cache_hits", Json::Num(stats.hits as f64)),
        ("cache_inserts", Json::Num(stats.inserts as f64)),
        ("reused_tokens", Json::Num(stats.reused_tokens as f64)),
        ("dedup_tokens_hashed_cold", Json::Num(hashed_cold as f64)),
    ]);
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join("BENCH_prefix_reuse.json");
    let _ = std::fs::write(&path, json.to_string());
    println!("[saved {}]", path.display());

    assert!(
        warm_prefill <= 0.5 * cold_prefill,
        "warm prefill {warm_prefill:.4}s must be <= 0.5x cold {cold_prefill:.4}s"
    );
    assert_eq!(warm_texts, cold_texts, "cached-prefix decode must be bit-identical to cold");
    assert!(stats.hits >= BATCH as u64, "warm run should fully hit for every prompt");
    assert!(stats.inserts >= BATCH as u64, "cold run should insert every prompt");
    // intra-batch dedup: the four cold rows share one sig window, so
    // the whole batch hashes no more than a single row alone does
    assert!(
        hashed_cold <= hashed_single,
        "intra-batch dedup must collapse shared sig windows: batch of {BATCH} hashed \
         {hashed_cold} tokens vs {hashed_single} for one row"
    );
    assert_eq!(hashed_warm, 0, "warm rows must not re-hash cached prefixes");
    println!(
        "(acceptance: warm prefill <= 0.5x cold, byte-identical texts, shared windows \
         hashed once)"
    );
}
