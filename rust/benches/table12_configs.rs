//! Paper Table 12: the per-(model, benchmark, length) hyperparameter
//! configuration table, emitted from the presets actually used by the
//! benches (windows ÷4 vs the paper's values).
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::table12_config;

fn main() {
    println!("=== Table 12 — configurations (lengths & windows are paper values ÷ 4) ===");
    println!(
        "{:<16}{:<22}{:>8}{:>9}{:>7}{:>7}{:>12}",
        "model", "benchmark", "gen len", "window", "tau0", "alpha", "block_size"
    );
    for model in ["dream-mini", "llada-mini", "llada15-mini"] {
        for (suite, _) in common::SUITES {
            for gen_len in common::GEN_LENS {
                let c = table12_config(model, suite, gen_len);
                println!(
                    "{:<16}{:<22}{:>8}{:>9}{:>7.1}{:>7.1}{:>12}",
                    model,
                    suite,
                    gen_len,
                    c.window(),
                    c.tau0(),
                    c.alpha(),
                    c.block_size
                );
            }
        }
    }
}
