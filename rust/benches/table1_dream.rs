//! Paper Table 1 (+ latency Table 9): Dream-Base suite — accuracy and
//! throughput/latency for 5 methods × 4 benchmarks × 2 gen lengths.
#[path = "common.rs"]
mod common;

fn main() {
    common::main_table("dream-mini", "Table 1 — Dream-mini (paper: Dream-v0-7B-Base)");
}
