//! Paper Table 2 (+ latency Table 10): LLaDA-1.5 suite.
#[path = "common.rs"]
mod common;

fn main() {
    common::main_table("llada15-mini", "Table 2 — LLaDA-1.5-mini (paper: LLaDA-1.5)");
}
