//! Paper Table 3: module ablation (Suf. / Dyn. / Exit.) on GSM8K-mini at
//! L=128 (paper: GSM8K @ 512) across the three bidirectional backbones.
//! Saves `BENCH_table3_ablation.json` — the CI bench-smoke artifact.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::run_suite;
use streaming_dllm::util::bench::{save_rows, Cell, Row};

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let n = common::bench_n();
    let gen_len = 128;
    println!("=== Table 3 — ablation on gsm-mini, L={gen_len} (paper: GSM8K L=512) ===");
    if setup.is_reference() {
        // under the causal mode the Acc. column actually responds to the
        // ablated modules; toy mode pins it at 100 and varies NFE only
        println!("[reference mode: {}]", common::ref_mode());
    }
    println!(
        "{:<14}{:<6}{:<6}{:<7}{:>9}{:>13}{:>8}",
        "model", "Suf.", "Dyn.", "Exit.", "Acc.(%)", "Th.(tok/s)", "NFE"
    );
    let toggles = [
        (false, false, false), // ≙ Fast-dLLM baseline row
        (true, false, false),
        (true, true, false),
        (true, true, true),
    ];
    let mut rows: Vec<Row> = vec![];
    for model in ["dream-mini", "llada-mini", "llada15-mini"] {
        let be = setup.model(model);
        let items = setup.suite("gsm-mini");
        let items = &items[..n.min(items.len())];
        let mut cells: Vec<(String, Cell)> = vec![];
        for (suf, dynamic, exit) in toggles {
            let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
            cfg.set_suffix_pruning(suf);
            cfg.set_dynamic_threshold(dynamic);
            cfg.early_exit = exit;
            let res = run_suite(&be, &cfg, items, None).expect("suite");
            println!(
                "{:<14}{:<6}{:<6}{:<7}{:>9.1}{:>13.1}{:>8.1}",
                model,
                tick(suf),
                tick(dynamic),
                tick(exit),
                res.accuracy(),
                res.tokens_per_sec(),
                res.steps as f64 / items.len() as f64
            );
            let label = format!("suf={}/dyn={}/exit={}", tick(suf), tick(dynamic), tick(exit));
            cells.push((label, res.to_cell()));
        }
        rows.push(Row { label: format!("{model} gsm-mini L={gen_len}"), cells });
    }
    save_rows("table3_ablation", &rows);
    println!("(n={n}; row 1 per model = no-module baseline ≙ Fast-dLLM)");
}

fn tick(b: bool) -> &'static str {
    if b {
        "Y"
    } else {
        "x"
    }
}
