//! Paper Table 4: prefill-length sweep — 3/5/8-shot GSM8K-mini on
//! LLaDA-1.5-mini, LLaDA-1.5 vs Fast-dLLM vs Streaming.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::Method;
use streaming_dllm::util::bench::{print_table, save_rows, Cell, Row};

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "llada15-mini";
    let mrt = setup.model(model);
    let n = common::bench_n();
    let gen_len = 128; // paper: 512

    let mut rows = vec![];
    let shot_files =
        [(3, "gsm-mini-3shot.jsonl"), (5, "gsm-mini.jsonl"), (8, "gsm-mini-8shot.jsonl")];
    for (shots, file) in shot_files {
        let items = setup.suite_file(file);
        let items = &items[..n.min(items.len())];
        let mut cells: Vec<(String, Cell)> = vec![];
        for method in [Method::Vanilla, Method::FastDllm, Method::Streaming] {
            let res = common::run_cell(&mrt, method, model, "gsm-mini", gen_len, items);
            cells.push((method.name().to_string(), res.to_cell()));
        }
        rows.push(Row { label: format!("gsm-mini {shots}-shot L={gen_len}"), cells });
    }
    print_table("Table 4 — few-shot prefill sweep (LLaDA-1.5-mini)", &rows);
    save_rows("table4_fewshot", &rows);
    println!("(expected: all methods slow with longer prefill; streaming's margin grows)");
}
