//! Paper Tables 5 & 13: generation-length sweep ({512,1024,2048} ÷4 →
//! {128,256,512}) on GSM8K-mini — vanilla collapses, Streaming stays
//! flat (early exit + pruning), speedup grows superlinearly.
//! `--model llada-mini` reproduces Table 13.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::Method;
use streaming_dllm::util::bench::{print_table, save_rows, Cell, Row};
use streaming_dllm::util::cli::Args;

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let args = Args::parse_env();
    let model = args.get_or("model", "llada15-mini").to_string();
    let mrt = setup.model(&model);
    // long-generation cells are expensive (vanilla pays L full forwards);
    // default to fewer items than the main tables.
    let n = std::env::var("SDLLM_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(6);

    let items = setup.suite("gsm-mini");
    let items = &items[..n.min(items.len())];
    let mut rows = vec![];
    for gen_len in [128usize, 256, 512] {
        let mut cells: Vec<(String, Cell)> = vec![];
        for method in [Method::Vanilla, Method::FastDllm, Method::Streaming] {
            let res = common::run_cell(&mrt, method, &model, "gsm-mini", gen_len, items);
            cells.push((method.name().to_string(), res.to_cell()));
        }
        rows.push(Row { label: format!("gsm-mini L={gen_len}"), cells });
    }
    let title =
        format!("Table 5/13 — generation-length sweep ({model}); paper lengths = 4x these");
    print_table(&title, &rows);
    save_rows(&format!("table5_genlen_{model}"), &rows);
    println!("(n={n}; expected: streaming speedup grows with L — paper: 28x → 225x)");
}
