//! Paper Table 6: impact of the trailing positional token. Dropping it
//! removes the coarse "where does the sequence end" cue (Eq. 7's
//! ∪ {p_L + L} term) and costs accuracy on all three backbones.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::run_suite;

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let n = common::bench_n();
    let gen_len = 128;
    println!("=== Table 6 — trailing positional information (gsm-mini, L={gen_len}) ===");
    println!("{:<16}{:<20}{:>12}{:>14}", "model", "trailing position", "Acc.(%)", "Th.(tok/s)");
    for model in ["dream-mini", "llada-mini", "llada15-mini"] {
        let mrt = setup.model(model);
        let items = setup.suite("gsm-mini");
        let items = &items[..n.min(items.len())];
        for trailing in [false, true] {
            let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
            cfg.set_trailing(trailing);
            let res = run_suite(&mrt, &cfg, items, None).expect("suite");
            println!(
                "{:<16}{:<20}{:>12.1}{:>14.1}",
                model,
                if trailing { "yes" } else { "no" },
                res.accuracy(),
                res.tokens_per_sec()
            );
        }
    }
    println!("(n={n}; paper: omitting the trailing position drops accuracy 1.2–1.9 points)");
}
