//! Paper Table 7 (§4.4): the block-causal extension. On Open-Pangu-like
//! topologies the distant suffix is already absent (spatial pruning
//! degenerates to a topology-aware no-op), but the *temporal* module —
//! dynamic confidence-aware decoding + early exit — still applies.
//! Baseline = fixed-threshold commits, ours = dynamic + exit.
#[path = "common.rs"]
mod common;

use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::run_suite;
use streaming_dllm::util::bench::{print_table, save_rows, Row};

fn main() {
    let Some(setup) = common::Setup::new() else { return };
    let model = "pangu-mini";
    let mrt = setup.model(model);
    let n = common::bench_n();
    let gen_len = 64;

    let mut rows = vec![];
    for (suite, label) in common::SUITES {
        let items = setup.suite(suite);
        let items = &items[..n.min(items.len())];

        // Block-causal topology: the suffix is *absent by construction*,
        // so both arms run block-only query bundles (window = 0, no
        // trailing token — spatial pruning degenerates, paper §4.4).
        // baseline: static threshold, no early exit (Fast-dLLM-style
        // commits adapted to the topology)
        let mut base = GenConfig::preset(Method::Streaming, gen_len);
        base.set_suffix_pruning(true);
        base.set_window(0);
        base.set_trailing(false);
        base.set_dynamic_threshold(false);
        base.early_exit = false;

        // ours: the temporal modules (dynamic threshold + early exit)
        let mut ours = GenConfig::preset(Method::Streaming, gen_len);
        ours.set_window(0);
        ours.set_trailing(false);

        let res_b = run_suite(&mrt, &base, items, None).expect("base");
        let res_o = run_suite(&mrt, &ours, items, None).expect("ours");
        rows.push(Row {
            label: label.to_string(),
            cells: vec![
                ("open-pangu-mini".to_string(), res_b.to_cell()),
                ("ours (temporal)".to_string(), res_o.to_cell()),
            ],
        });
    }
    print_table("Table 7 — block-causal extension (pangu-mini)", &rows);
    save_rows("table7_blockcausal", &rows);
    println!("(n={n}; paper: 1.4–1.6x throughput, accuracy maintained or improved on 5/6 tasks)");
}
