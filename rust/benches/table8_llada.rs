//! Paper Table 8 (+ latency Table 11): LLaDA-Instruct suite.
#[path = "common.rs"]
mod common;

fn main() {
    common::main_table("llada-mini", "Table 8 — LLaDA-mini (paper: LLaDA-8B-Instruct)");
}
