//! Bench-history drift check: compares the fresh
//! `target/bench-results/BENCH_*.json` dumps against the committed
//! baselines in `bench/history/` field by field and prints every
//! numeric drift beyond the tolerance. **Loud but green**: the process
//! always exits 0 — CI uses it to annotate the bench-smoke log, not to
//! gate merges, because reference-backend timings are machine-dependent.
//! Structural changes are reported too (fields or whole files appearing
//! or disappearing), so a bench that silently stops writing a series
//! shows up in the log instead of vanishing from the trajectory.
//!
//! Knobs (env): `SDLLM_BENCH_HISTORY` (baseline dir, default
//! `bench/history`), `SDLLM_BENCH_RESULTS` (fresh dir, default
//! `target/bench-results`), `SDLLM_BENCH_DIFF_TOL` (relative tolerance,
//! default 0.25).
//!
//! Opt-in gating: `--fail-on-drift <pct>` turns the check into a gate —
//! the tolerance becomes `pct/100` and any DRIFT, GONE field, or
//! MISSING fresh result exits 1. The default (no flag) behavior is
//! unchanged: informational, always exit 0. `--only <BENCH_*.json>`
//! restricts the comparison to a single baseline file, so CI can gate
//! one curated baseline while the rest stay informational.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use streaming_dllm::util::json::Json;

/// Flatten every numeric leaf to a dotted path. Array elements that
/// carry a `label` or `method` string use it as the path segment, so
/// reordering rows or cells is not reported as drift.
fn flatten(j: &Json, path: &str, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Num(n) => {
            out.insert(path.to_string(), *n);
        }
        Json::Obj(m) => {
            for (k, v) in m {
                let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                flatten(v, &p, out);
            }
        }
        Json::Arr(items) => {
            for (i, v) in items.iter().enumerate() {
                let seg = v
                    .get("label")
                    .or_else(|| v.get("method"))
                    .and_then(|s| s.as_str())
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| i.to_string());
                let p = if path.is_empty() { seg } else { format!("{path}.{seg}") };
                flatten(v, &p, out);
            }
        }
        _ => {}
    }
}

fn load(path: &Path) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    Json::parse(&text).ok()
}

/// `BENCH_*.json` file names under `dir`, sorted (empty if unreadable).
fn bench_files(dir: &Path) -> Vec<String> {
    let mut names = vec![];
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                names.push(name);
            }
        }
    }
    names.sort();
    names
}

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

/// `--fail-on-drift <pct>` from argv: `Some(pct/100)` when present.
/// A malformed or missing value is a usage error, not a silent pass.
fn fail_on_drift_arg() -> Option<f64> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--fail-on-drift" {
            let pct = args
                .next()
                .and_then(|s| s.parse::<f64>().ok())
                .unwrap_or_else(|| {
                    eprintln!("usage: bench_diff [--only <BENCH_*.json>] [--fail-on-drift <pct>]");
                    std::process::exit(2);
                });
            return Some(pct / 100.0);
        }
    }
    None
}

/// `--only <file>` from argv: restrict the comparison to one baseline
/// file. Lets CI gate a single deliberately-curated baseline (e.g.
/// `BENCH_host_overhead.json`) while the rest of `bench/history` stays
/// informational — gating every machine-dependent timing would flake.
fn only_arg() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--only" {
            return Some(args.next().unwrap_or_else(|| {
                eprintln!("usage: bench_diff [--only <BENCH_*.json>] [--fail-on-drift <pct>]");
                std::process::exit(2);
            }));
        }
    }
    None
}

fn main() {
    let history = PathBuf::from(env_or("SDLLM_BENCH_HISTORY", "bench/history"));
    let results = PathBuf::from(env_or("SDLLM_BENCH_RESULTS", "target/bench-results"));
    let gate = fail_on_drift_arg();
    let only = only_arg();
    let tol = gate.unwrap_or_else(|| {
        std::env::var("SDLLM_BENCH_DIFF_TOL")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(0.25)
    });
    println!("=== bench drift vs {} (tolerance ±{:.0}%) ===", history.display(), tol * 100.0);

    let mut baselines = bench_files(&history);
    if let Some(name) = &only {
        baselines.retain(|n| n == name);
        if baselines.is_empty() {
            println!("[{name}] no such baseline under {}", history.display());
            if gate.is_some() {
                std::process::exit(1);
            }
            return;
        }
    }
    if baselines.is_empty() {
        println!("no baselines under {} — nothing to compare", history.display());
        return;
    }
    let mut checked = 0usize;
    let mut drifts = 0usize;
    for name in &baselines {
        let Some(base) = load(&history.join(name)) else {
            println!("[{name}] unreadable baseline — skipped");
            continue;
        };
        let cur_path = results.join(name);
        let Some(cur) = load(&cur_path) else {
            println!("[{name}] MISSING fresh result at {} (bench not run?)", cur_path.display());
            drifts += 1;
            continue;
        };
        let mut b = BTreeMap::new();
        let mut c = BTreeMap::new();
        flatten(&base, "", &mut b);
        flatten(&cur, "", &mut c);
        let mut file_drifts = 0usize;
        for (key, bv) in &b {
            match c.get(key) {
                None => {
                    println!("[{name}] GONE   {key} (in baseline, absent from fresh result)");
                    file_drifts += 1;
                }
                Some(cv) => {
                    checked += 1;
                    let rel = (*cv - *bv) / bv.abs().max(1e-9);
                    if rel.abs() > tol {
                        println!(
                            "[{name}] DRIFT  {key}: {bv:.3} -> {cv:.3} ({:+.1}%)",
                            rel * 100.0
                        );
                        file_drifts += 1;
                    }
                }
            }
        }
        for key in c.keys() {
            if !b.contains_key(key) {
                println!("[{name}] NEW    {key} (not in baseline — refresh bench/history)");
            }
        }
        if file_drifts == 0 {
            println!("[{name}] ok ({} fields within tolerance)", b.len());
        }
        drifts += file_drifts;
    }
    if only.is_none() {
        for name in bench_files(&results) {
            if !baselines.contains(&name) {
                println!("[{name}] UNTRACKED (fresh result with no committed baseline)");
            }
        }
    }
    match gate {
        Some(_) if drifts > 0 => {
            println!("=== {checked} fields compared, {drifts} drift(s); --fail-on-drift — exit 1 ===");
            std::process::exit(1);
        }
        Some(_) => {
            println!("=== {checked} fields compared, 0 drift(s); --fail-on-drift — exit 0 ===");
        }
        None => {
            println!(
                "=== {checked} fields compared, {drifts} drift(s); informational only — exit 0 ==="
            );
        }
    }
}
