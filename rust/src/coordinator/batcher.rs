//! Dynamic batcher: groups admitted requests into executable-compatible
//! batches. Compatibility = same (method, gen_len) — those determine the
//! decode schedule; prompt lengths may differ (bucketed + masked).
//!
//! Policy: flush a group when it reaches `max_batch`, or when its oldest
//! member has waited `max_wait` (classic vLLM-style continuous admission,
//! simplified to block granularity since dLLM decode is block-wise).
//!
//! Pure logic — no runtime handles — so the property tests can hammer it.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::Method;

use super::request::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub method: Method,
    pub gen_len: usize,
}

#[derive(Debug)]
struct Pending {
    req: Request,
    arrived: Instant,
}

#[derive(Debug)]
pub struct Batcher {
    queues: Vec<(GroupKey, VecDeque<Pending>)>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { queues: vec![], max_batch, max_wait }
    }

    pub fn push(&mut self, req: Request) {
        self.push_at(req, Instant::now())
    }

    pub fn push_at(&mut self, req: Request, now: Instant) {
        let key = GroupKey { method: req.method, gen_len: req.gen_len };
        let q = match self.queues.iter_mut().find(|(k, _)| *k == key) {
            Some((_, q)) => q,
            None => {
                self.queues.push((key, VecDeque::new()));
                &mut self.queues.last_mut().unwrap().1
            }
        };
        q.push_back(Pending { req, arrived: now });
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Pop the next batch to run, if any group is ready. Ready = full
    /// batch available, or oldest member exceeded max_wait (then take
    /// whatever the group has, up to max_batch).
    pub fn pop_ready(&mut self, now: Instant) -> Option<(GroupKey, Vec<Request>)> {
        // full groups first (throughput), then timed-out groups (latency)
        let mut chosen: Option<usize> = None;
        for (i, (_, q)) in self.queues.iter().enumerate() {
            if q.len() >= self.max_batch {
                chosen = Some(i);
                break;
            }
        }
        if chosen.is_none() {
            let mut oldest: Option<(usize, Instant)> = None;
            for (i, (_, q)) in self.queues.iter().enumerate() {
                if let Some(front) = q.front() {
                    if now.duration_since(front.arrived) >= self.max_wait
                        && oldest.map(|(_, t)| front.arrived < t).unwrap_or(true)
                    {
                        oldest = Some((i, front.arrived));
                    }
                }
            }
            chosen = oldest.map(|(i, _)| i);
        }
        let i = chosen?;
        let (key, q) = &mut self.queues[i];
        let key = *key;
        let n = q.len().min(self.max_batch);
        let batch: Vec<Request> = q.drain(..n).map(|p| p.req).collect();
        if q.is_empty() {
            self.queues.remove(i);
        }
        Some((key, batch))
    }

    /// Time until the next queue would time out (router uses this as its
    /// poll timeout). None when idle.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|(_, q)| q.front())
            .map(|p| {
                let waited = now.duration_since(p.arrived);
                self.max_wait.saturating_sub(waited)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(id: u64, method: Method, gen_len: usize) -> Request {
        Request { id, prompt: vec![2], method, gen_len }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        assert!(b.pop_ready(t).is_none());
        b.push_at(req(2, Method::Streaming, 64), t);
        let (key, batch) = b.pop_ready(t).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(key.gen_len, 64);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn incompatible_requests_never_mix() {
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Vanilla, 64), t);
        b.push_at(req(3, Method::Streaming, 128), t);
        assert!(b.pop_ready(t).is_none()); // three singleton groups
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        assert!(b.pop_ready(t).is_none());
        let later = t + Duration::from_millis(11);
        let (_, batch) = b.pop_ready(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn oldest_group_flushes_first() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t = Instant::now();
        b.push_at(req(1, Method::Vanilla, 64), t);
        b.push_at(req(2, Method::Streaming, 64), t + Duration::from_millis(2));
        let later = t + Duration::from_millis(20);
        let (key, _) = b.pop_ready(later).unwrap();
        assert_eq!(key.method, Method::Vanilla);
    }

    #[test]
    fn deadline_reflects_oldest() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        let t = Instant::now();
        assert!(b.next_deadline(t).is_none());
        b.push_at(req(1, Method::Streaming, 64), t);
        let d = b.next_deadline(t + Duration::from_millis(30)).unwrap();
        assert!(d <= Duration::from_millis(70));
    }

    #[test]
    fn prop_batches_homogeneous_and_complete() {
        prop::check(200, |g| {
            let max_batch = g.usize(1, 8);
            let n = g.usize(0, 40);
            let mut b = Batcher::new(max_batch, Duration::from_millis(0));
            let t = Instant::now();
            let methods = Method::all();
            let mut pushed = 0usize;
            for i in 0..n {
                let m = methods[g.usize(0, 4)];
                let len = [64, 128][g.usize(0, 1)];
                b.push_at(req(i as u64, m, len), t);
                pushed += 1;
            }
            let mut popped = 0usize;
            while let Some((key, batch)) = b.pop_ready(t + Duration::from_millis(1)) {
                if batch.is_empty() || batch.len() > max_batch {
                    return Err(format!("bad batch size {}", batch.len()));
                }
                if !batch.iter().all(|r| r.method == key.method && r.gen_len == key.gen_len) {
                    return Err("mixed batch".into());
                }
                popped += batch.len();
            }
            if popped != pushed {
                return Err(format!("lost requests: {popped} != {pushed}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fifo_within_group() {
        prop::check(100, |g| {
            let n = g.usize(1, 20);
            let mut b = Batcher::new(4, Duration::from_millis(0));
            let t = Instant::now();
            for i in 0..n {
                b.push_at(req(i as u64, Method::Streaming, 64), t);
            }
            let mut last = None;
            while let Some((_, batch)) = b.pop_ready(t) {
                for r in batch {
                    if let Some(prev) = last {
                        if r.id <= prev {
                            return Err("out of order".into());
                        }
                    }
                    last = Some(r.id);
                }
            }
            Ok(())
        });
    }
}
