//! Dynamic batcher: groups admitted requests into executable-compatible
//! batches. Compatibility = same (method, gen_len) — those determine the
//! decode schedule; prompt lengths may differ (bucketed + masked).
//!
//! Policy: flush a group when it reaches `max_batch`, or when its oldest
//! member has waited `max_wait` (classic vLLM-style continuous admission,
//! simplified to block granularity since dLLM decode is block-wise).
//!
//! Pure logic — no runtime handles — so the property tests can hammer it.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::Method;

use super::request::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub method: Method,
    pub gen_len: usize,
}

#[derive(Debug)]
struct Pending {
    req: Request,
    arrived: Instant,
}

#[derive(Debug)]
pub struct Batcher {
    queues: Vec<(GroupKey, VecDeque<Pending>)>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { queues: vec![], max_batch, max_wait }
    }

    pub fn push(&mut self, req: Request) {
        self.push_at(req, Instant::now())
    }

    pub fn push_at(&mut self, req: Request, now: Instant) {
        let key = GroupKey { method: req.method, gen_len: req.gen_len };
        let q = match self.queues.iter_mut().find(|(k, _)| *k == key) {
            Some((_, q)) => q,
            None => {
                self.queues.push((key, VecDeque::new()));
                &mut self.queues.last_mut().unwrap().1
            }
        };
        q.push_back(Pending { req, arrived: now });
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Whether group `q` is ready to run at `now`: a full batch is
    /// available, or its oldest member exceeded max_wait.
    fn is_ready(&self, q: &VecDeque<Pending>, now: Instant) -> bool {
        q.len() >= self.max_batch
            || q.front()
                .map(|p| now.duration_since(p.arrived) >= self.max_wait)
                .unwrap_or(false)
    }

    /// Whether any group is ready to run right now (the router uses
    /// this to avoid sleeping while work is already runnable).
    pub fn has_ready(&self, now: Instant) -> bool {
        self.queues.iter().any(|(_, q)| self.is_ready(q, now))
    }

    /// Pop the next batch to run, if any group is ready. Ready = full
    /// batch available (immediately), or oldest member exceeded
    /// max_wait (then take whatever the group has, up to max_batch).
    ///
    /// Fairness: among ready groups, the one whose *front request*
    /// arrived earliest wins. Full groups don't jump ahead of an older
    /// timed-out group — that is what bounds cross-group starvation: a
    /// waiting group's front only gets older, so it eventually beats
    /// any hot group whose front is constantly refreshed by admission.
    pub fn pop_ready(&mut self, now: Instant) -> Option<(GroupKey, Vec<Request>)> {
        let mut oldest: Option<(usize, Instant)> = None;
        for (i, (_, q)) in self.queues.iter().enumerate() {
            if !self.is_ready(q, now) {
                continue;
            }
            let front = q.front().expect("ready queue has a front").arrived;
            if oldest.map(|(_, t)| front < t).unwrap_or(true) {
                oldest = Some((i, front));
            }
        }
        let i = oldest.map(|(i, _)| i)?;
        let (key, q) = &mut self.queues[i];
        let key = *key;
        let n = q.len().min(self.max_batch);
        let batch: Vec<Request> = q.drain(..n).map(|p| p.req).collect();
        if q.is_empty() {
            self.queues.remove(i);
        }
        Some((key, batch))
    }

    /// Pop the single oldest waiting request of exactly this group —
    /// the router uses this to fill freed engine slots mid-flight
    /// (joining a running batch is always better than waiting, so
    /// readiness rules don't apply).
    pub fn pop_compatible(&mut self, key: GroupKey) -> Option<Request> {
        let i = self.queues.iter().position(|(k, _)| *k == key)?;
        let req = self.queues[i].1.pop_front().map(|p| p.req);
        if self.queues[i].1.is_empty() {
            self.queues.remove(i);
        }
        req
    }

    /// Whether any *other* group's front request has outlived
    /// `max_wait`. The router stops admitting mid-flight joins into a
    /// running batch when this turns true, letting the engine drain so
    /// the starving group can be scheduled — a steady stream of
    /// compatible requests must not keep one engine alive forever.
    pub fn starving_other(&self, key: GroupKey, now: Instant) -> bool {
        self.queues.iter().any(|(k, q)| {
            *k != key
                && q.front()
                    .map(|p| now.duration_since(p.arrived) >= self.max_wait)
                    .unwrap_or(false)
        })
    }

    /// Time until the next queue would time out (router uses this as its
    /// poll timeout). None when idle.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|(_, q)| q.front())
            .map(|p| {
                let waited = now.duration_since(p.arrived);
                self.max_wait.saturating_sub(waited)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(id: u64, method: Method, gen_len: usize) -> Request {
        Request { id, prompt: vec![2], method, gen_len }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        assert!(b.pop_ready(t).is_none());
        b.push_at(req(2, Method::Streaming, 64), t);
        let (key, batch) = b.pop_ready(t).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(key.gen_len, 64);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn incompatible_requests_never_mix() {
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Vanilla, 64), t);
        b.push_at(req(3, Method::Streaming, 128), t);
        assert!(b.pop_ready(t).is_none()); // three singleton groups
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        assert!(b.pop_ready(t).is_none());
        let later = t + Duration::from_millis(11);
        let (_, batch) = b.pop_ready(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn full_group_with_oldest_front_wins() {
        // regression: two full groups; the one queued *second* has the
        // older front request and must flush first (previously the
        // insertion-ordered scan always picked the first full group)
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t + Duration::from_millis(5));
        b.push_at(req(2, Method::Vanilla, 64), t); // older front, later queue
        b.push_at(req(3, Method::Streaming, 64), t + Duration::from_millis(6));
        b.push_at(req(4, Method::Vanilla, 64), t + Duration::from_millis(7));
        let (key, batch) = b.pop_ready(t + Duration::from_millis(8)).unwrap();
        assert_eq!(key.method, Method::Vanilla, "oldest full group must flush first");
        assert_eq!(batch[0].id, 2);
        let (key2, _) = b.pop_ready(t + Duration::from_millis(8)).unwrap();
        assert_eq!(key2.method, Method::Streaming);
    }

    #[test]
    fn pop_compatible_takes_only_matching_group() {
        let mut b = Batcher::new(8, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Vanilla, 64), t);
        b.push_at(req(3, Method::Streaming, 64), t);
        let key = GroupKey { method: Method::Streaming, gen_len: 64 };
        assert_eq!(b.pop_compatible(key).unwrap().id, 1);
        assert_eq!(b.pop_compatible(key).unwrap().id, 3);
        assert!(b.pop_compatible(key).is_none());
        assert_eq!(b.pending(), 1); // the vanilla request stays queued
        assert!(b
            .pop_compatible(GroupKey { method: Method::Streaming, gen_len: 128 })
            .is_none());
    }

    #[test]
    fn starving_other_ignores_own_group_and_fresh_waiters() {
        let mut b = Batcher::new(4, Duration::from_millis(10));
        let t = Instant::now();
        let streaming = GroupKey { method: Method::Streaming, gen_len: 64 };
        b.push_at(req(1, Method::Streaming, 64), t);
        // own group aging never counts as starvation
        assert!(!b.starving_other(streaming, t + Duration::from_millis(50)));
        b.push_at(req(2, Method::Vanilla, 64), t + Duration::from_millis(5));
        // the vanilla waiter is fresh …
        assert!(!b.starving_other(streaming, t + Duration::from_millis(10)));
        // … and starving once it outlives max_wait
        assert!(b.starving_other(streaming, t + Duration::from_millis(20)));
        // from vanilla's perspective the aged streaming front starves too
        let vanilla = GroupKey { method: Method::Vanilla, gen_len: 64 };
        assert!(b.starving_other(vanilla, t + Duration::from_millis(20)));
    }

    #[test]
    fn oldest_group_flushes_first() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t = Instant::now();
        b.push_at(req(1, Method::Vanilla, 64), t);
        b.push_at(req(2, Method::Streaming, 64), t + Duration::from_millis(2));
        let later = t + Duration::from_millis(20);
        let (key, _) = b.pop_ready(later).unwrap();
        assert_eq!(key.method, Method::Vanilla);
    }

    #[test]
    fn deadline_reflects_oldest() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        let t = Instant::now();
        assert!(b.next_deadline(t).is_none());
        b.push_at(req(1, Method::Streaming, 64), t);
        let d = b.next_deadline(t + Duration::from_millis(30)).unwrap();
        assert!(d <= Duration::from_millis(70));
    }

    #[test]
    fn prop_batches_homogeneous_and_complete() {
        prop::check(200, |g| {
            let max_batch = g.usize(1, 8);
            let n = g.usize(0, 40);
            let mut b = Batcher::new(max_batch, Duration::from_millis(0));
            let t = Instant::now();
            let methods = Method::all();
            let mut pushed = 0usize;
            for i in 0..n {
                let m = methods[g.usize(0, 4)];
                let len = [64, 128][g.usize(0, 1)];
                b.push_at(req(i as u64, m, len), t);
                pushed += 1;
            }
            let mut popped = 0usize;
            while let Some((key, batch)) = b.pop_ready(t + Duration::from_millis(1)) {
                if batch.is_empty() || batch.len() > max_batch {
                    return Err(format!("bad batch size {}", batch.len()));
                }
                if !batch.iter().all(|r| r.method == key.method && r.gen_len == key.gen_len) {
                    return Err("mixed batch".into());
                }
                popped += batch.len();
            }
            if popped != pushed {
                return Err(format!("lost requests: {popped} != {pushed}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fifo_within_group() {
        prop::check(100, |g| {
            let n = g.usize(1, 20);
            let mut b = Batcher::new(4, Duration::from_millis(0));
            let t = Instant::now();
            for i in 0..n {
                b.push_at(req(i as u64, Method::Streaming, 64), t);
            }
            let mut last = None;
            while let Some((_, batch)) = b.pop_ready(t) {
                for r in batch {
                    if let Some(prev) = last {
                        if r.id <= prev {
                            return Err("out of order".into());
                        }
                    }
                    last = Some(r.id);
                }
            }
            Ok(())
        });
    }
}
