//! Dynamic batcher: groups admitted requests into executable-compatible
//! batches. Compatibility = same [`GroupKey`], i.e. same (method,
//! resolved decode policy) — the pair determines the decode *schedule
//! shape*, so rows with different policies never share an engine round;
//! gen lengths and prompt lengths may both differ per row (each row
//! carries its own block budget in the engine, buffers are bucketed to
//! the max in-flight length).
//!
//! Queues are kept ordered by **effective deadline**: every request is
//! assigned `arrived + deadline_ms` (or `arrived + default_sla` when
//! the client sets none), and slot claiming always takes the earliest
//! deadline first. Because effective deadlines are finite and anchored
//! to arrival, an aged request eventually out-ranks any stream of
//! fresher arrivals — the anti-starvation property the old
//! arrival-FIFO order had, preserved under SLA ordering. With no
//! deadlines set, the order degenerates to exactly the old FIFO.
//!
//! Flush policy: a group runs when it reaches `max_batch`, when its
//! oldest member has waited `max_wait` (classic vLLM-style continuous
//! admission, simplified to block granularity since dLLM decode is
//! block-wise), or when a member with an *explicit* deadline is within
//! one flush window of missing it — an urgent request on an idle
//! server must not burn its whole SLA budget waiting out `max_wait`.
//!
//! Pure logic — no runtime handles — so the property tests can hammer it.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::Method;

use super::request::{GroupKey, Request};

/// Fallback SLA assigned to requests that carry no `deadline_ms`: late
/// enough that explicit deadlines win while fresh, finite so an aged
/// request cannot be starved by an endless stream of urgent arrivals.
pub const DEFAULT_SLA: Duration = Duration::from_secs(30);

/// Explicit deadlines are clamped to this cap (24 h): a bogus
/// client-supplied `deadline_ms` must not overflow `Instant +
/// Duration` (which panics on platforms where `Instant` is a u64 tick
/// count) or distort the queue order.
pub const MAX_DEADLINE_MS: u64 = 24 * 60 * 60 * 1000;

/// Shortest shared prompt prefix that counts as intra-batch dedup: two
/// rows in one flush sharing at least this many leading tokens decode
/// their template from one shared prefill via the prefix cache.
pub const DEDUP_MIN_PREFIX: usize = 8;

/// How many rows of `batch` (beyond the first sharer) ride a prompt
/// prefix of ≥ `min_len` tokens that some earlier row in the same batch
/// also carries — the router's intra-batch dedup gauge. Pure accounting
/// over the flushed batch: with the prefix cache on, the first such row
/// computes and publishes the shared prefix and the rest hit it within
/// the same engine lifetime.
pub fn shared_prefix_rows(batch: &[Request], min_len: usize) -> usize {
    let mut dedup = 0usize;
    for (i, r) in batch.iter().enumerate() {
        if r.prompt.len() < min_len {
            continue;
        }
        let shared = batch[..i].iter().any(|prev| {
            prev.prompt.len() >= min_len && prev.prompt[..min_len] == r.prompt[..min_len]
        });
        if shared {
            dedup += 1;
        }
    }
    dedup
}

#[derive(Debug)]
struct Pending {
    req: Request,
    arrived: Instant,
    /// effective deadline: `arrived + deadline_ms.unwrap_or(default_sla)`
    deadline: Instant,
}

impl Pending {
    /// Queue order: earliest deadline first, ties broken by arrival.
    fn urgency(&self) -> (Instant, Instant) {
        (self.deadline, self.arrived)
    }
}

#[derive(Debug)]
pub struct Batcher {
    queues: Vec<(GroupKey, VecDeque<Pending>)>,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub default_sla: Duration,
    /// Admission bound per group queue. The router checks
    /// [`Batcher::is_full`] *before* pushing and answers a reject
    /// instead; internal requeues (worker overflow bounces) bypass the
    /// cap so in-flight work is never dropped by backpressure.
    pub max_depth: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher {
            queues: vec![],
            max_batch,
            max_wait,
            default_sla: DEFAULT_SLA,
            max_depth: usize::MAX,
        }
    }

    pub fn push(&mut self, req: Request) {
        self.push_at(req, Instant::now())
    }

    /// The effective deadline a request is scheduled (and its
    /// `deadline_misses` judged) by: `arrived + deadline_ms` (clamped
    /// to [`MAX_DEADLINE_MS`]), or `arrived + default_sla` when the
    /// client set none. Single source of truth — the router stamps
    /// reply slots through this too, so queue order and the miss
    /// metric can't drift apart.
    pub fn effective_deadline(&self, req: &Request, arrived: Instant) -> Instant {
        let sla = req
            .deadline_ms
            .map(|d| Duration::from_millis(d.min(MAX_DEADLINE_MS)))
            .unwrap_or(self.default_sla);
        arrived + sla
    }

    pub fn push_at(&mut self, req: Request, now: Instant) {
        let deadline = self.effective_deadline(&req, now);
        let p = Pending { req, arrived: now, deadline };
        let key = p.req.group_key();
        let q = match self.queues.iter_mut().find(|(k, _)| *k == key) {
            Some((_, q)) => q,
            None => {
                self.queues.push((key, VecDeque::new()));
                &mut self.queues.last_mut().unwrap().1
            }
        };
        // sorted insert, stable for equal urgency (new goes after ties)
        let at = q.partition_point(|e| e.urgency() <= p.urgency());
        q.insert(at, p);
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Queued depth of one (method, policy) group.
    pub fn depth(&self, key: GroupKey) -> usize {
        self.queues.iter().find(|(k, _)| *k == key).map(|(_, q)| q.len()).unwrap_or(0)
    }

    /// Queued depth across every policy group of one method (the
    /// router's per-method gauge keeps its legacy meaning).
    pub fn method_depth(&self, method: Method) -> usize {
        self.queues.iter().filter(|(k, _)| k.method == method).map(|(_, q)| q.len()).sum()
    }

    /// Whether the group's queue is at the admission bound — the
    /// router's backpressure predicate, checked before every external
    /// push.
    pub fn is_full(&self, key: GroupKey) -> bool {
        self.depth(key) >= self.max_depth
    }

    /// Remove one queued request by id (cancelled subscriber whose row
    /// never reached a worker). Returns it so the router can account
    /// for the removal.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        for i in 0..self.queues.len() {
            let q = &mut self.queues[i].1;
            if let Some(at) = q.iter().position(|p| p.req.id == id) {
                let req = q.remove(at).map(|p| p.req);
                if q.is_empty() {
                    self.queues.remove(i);
                }
                return req;
            }
        }
        None
    }

    /// Deadline-aware shedding: drain queued `park_on_miss` requests
    /// whose effective deadline has already passed — running them could
    /// only produce an instantly-evicted empty park, so they are
    /// answered as shed without ever costing an engine slot. Requests
    /// without the opt-in decode normally and count a miss, exactly as
    /// before.
    pub fn drain_blown(&mut self, now: Instant) -> Vec<Request> {
        let mut shed = Vec::new();
        for (_, q) in self.queues.iter_mut() {
            let mut keep = VecDeque::with_capacity(q.len());
            for p in q.drain(..) {
                if p.req.park_on_miss && now > p.deadline {
                    shed.push(p.req);
                } else {
                    keep.push_back(p);
                }
            }
            *q = keep;
        }
        self.queues.retain(|(_, q)| !q.is_empty());
        shed
    }

    /// Oldest arrival in a queue — readiness and starvation age are
    /// arrival-based even though the queue is deadline-ordered.
    fn oldest_arrival(q: &VecDeque<Pending>) -> Option<Instant> {
        q.iter().map(|p| p.arrived).min()
    }

    /// Whether group `q` is ready to run at `now`: a full batch is
    /// available, its oldest member exceeded max_wait, or a member with
    /// an *explicit* deadline is within one flush window of missing it
    /// (waiting out max_wait on an idle server would burn the whole SLA
    /// budget before decode even starts). Default-SLA members never
    /// pull the flush forward — without explicit deadlines the policy
    /// is exactly the classic full-or-aged rule.
    fn is_ready(&self, q: &VecDeque<Pending>, now: Instant) -> bool {
        if q.len() >= self.max_batch {
            return true;
        }
        let aged = Self::oldest_arrival(q)
            .map(|a| now.duration_since(a) >= self.max_wait)
            .unwrap_or(false);
        let urgent = q.iter().any(|p| {
            p.req.deadline_ms.is_some()
                && p.deadline.saturating_duration_since(now) <= self.max_wait
        });
        aged || urgent
    }

    /// Whether any group without a running engine is ready right now
    /// (the router uses this to avoid sleeping while work is already
    /// runnable).
    pub fn has_ready(&self, now: Instant) -> bool {
        self.queues.iter().any(|(_, q)| self.is_ready(q, now))
    }

    /// Pop the next batch to run, if any group not in `busy` is ready.
    /// Ready = full batch available (immediately), or oldest member
    /// exceeded max_wait (then take whatever the group has, up to
    /// max_batch). `busy` lists group keys that already have a running
    /// engine — their waiters join that engine through
    /// [`Batcher::pop_compatible`] instead of starting a second one.
    ///
    /// Among ready groups the earliest front deadline wins (ties by
    /// arrival). The router calls this in a loop until `None`, so every
    /// ready group gets its own engine in the same scheduling pass —
    /// cross-group blocking is structural, not ordering-dependent.
    /// Within the popped batch, requests come out oldest-deadline
    /// first.
    pub fn pop_ready(
        &mut self,
        now: Instant,
        busy: &[GroupKey],
    ) -> Option<(GroupKey, Vec<Request>)> {
        let mut best: Option<(usize, (Instant, Instant))> = None;
        for (i, (k, q)) in self.queues.iter().enumerate() {
            if busy.contains(k) || !self.is_ready(q, now) {
                continue;
            }
            let front = q.front().expect("ready queue has a front").urgency();
            if best.map(|(_, u)| front < u).unwrap_or(true) {
                best = Some((i, front));
            }
        }
        let i = best.map(|(i, _)| i)?;
        let (key, q) = &mut self.queues[i];
        let key = *key;
        let n = q.len().min(self.max_batch);
        let batch: Vec<Request> = q.drain(..n).map(|p| p.req).collect();
        if q.is_empty() {
            self.queues.remove(i);
        }
        Some((key, batch))
    }

    /// Pop the most urgent waiting request of exactly this group — the
    /// router uses this to fill freed engine slots mid-flight (joining
    /// a running batch is always better than waiting, so readiness
    /// rules don't apply; deadline order does).
    pub fn pop_compatible(&mut self, key: GroupKey) -> Option<Request> {
        let i = self.queues.iter().position(|(k, _)| *k == key)?;
        let req = self.queues[i].1.pop_front().map(|p| p.req);
        if self.queues[i].1.is_empty() {
            self.queues.remove(i);
        }
        req
    }

    /// Time until the next queue becomes ready by aging out max_wait or
    /// by an explicit deadline entering the pull-forward window (router
    /// uses this as its poll timeout). None when idle.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|(_, q)| {
                let oldest = Self::oldest_arrival(q)?;
                let aged_in = self.max_wait.saturating_sub(now.duration_since(oldest));
                let urgent_in = q
                    .iter()
                    .filter(|p| p.req.deadline_ms.is_some())
                    .map(|p| {
                        p.deadline.saturating_duration_since(now).saturating_sub(self.max_wait)
                    })
                    .min();
                Some(urgent_in.map(|u| u.min(aged_in)).unwrap_or(aged_in))
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DecodePolicy;
    use crate::util::prop;

    fn req(id: u64, method: Method, gen_len: usize) -> Request {
        Request {
            id,
            prompt: vec![2],
            method,
            policy: None,
            gen_len,
            deadline_ms: None,
            park_on_miss: false,
        }
    }

    fn req_sla(id: u64, method: Method, deadline_ms: u64) -> Request {
        Request {
            id,
            prompt: vec![2],
            method,
            policy: None,
            gen_len: 64,
            deadline_ms: Some(deadline_ms),
            park_on_miss: false,
        }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        assert!(b.pop_ready(t, &[]).is_none());
        b.push_at(req(2, Method::Streaming, 64), t);
        let (key, batch) = b.pop_ready(t, &[]).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(key, GroupKey::from(Method::Streaming));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn mixed_gen_lens_share_a_method_group() {
        // gen_len no longer splits groups: a 64 and a 128 streaming
        // request flush together; only the method divides queues
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Streaming, 128), t);
        let (key, batch) = b.pop_ready(t, &[]).unwrap();
        assert_eq!(key.method, Method::Streaming);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].gen_len, 64);
        assert_eq!(batch[1].gen_len, 128);
    }

    #[test]
    fn different_methods_never_mix() {
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Vanilla, 64), t);
        assert!(b.pop_ready(t, &[]).is_none()); // two singleton groups
        assert_eq!(b.pending(), 2);
        assert_eq!(b.depth(Method::Streaming.into()), 1);
        assert_eq!(b.depth(Method::Vanilla.into()), 1);
        assert_eq!(b.depth(Method::FastDllm.into()), 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        assert!(b.pop_ready(t, &[]).is_none());
        let later = t + Duration::from_millis(11);
        let (_, batch) = b.pop_ready(later, &[]).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn busy_methods_are_skipped() {
        let mut b = Batcher::new(1, Duration::from_millis(0));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Vanilla, 64), t);
        let later = t + Duration::from_millis(1);
        // streaming has a running engine: only vanilla may start one
        let busy = [GroupKey::from(Method::Streaming)];
        let (k, _) = b.pop_ready(later, &busy).unwrap();
        assert_eq!(k, GroupKey::from(Method::Vanilla));
        assert!(b.pop_ready(later, &busy).is_none());
        // the streaming waiter is still there for mid-flight joining
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 1);
    }

    #[test]
    fn earlier_deadline_jumps_the_queue() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t); // default SLA (30s)
        b.push_at(req_sla(2, Method::Streaming, 50), t + Duration::from_millis(1));
        b.push_at(req_sla(3, Method::Streaming, 10), t + Duration::from_millis(2));
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 3);
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 2);
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 1);
        assert!(b.pop_compatible(Method::Streaming.into()).is_none());
    }

    #[test]
    fn aged_request_eventually_outranks_urgent_arrivals() {
        // anti-starvation: an old default-SLA request's effective
        // deadline is fixed; later tight-deadline arrivals anchored far
        // enough in the future rank behind it
        let mut b = Batcher::new(8, Duration::from_millis(0));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t); // deadline t+30s
        let late = t + DEFAULT_SLA; // 30s later
        b.push_at(req_sla(2, Method::Streaming, 100), late); // deadline t+30.1s
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 1);
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 2);
    }

    #[test]
    fn ready_group_with_most_urgent_front_wins() {
        // two full groups; the one whose front deadline is earliest
        // flushes first regardless of queue insertion order
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req_sla(2, Method::Vanilla, 5), t + Duration::from_millis(1));
        b.push_at(req(3, Method::Streaming, 64), t + Duration::from_millis(2));
        b.push_at(req(4, Method::Vanilla, 64), t + Duration::from_millis(3));
        let (k1, batch) = b.pop_ready(t + Duration::from_millis(4), &[]).unwrap();
        assert_eq!(k1.method, Method::Vanilla, "urgent-front group must flush first");
        assert_eq!(batch[0].id, 2);
        let (k2, _) = b.pop_ready(t + Duration::from_millis(4), &[]).unwrap();
        assert_eq!(k2.method, Method::Streaming);
    }

    #[test]
    fn pop_compatible_takes_only_matching_method() {
        let mut b = Batcher::new(8, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Vanilla, 64), t);
        b.push_at(req(3, Method::Streaming, 128), t + Duration::from_millis(1));
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 1);
        // mixed gen_len joins the same method group
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 3);
        assert!(b.pop_compatible(Method::Streaming.into()).is_none());
        assert_eq!(b.pending(), 1); // the vanilla request stays queued
    }

    #[test]
    fn oldest_group_flushes_first_on_timeout() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t = Instant::now();
        b.push_at(req(1, Method::Vanilla, 64), t);
        b.push_at(req(2, Method::Streaming, 64), t + Duration::from_millis(2));
        let later = t + Duration::from_millis(20);
        // equal default SLAs: vanilla's front deadline (t+30s) is
        // earlier than streaming's (t+2ms+30s)
        let (k, _) = b.pop_ready(later, &[]).unwrap();
        assert_eq!(k.method, Method::Vanilla);
    }

    #[test]
    fn absurd_deadline_is_clamped_not_panicking() {
        // u64::MAX ms would overflow Instant + Duration on some
        // platforms; the clamp caps it at 24h, which also keeps it
        // ranked behind a fresh default-SLA request
        let mut b = Batcher::new(8, Duration::from_millis(0));
        let t = Instant::now();
        b.push_at(req_sla(1, Method::Streaming, u64::MAX), t);
        b.push_at(req(2, Method::Streaming, 64), t + Duration::from_millis(1));
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 2);
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 1);
    }

    #[test]
    fn explicit_deadline_pulls_flush_forward() {
        let mut b = Batcher::new(8, Duration::from_millis(500));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        // a lone default-SLA waiter follows the classic aged rule
        assert!(!b.has_ready(t + Duration::from_millis(10)));
        // a 50ms-deadline arrival sits inside the 500ms flush window,
        // so the partial group flushes immediately (urgent first)
        b.push_at(req_sla(2, Method::Streaming, 50), t + Duration::from_millis(10));
        let now = t + Duration::from_millis(11);
        assert!(b.has_ready(now));
        let (_, batch) = b.pop_ready(now, &[]).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 2);

        // the poll timeout anticipates the pull-forward point:
        // deadline 600ms − window 500ms = ready in ≤100ms
        let mut b2 = Batcher::new(8, Duration::from_millis(500));
        b2.push_at(req_sla(3, Method::Vanilla, 600), t);
        assert!(!b2.has_ready(t + Duration::from_millis(50)));
        let d = b2.next_deadline(t).unwrap();
        assert!(d <= Duration::from_millis(100));
        assert!(b2.has_ready(t + Duration::from_millis(150)));
    }

    #[test]
    fn deadline_reflects_oldest_arrival() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        let t = Instant::now();
        assert!(b.next_deadline(t).is_none());
        // a tight-deadline later arrival sorts first, but the flush
        // timer still keys off the oldest *arrival*
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req_sla(2, Method::Streaming, 1), t + Duration::from_millis(20));
        let d = b.next_deadline(t + Duration::from_millis(30)).unwrap();
        assert!(d <= Duration::from_millis(70));
    }

    #[test]
    fn bounded_depth_reports_full_per_method() {
        let mut b = Batcher::new(4, Duration::from_secs(60));
        b.max_depth = 2;
        let t = Instant::now();
        assert!(!b.is_full(Method::Streaming.into()));
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Streaming, 64), t);
        assert!(b.is_full(Method::Streaming.into()));
        // bounds are per method queue, not global
        assert!(!b.is_full(Method::Vanilla.into()));
        b.pop_compatible(Method::Streaming.into());
        assert!(!b.is_full(Method::Streaming.into()));
    }

    #[test]
    fn remove_pulls_one_queued_request() {
        let mut b = Batcher::new(4, Duration::from_secs(60));
        let t = Instant::now();
        b.push_at(req(1, Method::Streaming, 64), t);
        b.push_at(req(2, Method::Streaming, 64), t);
        assert_eq!(b.remove(1).unwrap().id, 1);
        assert!(b.remove(1).is_none());
        assert_eq!(b.pending(), 1);
        assert_eq!(b.remove(2).unwrap().id, 2);
        assert_eq!(b.pending(), 0);
        assert!(b.remove(3).is_none());
    }

    #[test]
    fn drain_blown_sheds_only_parkable_expired_rows() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        let t = Instant::now();
        // expired + park_on_miss → shed
        let mut a = req_sla(1, Method::Streaming, 10);
        a.park_on_miss = true;
        b.push_at(a, t);
        // expired but no opt-in → stays queued (decodes late, counts a miss)
        b.push_at(req_sla(2, Method::Streaming, 10), t);
        // park_on_miss but still within budget → stays queued
        let mut c = req_sla(3, Method::Vanilla, 60_000);
        c.park_on_miss = true;
        b.push_at(c, t);
        let shed = b.drain_blown(t + Duration::from_millis(20));
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 1);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.depth(Method::Streaming.into()), 1);
        assert_eq!(b.depth(Method::Vanilla.into()), 1);
        // nothing newly blown → no-op
        assert!(b.drain_blown(t + Duration::from_millis(21)).is_empty());
    }

    #[test]
    fn mixed_policies_never_share_a_batch() {
        // satellite regression: same method, different decode policies →
        // distinct groups that never flush together; identical policies
        // still batch
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        let att = DecodePolicy::parse("attenuating").unwrap();
        let mut r1 = req(1, Method::Streaming, 64);
        r1.policy = Some(att);
        let mut r2 = req(2, Method::Streaming, 64);
        r2.policy = Some(att);
        b.push_at(req(3, Method::Streaming, 64), t);
        b.push_at(r1, t + Duration::from_millis(1));
        b.push_at(r2, t + Duration::from_millis(2));
        let (key, batch) = b.pop_ready(t + Duration::from_millis(3), &[]).unwrap();
        assert_eq!(key.method, Method::Streaming);
        assert_eq!(key.policy, att);
        assert_eq!(batch.len(), 2, "identical-policy requests must batch");
        // the default-policy request sits alone in its own group
        assert!(b.pop_ready(t + Duration::from_millis(3), &[]).is_none());
        assert_eq!(b.depth(Method::Streaming.into()), 1);
        assert_eq!(b.method_depth(Method::Streaming), 1);
        assert_eq!(b.pop_compatible(Method::Streaming.into()).unwrap().id, 3);
    }

    #[test]
    fn explicit_preset_policy_batches_with_default() {
        // naming the method's own preset resolves to the same group key
        // as leaving the policy unset — the two must batch together
        let mut b = Batcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        let mut named = req(1, Method::Streaming, 64);
        named.policy = DecodePolicy::parse("streaming");
        b.push_at(named, t);
        b.push_at(req(2, Method::Streaming, 64), t);
        let (key, batch) = b.pop_ready(t, &[]).unwrap();
        assert_eq!(key, GroupKey::from(Method::Streaming));
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn shared_prefix_rows_counts_sharers_beyond_the_first() {
        let template: Vec<i32> = (0..10).map(|i| 100 + i).collect();
        let mk = |id: u64, tail: i32| {
            let mut r = req(id, Method::Streaming, 64);
            r.prompt = template.clone();
            r.prompt.push(tail);
            r
        };
        // 4 same-template rows: the first computes, 3 dedup against it
        let batch = vec![mk(1, 1), mk(2, 2), mk(3, 3), mk(4, 4)];
        assert_eq!(shared_prefix_rows(&batch, DEDUP_MIN_PREFIX), 3);
        // distinct prefixes: no dedup
        let mut odd = req(9, Method::Streaming, 64);
        odd.prompt = (0..12).map(|i| 900 + i).collect();
        let batch2 = vec![mk(1, 1), odd.clone()];
        assert_eq!(shared_prefix_rows(&batch2, DEDUP_MIN_PREFIX), 0);
        // prompts shorter than the floor never count
        let shorty = req(5, Method::Streaming, 64); // 1-token prompt
        let batch3 = vec![shorty.clone(), shorty];
        assert_eq!(shared_prefix_rows(&batch3, DEDUP_MIN_PREFIX), 0);
        // two groups of sharers in one batch count independently
        let batch4 = vec![mk(1, 1), odd.clone(), mk(2, 2), {
            let mut o2 = odd.clone();
            o2.id = 10;
            o2.prompt.push(7);
            o2
        }];
        assert_eq!(shared_prefix_rows(&batch4, DEDUP_MIN_PREFIX), 2);
    }

    #[test]
    fn prop_batches_method_homogeneous_and_complete() {
        prop::check(200, |g| {
            let max_batch = g.usize(1, 8);
            let n = g.usize(0, 40);
            let mut b = Batcher::new(max_batch, Duration::from_millis(0));
            let t = Instant::now();
            let methods = Method::all();
            let mut pushed = 0usize;
            for i in 0..n {
                let m = methods[g.usize(0, 4)];
                let len = [16, 64, 128][g.usize(0, 2)];
                let mut r = req(i as u64, m, len);
                if g.bool(0.5) {
                    r.deadline_ms = Some(g.usize(0, 500) as u64);
                }
                if g.bool(0.3) {
                    let names = ["attenuating", "dropout", "extrapolating"];
                    r.policy = DecodePolicy::parse(names[g.usize(0, 2)]);
                }
                b.push_at(r, t + Duration::from_millis(g.usize(0, 5) as u64));
                pushed += 1;
            }
            let mut popped = 0usize;
            while let Some((key, batch)) = b.pop_ready(t + Duration::from_millis(6), &[]) {
                if batch.is_empty() || batch.len() > max_batch {
                    return Err(format!("bad batch size {}", batch.len()));
                }
                if !batch.iter().all(|r| r.group_key() == key) {
                    return Err("mixed-group batch".into());
                }
                popped += batch.len();
            }
            if popped != pushed {
                return Err(format!("lost requests: {popped} != {pushed}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fifo_within_group_without_deadlines() {
        // no explicit deadlines → effective deadlines are arrival+SLA,
        // so deadline order degenerates to the old arrival FIFO
        prop::check(100, |g| {
            let n = g.usize(1, 20);
            let mut b = Batcher::new(4, Duration::from_millis(0));
            let t = Instant::now();
            for i in 0..n {
                let at = t + Duration::from_millis(i as u64);
                b.push_at(req(i as u64, Method::Streaming, 64), at);
            }
            let mut last = None;
            while let Some((_, batch)) = b.pop_ready(t + Duration::from_millis(n as u64), &[]) {
                for r in batch {
                    if let Some(prev) = last {
                        if r.id <= prev {
                            return Err("out of order".into());
                        }
                    }
                    last = Some(r.id);
                }
            }
            Ok(())
        });
    }
}
