//! Load-generator client for the line-protocol server: N worker threads
//! fire requests from a shared queue and collect responses — the client
//! half of the end-to-end serving example.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::protocol::CommitEvent;
use super::request::{Request, Response};

/// One v1 server frame as seen by a subscribed client.
#[derive(Debug)]
pub enum ServerFrame {
    Commit(CommitEvent),
    Done(Response),
}

pub struct Client {
    stream: TcpStream,
    /// persistent reader — streamed frames arrive back-to-back, so
    /// read-ahead bytes must survive between reads
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        self.stream.flush()?;
        let j = self.read_json()?;
        if let Some(err) = j.get("error").and_then(|e| e.as_str()) {
            if j.get("id").is_none() {
                anyhow::bail!("server error: {err}");
            }
        }
        Response::from_json(&j).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// v1 one-shot call: send a `generate` envelope, wait for the
    /// `done` frame.
    pub fn call_v1(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.to_frame("generate").to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        self.stream.flush()?;
        let j = self.read_json()?;
        match j.get("type").and_then(|t| t.as_str()) {
            // a backpressure reject is a terminal answer, not an error:
            // the Response carries rejected=true and retry_after_ms
            Some("done") | Some("reject") => {
                Response::from_json(&j).map_err(|e| anyhow!("bad response: {e}"))
            }
            Some("error") => {
                let msg = j.get("error").and_then(|e| e.as_str()).unwrap_or("unknown");
                anyhow::bail!("server error: {msg}")
            }
            other => anyhow::bail!("unexpected frame type {other:?}"),
        }
    }

    /// v1 streaming call: send a `subscribe` envelope and collect every
    /// frame of the per-request stream — the out-of-order `commit`
    /// events in arrival order, then the terminal `done`.
    pub fn subscribe(&mut self, req: &Request) -> Result<Vec<ServerFrame>> {
        let mut line = req.to_frame("subscribe").to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        self.stream.flush()?;
        let mut frames = Vec::new();
        loop {
            let j = self.read_json()?;
            match j.get("type").and_then(|t| t.as_str()) {
                Some("commit") => frames.push(ServerFrame::Commit(
                    CommitEvent::from_json(&j).map_err(|e| anyhow!("bad commit: {e}"))?,
                )),
                Some("done") | Some("reject") => {
                    let resp =
                        Response::from_json(&j).map_err(|e| anyhow!("bad response: {e}"))?;
                    frames.push(ServerFrame::Done(resp));
                    return Ok(frames);
                }
                Some("error") => {
                    let msg = j.get("error").and_then(|e| e.as_str()).unwrap_or("unknown");
                    anyhow::bail!("server error: {msg}")
                }
                other => anyhow::bail!("unexpected frame type {other:?}"),
            }
        }
    }

    fn read_json(&mut self) -> Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            anyhow::bail!("connection closed mid-stream");
        }
        Json::parse(line.trim()).map_err(|e| anyhow!("bad frame: {e}"))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.stream.write_all(b"{\"cmd\":\"stats\"}\n")?;
        self.stream.flush()?;
        self.read_json()
    }

    /// Prometheus-style stats: the server answers a multi-line text
    /// body terminated by a literal `# EOF` line (read up to and
    /// including it, since the connection stays open for more frames).
    pub fn stats_text(&mut self) -> Result<String> {
        self.stream.write_all(b"{\"cmd\":\"stats\",\"format\":\"prometheus\"}\n")?;
        self.stream.flush()?;
        let mut body = String::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed mid-stats");
            }
            let done = line.trim_end() == "# EOF";
            body.push_str(&line);
            if done {
                return Ok(body);
            }
        }
    }
}

/// Result of a load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub ok: usize,
    pub errors: usize,
    pub wall_secs: f64,
    pub client_latencies: Vec<f64>,
    pub responses: Vec<Response>,
}

/// Fire `requests` at `addr` from `concurrency` connections; each worker
/// pulls the next request off the shared queue (closed-loop load).
pub fn run_load(addr: &str, requests: Vec<Request>, concurrency: usize) -> Result<LoadReport> {
    let queue = Arc::new(Mutex::new(requests.into_iter().collect::<Vec<_>>()));
    let results = Arc::new(Mutex::new((0usize, 0usize, Vec::new(), Vec::new())));
    let t0 = Instant::now();
    let mut handles = vec![];
    for _ in 0..concurrency.max(1) {
        let queue = queue.clone();
        let results = results.clone();
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = Client::connect(&addr)?;
            loop {
                let req = {
                    let mut q = queue.lock().unwrap();
                    match q.pop() {
                        Some(r) => r,
                        None => return Ok(()),
                    }
                };
                let t = Instant::now();
                match client.call(&req) {
                    Ok(resp) => {
                        let mut r = results.lock().unwrap();
                        if resp.error.is_none() {
                            r.0 += 1;
                        } else {
                            r.1 += 1;
                        }
                        r.2.push(t.elapsed().as_secs_f64());
                        r.3.push(resp);
                    }
                    Err(_) => {
                        results.lock().unwrap().1 += 1;
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("client worker panicked"))??;
    }
    let (ok, errors, lats, responses) = Arc::try_unwrap(results)
        .map_err(|_| anyhow!("results still shared"))?
        .into_inner()
        .unwrap();
    Ok(LoadReport {
        ok,
        errors,
        wall_secs: t0.elapsed().as_secs_f64(),
        client_latencies: lats,
        responses,
    })
}
