//! Load-generator client for the line-protocol server: N worker threads
//! fire requests from a shared queue and collect responses — the client
//! half of the end-to-end serving example.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::request::{Request, Response};

pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client { stream })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes())?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut resp_line = String::new();
        reader.read_line(&mut resp_line)?;
        let j = Json::parse(resp_line.trim()).map_err(|e| anyhow!("bad response: {e}"))?;
        if let Some(err) = j.get("error").and_then(|e| e.as_str()) {
            if j.get("id").is_none() {
                anyhow::bail!("server error: {err}");
            }
        }
        Response::from_json(&j).map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.stream.write_all(b"{\"cmd\":\"stats\"}\n")?;
        self.stream.flush()?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        Json::parse(line.trim()).map_err(|e| anyhow!("bad stats: {e}"))
    }
}

/// Result of a load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub ok: usize,
    pub errors: usize,
    pub wall_secs: f64,
    pub client_latencies: Vec<f64>,
    pub responses: Vec<Response>,
}

/// Fire `requests` at `addr` from `concurrency` connections; each worker
/// pulls the next request off the shared queue (closed-loop load).
pub fn run_load(addr: &str, requests: Vec<Request>, concurrency: usize) -> Result<LoadReport> {
    let queue = Arc::new(Mutex::new(requests.into_iter().collect::<Vec<_>>()));
    let results = Arc::new(Mutex::new((0usize, 0usize, Vec::new(), Vec::new())));
    let t0 = Instant::now();
    let mut handles = vec![];
    for _ in 0..concurrency.max(1) {
        let queue = queue.clone();
        let results = results.clone();
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = Client::connect(&addr)?;
            loop {
                let req = {
                    let mut q = queue.lock().unwrap();
                    match q.pop() {
                        Some(r) => r,
                        None => return Ok(()),
                    }
                };
                let t = Instant::now();
                match client.call(&req) {
                    Ok(resp) => {
                        let mut r = results.lock().unwrap();
                        if resp.error.is_none() {
                            r.0 += 1;
                        } else {
                            r.1 += 1;
                        }
                        r.2.push(t.elapsed().as_secs_f64());
                        r.3.push(resp);
                    }
                    Err(_) => {
                        results.lock().unwrap().1 += 1;
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("client worker panicked"))??;
    }
    let (ok, errors, lats, responses) = Arc::try_unwrap(results)
        .map_err(|_| anyhow!("results still shared"))?
        .into_inner()
        .unwrap();
    Ok(LoadReport {
        ok,
        errors,
        wall_secs: t0.elapsed().as_secs_f64(),
        client_latencies: lats,
        responses,
    })
}
