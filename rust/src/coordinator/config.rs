//! `ServeConfig`: the one typed configuration for every serving
//! surface. Historically each knob lived wherever it was consumed —
//! `SDLLM_REF_MODE` in the backend, `SDLLM_STRESS_*` in the stress
//! harness, `--ref-mode`/`--gen-lens`/`--deadline-ms` in binaries —
//! with per-site defaults that could drift. This module collapses the
//! env/CLI split into a single struct with one precedence rule,
//! CLI flag > `SDLLM_*` environment variable > default, applied
//! uniformly by [`ServeConfig::from_env_and_args`]. `main.rs`, the
//! serve_batch example and the stress harness all consume it.

use std::path::PathBuf;
use std::str::FromStr;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::engine::{DecodePolicy, RefMode};
use crate::util::cli::Args;

use super::router::{
    RouterOptions, DEFAULT_MAX_ENGINES, DEFAULT_MAX_QUEUE_DEPTH, DEFAULT_PREFIX_CACHE_BYTES,
};
use super::server::DEFAULT_MAX_CONNECTIONS;

/// Typed serving configuration. Construct with
/// [`ServeConfig::from_env_and_args`] (binaries) or
/// [`ServeConfig::from_env`] (tests/harnesses with no CLI).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// listen address (`--addr` / `SDLLM_ADDR`)
    pub addr: String,
    /// reference-backend mode (`--ref-mode` / `SDLLM_REF_MODE`)
    pub ref_mode: RefMode,
    /// backend selector: reference|pjrt|auto (`--backend` / `SDLLM_BACKEND`)
    pub backend: String,
    /// model name under the artifacts index (`--model` / `SDLLM_MODEL`)
    pub model: String,
    /// artifacts directory override (`--artifacts` / `SDLLM_ARTIFACTS`)
    pub artifacts: Option<PathBuf>,
    /// dynamic batcher flush size (`--max-batch` / `SDLLM_MAX_BATCH`)
    pub max_batch: usize,
    /// batcher flush deadline (`--max-wait-ms` / `SDLLM_MAX_WAIT_MS`)
    pub max_wait: Duration,
    /// worker-thread cap (`--max-engines` / `SDLLM_MAX_ENGINES`)
    pub max_engines: usize,
    /// bounded-admission cap per method queue; a full queue answers a
    /// typed reject with a retry hint
    /// (`--max-queue-depth` / `SDLLM_MAX_QUEUE_DEPTH`)
    pub max_queue_depth: usize,
    /// concurrent-connection cap; over the cap the server answers one
    /// `busy` error frame and closes
    /// (`--max-connections` / `SDLLM_MAX_CONNECTIONS`)
    pub max_connections: usize,
    /// default decode policy applied to requests that don't name one;
    /// absent means each request's method preset
    /// (`--policy` / `SDLLM_POLICY`)
    pub policy: Option<DecodePolicy>,
    /// generation lengths driven by harnesses (`--gen-lens` / `SDLLM_GEN_LENS`)
    pub gen_lens: Vec<usize>,
    /// default SLA budget; 0/absent means none (`--deadline-ms` / `SDLLM_DEADLINE_MS`)
    pub deadline_ms: Option<u64>,
    /// byte budget for the router's cross-request prefix cache; 0
    /// disables caching entirely
    /// (`--prefix-cache-bytes` / `SDLLM_PREFIX_CACHE_BYTES`)
    pub prefix_cache_bytes: usize,
    /// per-engine host-side row parallelism within a decode step:
    /// selection/commit work fans across this many scoped threads with
    /// a deterministic row-order merge, so output is bit-identical at
    /// any setting; 1 = off
    /// (`--decode-threads` / `SDLLM_DECODE_THREADS`)
    pub decode_threads: usize,
    /// stress harness: schedules per scenario (`--schedules` / `SDLLM_STRESS_SCHEDULES`)
    pub stress_schedules: u64,
    /// stress harness: RNG seed base (`--seed-base` / `SDLLM_STRESS_SEED_BASE`)
    pub stress_seed_base: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7333".to_string(),
            ref_mode: RefMode::Toy,
            backend: "auto".to_string(),
            model: "llada15-mini".to_string(),
            artifacts: None,
            max_batch: 4,
            max_wait: Duration::from_millis(20),
            max_engines: DEFAULT_MAX_ENGINES,
            max_queue_depth: DEFAULT_MAX_QUEUE_DEPTH,
            max_connections: DEFAULT_MAX_CONNECTIONS,
            policy: None,
            gen_lens: vec![64],
            deadline_ms: None,
            prefix_cache_bytes: DEFAULT_PREFIX_CACHE_BYTES,
            decode_threads: 1,
            stress_schedules: 20,
            stress_seed_base: 0,
        }
    }
}

/// A non-empty environment value (empty/whitespace counts as unset, so
/// `SDLLM_X= cmd` doesn't shadow the default with garbage).
fn env_str(var: &str) -> Option<String> {
    std::env::var(var).ok().filter(|s| !s.trim().is_empty())
}

/// CLI option first, then environment variable.
fn pick(args: &Args, name: &str, env: &str) -> Option<String> {
    args.get(name).map(|s| s.to_string()).or_else(|| env_str(env))
}

/// Strict numeric parse — a typo in a knob is an error, not a silent
/// fallback to the default.
fn parse_num<T: FromStr>(src: Option<String>, what: &str) -> Result<Option<T>> {
    match src {
        Some(s) => s
            .trim()
            .parse()
            .map(Some)
            .map_err(|_| anyhow!("invalid {what} '{s}'")),
        None => Ok(None),
    }
}

impl ServeConfig {
    /// Environment-only construction (stress harness, tests).
    pub fn from_env() -> Result<ServeConfig> {
        ServeConfig::from_env_and_args(&Args::default())
    }

    /// Resolve every knob with the uniform precedence
    /// CLI > `SDLLM_*` env > default, validating as it goes.
    pub fn from_env_and_args(args: &Args) -> Result<ServeConfig> {
        let d = ServeConfig::default();

        let raw_mode = pick(args, "ref-mode", "SDLLM_REF_MODE").unwrap_or_default();
        let norm = raw_mode.trim().to_lowercase();
        let ref_mode = if norm.is_empty() {
            RefMode::Toy
        } else {
            RefMode::parse(&norm)
                .ok_or_else(|| anyhow!("unknown --ref-mode '{raw_mode}' (toy|causal)"))?
        };

        let gen_lens = match pick(args, "gen-lens", "SDLLM_GEN_LENS") {
            Some(s) => {
                let lens: Vec<usize> = s
                    .split(',')
                    .map(|x| {
                        x.trim().parse().map_err(|_| anyhow!("invalid gen len '{}'", x.trim()))
                    })
                    .collect::<Result<_>>()?;
                if lens.is_empty() || lens.iter().any(|&l| l == 0) {
                    bail!("gen-lens must be non-empty positive lengths, got '{s}'");
                }
                lens
            }
            None => d.gen_lens,
        };

        let max_batch =
            parse_num(pick(args, "max-batch", "SDLLM_MAX_BATCH"), "max-batch")?
                .unwrap_or(d.max_batch);
        if max_batch == 0 {
            bail!("max-batch must be >= 1");
        }
        let max_engines =
            parse_num(pick(args, "max-engines", "SDLLM_MAX_ENGINES"), "max-engines")?
                .unwrap_or(d.max_engines);
        if max_engines == 0 {
            bail!("max-engines must be >= 1");
        }
        let max_queue_depth =
            parse_num(pick(args, "max-queue-depth", "SDLLM_MAX_QUEUE_DEPTH"), "max-queue-depth")?
                .unwrap_or(d.max_queue_depth);
        if max_queue_depth == 0 {
            bail!("max-queue-depth must be >= 1");
        }
        let max_connections =
            parse_num(pick(args, "max-connections", "SDLLM_MAX_CONNECTIONS"), "max-connections")?
                .unwrap_or(d.max_connections);
        if max_connections == 0 {
            bail!("max-connections must be >= 1");
        }
        let policy = match pick(args, "policy", "SDLLM_POLICY") {
            Some(s) => Some(DecodePolicy::parse(s.trim()).ok_or_else(|| {
                anyhow!(
                    "unknown --policy '{s}' ({})",
                    DecodePolicy::preset_names().join("|")
                )
            })?),
            None => None,
        };
        let max_wait_ms: u64 =
            parse_num(pick(args, "max-wait-ms", "SDLLM_MAX_WAIT_MS"), "max-wait-ms")?
                .unwrap_or(d.max_wait.as_millis() as u64);
        let deadline_ms: Option<u64> =
            parse_num(pick(args, "deadline-ms", "SDLLM_DEADLINE_MS"), "deadline-ms")?
                .filter(|&ms| ms > 0);

        // 0 is a valid setting (cache off), unlike the >= 1 caps above
        let prefix_cache_bytes = parse_num(
            pick(args, "prefix-cache-bytes", "SDLLM_PREFIX_CACHE_BYTES"),
            "prefix-cache-bytes",
        )?
        .unwrap_or(d.prefix_cache_bytes);
        let decode_threads =
            parse_num(pick(args, "decode-threads", "SDLLM_DECODE_THREADS"), "decode-threads")?
                .unwrap_or(d.decode_threads);
        if decode_threads == 0 {
            bail!("decode-threads must be >= 1");
        }

        Ok(ServeConfig {
            addr: pick(args, "addr", "SDLLM_ADDR").unwrap_or(d.addr),
            ref_mode,
            backend: pick(args, "backend", "SDLLM_BACKEND").unwrap_or(d.backend),
            model: pick(args, "model", "SDLLM_MODEL").unwrap_or(d.model),
            artifacts: pick(args, "artifacts", "SDLLM_ARTIFACTS").map(PathBuf::from),
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            max_engines,
            max_queue_depth,
            max_connections,
            policy,
            gen_lens,
            deadline_ms,
            prefix_cache_bytes,
            decode_threads,
            stress_schedules: parse_num(
                pick(args, "schedules", "SDLLM_STRESS_SCHEDULES"),
                "schedules",
            )?
            .unwrap_or(d.stress_schedules),
            stress_seed_base: parse_num(
                pick(args, "seed-base", "SDLLM_STRESS_SEED_BASE"),
                "seed-base",
            )?
            .unwrap_or(d.stress_seed_base),
        })
    }

    /// The router options this configuration asks for.
    pub fn router_options(&self) -> RouterOptions {
        RouterOptions {
            max_batch: self.max_batch,
            max_wait: self.max_wait,
            max_engines: self.max_engines,
            max_queue_depth: self.max_queue_depth,
            prefix_cache_bytes: self.prefix_cache_bytes,
            decode_threads: self.decode_threads,
        }
    }

    /// The artifacts directory: explicit override or the workspace
    /// default.
    pub fn artifacts_root(&self) -> PathBuf {
        self.artifacts.clone().unwrap_or_else(crate::artifacts_root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn cli_overrides_parse_and_validate() {
        let c = ServeConfig::from_env_and_args(&parse(&[
            "--ref-mode",
            "causal",
            "--gen-lens",
            "32, 64,128",
            "--deadline-ms",
            "250",
            "--max-engines",
            "2",
            "--max-batch",
            "8",
            "--max-queue-depth",
            "16",
            "--max-connections",
            "5",
            "--policy",
            "attenuating",
            "--prefix-cache-bytes",
            "1048576",
            "--decode-threads",
            "4",
        ]))
        .unwrap();
        assert_eq!(c.ref_mode, RefMode::Causal);
        assert_eq!(c.policy, DecodePolicy::parse("attenuating"));
        assert_eq!(c.gen_lens, vec![32, 64, 128]);
        assert_eq!(c.deadline_ms, Some(250));
        assert_eq!(c.router_options().max_engines, 2);
        assert_eq!(c.router_options().max_batch, 8);
        assert_eq!(c.router_options().max_queue_depth, 16);
        assert_eq!(c.router_options().prefix_cache_bytes, 1048576);
        assert_eq!(c.router_options().decode_threads, 4);
        assert_eq!(c.max_connections, 5);

        assert!(ServeConfig::from_env_and_args(&parse(&["--ref-mode", "bogus"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--gen-lens", "64,x"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--max-batch", "0"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--max-engines", "nope"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--max-queue-depth", "0"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--max-connections", "0"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--policy", "bogus"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--prefix-cache-bytes", "x"])).is_err());
        // 1 thread = off, 0 is a config error (unlike prefix-cache-bytes)
        assert!(ServeConfig::from_env_and_args(&parse(&["--decode-threads", "0"])).is_err());
        assert!(ServeConfig::from_env_and_args(&parse(&["--decode-threads", "x"])).is_err());
        // deadline 0 means "no deadline", not an error
        let c = ServeConfig::from_env_and_args(&parse(&["--deadline-ms", "0"])).unwrap();
        assert_eq!(c.deadline_ms, None);
        // prefix-cache-bytes 0 means "cache off", not an error
        let c = ServeConfig::from_env_and_args(&parse(&["--prefix-cache-bytes", "0"])).unwrap();
        assert_eq!(c.prefix_cache_bytes, 0);
    }

    #[test]
    fn env_layering_under_cli() {
        // all env manipulation — and every assertion that depends on the
        // SDLLM_* variables being unset — lives in this one test: unit
        // tests in this binary run in parallel and share the process
        // environment, so defaults are checked here, strictly before the
        // variables are set. The harness may also inherit SDLLM_* from
        // the caller (CI exports SDLLM_STRESS_SCHEDULES) — clear first.
        for var in [
            "SDLLM_ADDR",
            "SDLLM_REF_MODE",
            "SDLLM_BACKEND",
            "SDLLM_MODEL",
            "SDLLM_ARTIFACTS",
            "SDLLM_MAX_BATCH",
            "SDLLM_MAX_WAIT_MS",
            "SDLLM_MAX_ENGINES",
            "SDLLM_MAX_QUEUE_DEPTH",
            "SDLLM_MAX_CONNECTIONS",
            "SDLLM_POLICY",
            "SDLLM_GEN_LENS",
            "SDLLM_DEADLINE_MS",
            "SDLLM_PREFIX_CACHE_BYTES",
            "SDLLM_DECODE_THREADS",
            "SDLLM_STRESS_SCHEDULES",
            "SDLLM_STRESS_SEED_BASE",
        ] {
            std::env::remove_var(var);
        }
        let c = ServeConfig::from_env_and_args(&parse(&[])).unwrap();
        assert_eq!(c.addr, "127.0.0.1:7333");
        assert_eq!(c.ref_mode, RefMode::Toy);
        assert_eq!(c.backend, "auto");
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.max_wait, Duration::from_millis(20));
        assert_eq!(c.max_engines, DEFAULT_MAX_ENGINES);
        assert_eq!(c.max_queue_depth, DEFAULT_MAX_QUEUE_DEPTH);
        assert_eq!(c.max_connections, DEFAULT_MAX_CONNECTIONS);
        assert_eq!(c.gen_lens, vec![64]);
        assert_eq!(c.deadline_ms, None);
        assert_eq!(c.policy, None);
        assert_eq!(c.prefix_cache_bytes, DEFAULT_PREFIX_CACHE_BYTES);
        assert_eq!(c.decode_threads, 1);
        assert_eq!(c.stress_schedules, 20);

        std::env::set_var("SDLLM_POLICY", "dropout");
        std::env::set_var("SDLLM_GEN_LENS", "16,32");
        std::env::set_var("SDLLM_STRESS_SEED_BASE", "77");
        std::env::set_var("SDLLM_DEADLINE_MS", "  ");
        std::env::set_var("SDLLM_MAX_QUEUE_DEPTH", "9");
        std::env::set_var("SDLLM_MAX_CONNECTIONS", "3");
        std::env::set_var("SDLLM_PREFIX_CACHE_BYTES", "65536");
        std::env::set_var("SDLLM_DECODE_THREADS", "2");
        let c = ServeConfig::from_env_and_args(&parse(&[])).unwrap();
        assert_eq!(c.gen_lens, vec![16, 32]);
        assert_eq!(c.policy, DecodePolicy::parse("dropout"));
        assert_eq!(c.stress_seed_base, 77);
        assert_eq!(c.max_queue_depth, 9);
        assert_eq!(c.max_connections, 3);
        assert_eq!(c.prefix_cache_bytes, 65536);
        assert_eq!(c.decode_threads, 2);
        // whitespace-only env value counts as unset
        assert_eq!(c.deadline_ms, None);
        // CLI wins over env
        let c = ServeConfig::from_env_and_args(&parse(&["--gen-lens", "64"])).unwrap();
        assert_eq!(c.gen_lens, vec![64]);
        let c = ServeConfig::from_env_and_args(&parse(&["--max-queue-depth", "40"])).unwrap();
        assert_eq!(c.max_queue_depth, 40);
        let c = ServeConfig::from_env_and_args(&parse(&["--policy", "streaming"])).unwrap();
        assert_eq!(c.policy, DecodePolicy::parse("streaming"));
        let c =
            ServeConfig::from_env_and_args(&parse(&["--prefix-cache-bytes", "4096"])).unwrap();
        assert_eq!(c.prefix_cache_bytes, 4096);
        let c = ServeConfig::from_env_and_args(&parse(&["--decode-threads", "3"])).unwrap();
        assert_eq!(c.decode_threads, 3);
        std::env::remove_var("SDLLM_POLICY");
        std::env::remove_var("SDLLM_GEN_LENS");
        std::env::remove_var("SDLLM_STRESS_SEED_BASE");
        std::env::remove_var("SDLLM_DEADLINE_MS");
        std::env::remove_var("SDLLM_MAX_QUEUE_DEPTH");
        std::env::remove_var("SDLLM_MAX_CONNECTIONS");
        std::env::remove_var("SDLLM_PREFIX_CACHE_BYTES");
        std::env::remove_var("SDLLM_DECODE_THREADS");
    }
}
