//! Serving metrics: request counts, token throughput (the paper's
//! non-EOS tokens/s), latency percentiles and queueing delay. Shared
//! behind a mutex; snapshots serialize to JSON for the server's `stats`
//! command and the serve_batch example report.

use std::sync::Mutex;
use std::time::Instant;

use crate::engine::{GenReport, PrefixCacheStats};
use crate::util::json::Json;
use crate::util::stats::Samples;

/// One worker thread's capacity picture as the router last saw it —
/// refreshed every scheduling pass alongside the group-depth gauges.
#[derive(Debug, Clone, Default)]
pub struct WorkerGauge {
    /// rows routed to this worker and not yet answered/bounced
    pub outstanding: usize,
    /// engine slot count
    pub capacity: usize,
    /// the method whose engine the worker is currently running
    pub assigned: Option<&'static str>,
    pub ready: bool,
    pub dead: bool,
}

#[derive(Debug, Default)]
struct Inner {
    requests_ok: u64,
    requests_err: u64,
    non_eos_tokens: u64,
    batches: u64,
    batch_sizes: Vec<usize>,
    latency: Samples,
    queue_delay: Samples,
    started: Option<Instant>,
    /// requests admitted into a batch already mid-flight (continuous
    /// batching joins, as opposed to batch-start admissions)
    joins: u64,
    /// requests admitted when their engine's batch started
    batch_started: u64,
    /// every successful engine admission, regardless of path — the
    /// conservation identity `joins + batch_started == admissions`
    /// pins the router wiring (the stress harness asserts it)
    admissions: u64,
    /// ok responses that completed past their effective deadline
    deadline_misses: u64,
    /// block rounds driven across all retired engines
    engine_rounds: u64,
    /// rounds whose live rows spanned ≥ 2 distinct gen lengths
    /// (mixed-length occupancy numerator, against engine_rounds)
    mixed_len_rounds: u64,
    engine_steps: u64,
    engine_prefills: u64,
    engine_blocks_skipped: u64,
    /// per-phase engine seconds (prefill / decode / host-gather)
    prefill_secs: f64,
    decode_secs: f64,
    host_secs: f64,
    /// prefill seconds split by cause: first pass over fresh rows vs
    /// dkv-refresh re-prefills mid-decode (the two sum to
    /// `prefill_secs` up to rounds that mix both)
    init_prefill_secs: f64,
    reprefill_secs: f64,
    init_prefills: u64,
    reprefills: u64,
    /// batch rows sharing a ≥ DEDUP_MIN_PREFIX token prefix with an
    /// earlier row of the same batch (counted beyond the first sharer)
    prefix_dedup_rows: u64,
    /// gauge: the router-owned prefix cache's latest stats snapshot,
    /// refreshed every scheduling pass (zeros when the cache is off)
    prefix_cache: PrefixCacheStats,
    /// gauge: per-method (queued, active-in-engine) depths, refreshed
    /// by the router every scheduling pass
    group_depth: Vec<(&'static str, usize, usize)>,
    /// gauge + high-water mark of concurrently running engines
    engines_active: usize,
    max_engines_active: usize,
    /// decode wall-clock summed across all worker threads — with true
    /// parallel engines this exceeds router elapsed time (the
    /// `engines_overlap` bench asserts exactly that)
    busy_secs: f64,
    busy_by_method: Vec<(&'static str, f64)>,
    /// rows SLA-evicted into the `parked` terminal state (counted as ok
    /// responses, never as deadline misses)
    parked: u64,
    /// every request the router's inbox accepted — the left side of the
    /// conservation identity
    /// `submitted == answered + rejected + shed + parked + cancelled`
    /// (the overload suite asserts it per seed)
    submitted: u64,
    /// normally-answered terminal responses (ok or error) — excludes
    /// parked/rejected/shed/cancelled, which have their own counters
    answered: u64,
    /// backpressure rejects: the method queue was at `max_queue_depth`
    /// at submission, so the request was answered with `retry_after_ms`
    /// and never queued
    rejected: u64,
    /// load sheds: queued `park_on_miss` requests whose effective
    /// deadline passed before an engine slot opened (counted separately
    /// from `deadline_misses`, which are late *completions*)
    shed: u64,
    /// rows detached because their subscriber disconnected mid-stream —
    /// the worker slot is reclaimed instead of decoding into the void
    cancelled: u64,
    /// high-water mark of total queued depth across method queues
    queue_depth_peak: usize,
    /// gauge: per-worker outstanding/capacity/assignment, refreshed by
    /// the router every scheduling pass
    workers: Vec<WorkerGauge>,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn start_clock(&self) {
        let mut m = self.inner.lock().unwrap();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
    }

    pub fn record_batch(&self, size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_sizes.push(size);
    }

    /// A request joined an already-running batch between block rounds.
    pub fn record_join(&self) {
        let mut m = self.inner.lock().unwrap();
        m.joins += 1;
    }

    /// A request was admitted when its engine's batch started.
    pub fn record_batch_admit(&self) {
        let mut m = self.inner.lock().unwrap();
        m.batch_started += 1;
    }

    /// Any successful engine admission (batch start or join). Recorded
    /// at the `BatchEngine::admit` call site, independently of the
    /// per-path counters, so `joins + batch_started == admissions`
    /// holds exactly when the router wiring is correct.
    pub fn record_admission(&self) {
        let mut m = self.inner.lock().unwrap();
        m.admissions += 1;
    }

    /// An ok response completed past its effective deadline.
    pub fn record_deadline_miss(&self) {
        let mut m = self.inner.lock().unwrap();
        m.deadline_misses += 1;
    }

    /// Refresh the scheduling gauges: per-method (queued, active) depth
    /// and the number of concurrently running engines.
    pub fn set_groups(&self, depths: Vec<(&'static str, usize, usize)>, engines: usize) {
        let mut m = self.inner.lock().unwrap();
        m.group_depth = depths;
        m.engines_active = engines;
        m.max_engines_active = m.max_engines_active.max(engines);
    }

    /// Fold a retired engine's cumulative report into the serving
    /// totals (per-phase seconds, steps, prefills, skipped blocks,
    /// mixed-length rounds).
    pub fn record_engine(&self, report: &GenReport, rounds: u64, mixed_rounds: u64) {
        let mut m = self.inner.lock().unwrap();
        m.engine_rounds += rounds;
        m.mixed_len_rounds += mixed_rounds;
        m.engine_steps += report.steps;
        m.engine_prefills += report.prefills;
        m.engine_blocks_skipped += report.blocks_skipped;
        m.prefill_secs += report.prefill_secs;
        m.decode_secs += report.decode_secs;
        m.host_secs += report.host_secs;
        m.init_prefill_secs += report.init_prefill_secs;
        m.reprefill_secs += report.reprefill_secs;
        m.init_prefills += report.init_prefills;
        m.reprefills += report.reprefills;
    }

    /// `n` rows of a dispatched batch shared a long-enough prompt
    /// prefix with an earlier row of the same batch (the intra-batch
    /// dedup window the prefix cache collapses to one sig computation).
    pub fn record_prefix_dedup(&self, n: u64) {
        let mut m = self.inner.lock().unwrap();
        m.prefix_dedup_rows += n;
    }

    /// Refresh the prefix-cache gauge block from the shared cache's
    /// cumulative stats (called by the router every scheduling pass).
    pub fn set_prefix_cache(&self, stats: PrefixCacheStats) {
        let mut m = self.inner.lock().unwrap();
        m.prefix_cache = stats;
    }

    /// Decode wall-clock one worker spent on one block round. Summed
    /// per method and in total; overlap across workers is what makes
    /// `busy_s` exceed `elapsed_s` under parallel serving.
    pub fn record_busy(&self, method: &'static str, secs: f64) {
        let mut m = self.inner.lock().unwrap();
        m.busy_secs += secs;
        match m.busy_by_method.iter_mut().find(|(name, _)| *name == method) {
            Some((_, total)) => *total += secs,
            None => m.busy_by_method.push((method, secs)),
        }
    }

    /// A row was SLA-evicted and answered in the parked terminal state.
    pub fn record_parked(&self) {
        let mut m = self.inner.lock().unwrap();
        m.parked += 1;
    }

    /// A request reached the router's inbox (before any admission
    /// decision) — the left side of the conservation identity.
    pub fn record_submitted(&self) {
        let mut m = self.inner.lock().unwrap();
        m.submitted += 1;
    }

    /// A request was answered through the normal terminal path (ok or
    /// error; not parked/rejected/shed/cancelled).
    pub fn record_answered(&self) {
        let mut m = self.inner.lock().unwrap();
        m.answered += 1;
    }

    /// A request was rejected at admission (queue full) with a
    /// `retry_after_ms` hint.
    pub fn record_rejected(&self) {
        let mut m = self.inner.lock().unwrap();
        m.rejected += 1;
    }

    /// A queued request was shed because its deadline became unmeetable.
    pub fn record_shed(&self) {
        let mut m = self.inner.lock().unwrap();
        m.shed += 1;
    }

    /// A row was detached because its subscriber disconnected.
    pub fn record_cancelled(&self) {
        let mut m = self.inner.lock().unwrap();
        m.cancelled += 1;
    }

    /// Fold the current total queued depth into the high-water mark
    /// (called on every external push).
    pub fn note_queue_depth(&self, depth: usize) {
        let mut m = self.inner.lock().unwrap();
        m.queue_depth_peak = m.queue_depth_peak.max(depth);
    }

    /// Refresh the per-worker capacity gauges.
    pub fn set_workers(&self, workers: Vec<WorkerGauge>) {
        let mut m = self.inner.lock().unwrap();
        m.workers = workers;
    }

    pub fn record_response(&self, ok: bool, tokens: usize, latency_s: f64, queue_s: f64) {
        let mut m = self.inner.lock().unwrap();
        if ok {
            m.requests_ok += 1;
            m.non_eos_tokens += tokens as u64;
        } else {
            m.requests_err += 1;
        }
        m.latency.push(latency_s);
        m.queue_delay.push(queue_s);
    }

    pub fn snapshot(&self) -> Json {
        let mut m = self.inner.lock().unwrap();
        let elapsed = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let tps = if elapsed > 0.0 { m.non_eos_tokens as f64 / elapsed } else { 0.0 };
        let mean_batch = if m.batch_sizes.is_empty() {
            0.0
        } else {
            m.batch_sizes.iter().sum::<usize>() as f64 / m.batch_sizes.len() as f64
        };
        let p50 = m.latency.percentile(50.0);
        let p95 = m.latency.percentile(95.0);
        let p99 = m.latency.percentile(99.0);
        let qmean = m.queue_delay.mean();
        Json::obj(vec![
            ("requests_ok", Json::Num(m.requests_ok as f64)),
            ("requests_err", Json::Num(m.requests_err as f64)),
            ("non_eos_tokens", Json::Num(m.non_eos_tokens as f64)),
            ("elapsed_s", Json::Num(elapsed)),
            ("tokens_per_s", Json::Num(tps)),
            ("batches", Json::Num(m.batches as f64)),
            ("mean_batch_size", Json::Num(mean_batch)),
            ("latency_p50_s", Json::Num(p50)),
            ("latency_p95_s", Json::Num(p95)),
            ("latency_p99_s", Json::Num(p99)),
            ("queue_delay_mean_s", Json::Num(qmean)),
            ("joins", Json::Num(m.joins as f64)),
            ("batch_started", Json::Num(m.batch_started as f64)),
            ("admissions", Json::Num(m.admissions as f64)),
            ("deadline_misses", Json::Num(m.deadline_misses as f64)),
            ("parked", Json::Num(m.parked as f64)),
            ("submitted", Json::Num(m.submitted as f64)),
            ("answered", Json::Num(m.answered as f64)),
            ("rejected", Json::Num(m.rejected as f64)),
            ("shed", Json::Num(m.shed as f64)),
            ("cancelled", Json::Num(m.cancelled as f64)),
            ("queue_depth_peak", Json::Num(m.queue_depth_peak as f64)),
            (
                "workers",
                Json::Arr(
                    m.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("outstanding", Json::Num(w.outstanding as f64)),
                                ("capacity", Json::Num(w.capacity as f64)),
                                (
                                    "assigned",
                                    w.assigned
                                        .map(|m| Json::Str(m.to_string()))
                                        .unwrap_or(Json::Null),
                                ),
                                ("ready", Json::Bool(w.ready)),
                                ("dead", Json::Bool(w.dead)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("busy_s", Json::Num(m.busy_secs)),
            (
                "busy_by_method",
                Json::obj(
                    m.busy_by_method
                        .iter()
                        .map(|&(name, secs)| (name, Json::Num(secs)))
                        .collect(),
                ),
            ),
            ("mixed_len_rounds", Json::Num(m.mixed_len_rounds as f64)),
            ("engines_active", Json::Num(m.engines_active as f64)),
            ("max_engines_active", Json::Num(m.max_engines_active as f64)),
            (
                "group_depth",
                Json::obj(
                    m.group_depth
                        .iter()
                        .map(|&(name, queued, active)| {
                            (
                                name,
                                Json::obj(vec![
                                    ("queued", Json::Num(queued as f64)),
                                    ("active", Json::Num(active as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            ("engine_rounds", Json::Num(m.engine_rounds as f64)),
            ("engine_steps", Json::Num(m.engine_steps as f64)),
            ("engine_prefills", Json::Num(m.engine_prefills as f64)),
            ("engine_blocks_skipped", Json::Num(m.engine_blocks_skipped as f64)),
            ("prefill_s", Json::Num(m.prefill_secs)),
            ("decode_s", Json::Num(m.decode_secs)),
            ("host_s", Json::Num(m.host_secs)),
            ("init_prefill_s", Json::Num(m.init_prefill_secs)),
            ("reprefill_s", Json::Num(m.reprefill_secs)),
            ("init_prefills", Json::Num(m.init_prefills as f64)),
            ("reprefills", Json::Num(m.reprefills as f64)),
            ("prefix_dedup_rows", Json::Num(m.prefix_dedup_rows as f64)),
            (
                "prefix_cache",
                Json::obj(vec![
                    ("lookups", Json::Num(m.prefix_cache.lookups as f64)),
                    ("hits", Json::Num(m.prefix_cache.hits as f64)),
                    ("partial_hits", Json::Num(m.prefix_cache.partial_hits as f64)),
                    ("misses", Json::Num(m.prefix_cache.misses as f64)),
                    ("inserts", Json::Num(m.prefix_cache.inserts as f64)),
                    ("evictions", Json::Num(m.prefix_cache.evictions as f64)),
                    ("bytes", Json::Num(m.prefix_cache.bytes as f64)),
                    ("nodes", Json::Num(m.prefix_cache.nodes as f64)),
                    ("entries", Json::Num(m.prefix_cache.entries as f64)),
                    ("reused_tokens", Json::Num(m.prefix_cache.reused_tokens as f64)),
                    ("saved_prefill_s", Json::Num(m.prefix_cache.saved_prefill_secs)),
                ]),
            ),
        ])
    }

    /// Scrapeable Prometheus-style text rendering of the capacity
    /// picture. Every metric is prefixed `sdllm_` and preceded by a
    /// `# TYPE` line; per-method and per-worker series carry labels.
    /// The body ends with a literal `# EOF` line — the on-wire
    /// terminator clients read up to (JSON stats are one line; the text
    /// format is the only multi-line server payload).
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let m = self.inner.lock().unwrap();
        let mut out = String::new();
        let counter = |out: &mut String, name: &str, v: u64| {
            let _ = writeln!(out, "# TYPE sdllm_{name} counter\nsdllm_{name} {v}");
        };
        counter(&mut out, "submitted", m.submitted);
        counter(&mut out, "answered", m.answered);
        counter(&mut out, "rejected", m.rejected);
        counter(&mut out, "shed", m.shed);
        counter(&mut out, "cancelled", m.cancelled);
        counter(&mut out, "parked", m.parked);
        counter(&mut out, "deadline_misses", m.deadline_misses);
        counter(&mut out, "requests_ok", m.requests_ok);
        counter(&mut out, "requests_err", m.requests_err);
        counter(&mut out, "admissions", m.admissions);
        counter(&mut out, "joins", m.joins);
        counter(&mut out, "batch_started", m.batch_started);
        counter(&mut out, "non_eos_tokens", m.non_eos_tokens);
        counter(&mut out, "prefix_cache_lookups", m.prefix_cache.lookups);
        counter(&mut out, "prefix_cache_hits", m.prefix_cache.hits);
        counter(&mut out, "prefix_cache_partial_hits", m.prefix_cache.partial_hits);
        counter(&mut out, "prefix_cache_misses", m.prefix_cache.misses);
        counter(&mut out, "prefix_cache_inserts", m.prefix_cache.inserts);
        counter(&mut out, "prefix_cache_evictions", m.prefix_cache.evictions);
        counter(&mut out, "prefix_reused_tokens", m.prefix_cache.reused_tokens);
        counter(&mut out, "prefix_dedup_rows", m.prefix_dedup_rows);
        counter(&mut out, "init_prefills", m.init_prefills);
        counter(&mut out, "reprefills", m.reprefills);

        let gauge = |out: &mut String, name: &str, v: f64| {
            let _ = writeln!(out, "# TYPE sdllm_{name} gauge\nsdllm_{name} {v}");
        };
        gauge(&mut out, "prefix_cache_bytes", m.prefix_cache.bytes as f64);
        gauge(&mut out, "prefix_cache_nodes", m.prefix_cache.nodes as f64);
        gauge(&mut out, "prefix_cache_entries", m.prefix_cache.entries as f64);
        gauge(&mut out, "prefix_saved_prefill_seconds", m.prefix_cache.saved_prefill_secs);
        gauge(&mut out, "init_prefill_seconds", m.init_prefill_secs);
        gauge(&mut out, "reprefill_seconds", m.reprefill_secs);
        gauge(&mut out, "queue_depth_peak", m.queue_depth_peak as f64);
        gauge(&mut out, "engines_active", m.engines_active as f64);
        gauge(&mut out, "max_engines_active", m.max_engines_active as f64);
        gauge(&mut out, "latency_p50_seconds", m.latency.percentile(50.0));
        gauge(&mut out, "latency_p95_seconds", m.latency.percentile(95.0));
        gauge(&mut out, "latency_p99_seconds", m.latency.percentile(99.0));
        gauge(&mut out, "busy_seconds", m.busy_secs);

        let _ = writeln!(out, "# TYPE sdllm_queue_depth gauge");
        for &(name, queued, _) in &m.group_depth {
            let _ = writeln!(out, "sdllm_queue_depth{{method=\"{name}\"}} {queued}");
        }
        let _ = writeln!(out, "# TYPE sdllm_active_rows gauge");
        for &(name, _, active) in &m.group_depth {
            let _ = writeln!(out, "sdllm_active_rows{{method=\"{name}\"}} {active}");
        }
        let _ = writeln!(out, "# TYPE sdllm_worker_outstanding gauge");
        for (i, w) in m.workers.iter().enumerate() {
            let _ = writeln!(out, "sdllm_worker_outstanding{{worker=\"{i}\"}} {}", w.outstanding);
        }
        let _ = writeln!(out, "# TYPE sdllm_worker_capacity gauge");
        for (i, w) in m.workers.iter().enumerate() {
            let _ = writeln!(out, "sdllm_worker_capacity{{worker=\"{i}\"}} {}", w.capacity);
        }
        let _ = writeln!(out, "# TYPE sdllm_worker_up gauge");
        for (i, w) in m.workers.iter().enumerate() {
            let state = if w.dead {
                "dead"
            } else if w.ready {
                "ready"
            } else {
                "starting"
            };
            let up = u8::from(!w.dead);
            let _ = writeln!(out, "sdllm_worker_up{{worker=\"{i}\",state=\"{state}\"}} {up}");
        }
        out.push_str("# EOF\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_percentiles() {
        let m = Metrics::new();
        m.start_clock();
        m.record_batch(4);
        for i in 0..10 {
            m.record_response(true, 10, 0.1 * (i + 1) as f64, 0.01);
        }
        m.record_response(false, 0, 1.0, 0.0);
        let s = m.snapshot();
        assert_eq!(s.get("requests_ok").unwrap().as_usize(), Some(10));
        assert_eq!(s.get("requests_err").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("non_eos_tokens").unwrap().as_usize(), Some(100));
        assert!(s.get("latency_p95_s").unwrap().as_f64().unwrap() >= 0.9);
        assert_eq!(s.get("mean_batch_size").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn joins_and_engine_phases_accumulate() {
        let m = Metrics::new();
        m.record_join();
        m.record_join();
        let report = GenReport {
            steps: 40,
            prefills: 8,
            blocks_skipped: 3,
            prefill_secs: 0.25,
            decode_secs: 0.5,
            host_secs: 0.125,
            init_prefill_secs: 0.2,
            reprefill_secs: 0.05,
            init_prefills: 6,
            reprefills: 2,
            ..Default::default()
        };
        m.record_engine(&report, 8, 3);
        m.record_engine(&report, 8, 2);
        let s = m.snapshot();
        assert_eq!(s.get("joins").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("engine_rounds").unwrap().as_usize(), Some(16));
        assert_eq!(s.get("mixed_len_rounds").unwrap().as_usize(), Some(5));
        assert_eq!(s.get("engine_steps").unwrap().as_usize(), Some(80));
        assert_eq!(s.get("engine_blocks_skipped").unwrap().as_usize(), Some(6));
        assert!((s.get("prefill_s").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
        assert!((s.get("host_s").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
        // the phase split accumulates alongside the total and the two
        // causes sum back to it
        assert!((s.get("init_prefill_s").unwrap().as_f64().unwrap() - 0.4).abs() < 1e-9);
        assert!((s.get("reprefill_s").unwrap().as_f64().unwrap() - 0.1).abs() < 1e-9);
        assert_eq!(s.get("init_prefills").unwrap().as_usize(), Some(12));
        assert_eq!(s.get("reprefills").unwrap().as_usize(), Some(4));
        assert_eq!(s.get("engine_prefills").unwrap().as_usize(), Some(16));
    }

    #[test]
    fn prefix_cache_stats_and_dedup_surface_in_snapshot() {
        let m = Metrics::new();
        m.record_prefix_dedup(3);
        m.record_prefix_dedup(2);
        m.set_prefix_cache(PrefixCacheStats {
            lookups: 10,
            hits: 4,
            partial_hits: 1,
            misses: 5,
            inserts: 5,
            evictions: 2,
            bytes: 4096,
            nodes: 7,
            entries: 3,
            reused_tokens: 512,
            saved_prefill_secs: 0.125,
        });
        let s = m.snapshot();
        assert_eq!(s.get("prefix_dedup_rows").unwrap().as_usize(), Some(5));
        let pc = s.get("prefix_cache").unwrap();
        assert_eq!(pc.get("lookups").unwrap().as_usize(), Some(10));
        assert_eq!(pc.get("hits").unwrap().as_usize(), Some(4));
        assert_eq!(pc.get("partial_hits").unwrap().as_usize(), Some(1));
        assert_eq!(pc.get("misses").unwrap().as_usize(), Some(5));
        assert_eq!(pc.get("evictions").unwrap().as_usize(), Some(2));
        assert_eq!(pc.get("bytes").unwrap().as_usize(), Some(4096));
        assert_eq!(pc.get("entries").unwrap().as_usize(), Some(3));
        assert_eq!(pc.get("reused_tokens").unwrap().as_usize(), Some(512));
        assert!((pc.get("saved_prefill_s").unwrap().as_f64().unwrap() - 0.125).abs() < 1e-9);
        // set_prefix_cache replaces (gauge semantics), never accumulates
        m.set_prefix_cache(PrefixCacheStats::default());
        let s = m.snapshot();
        assert_eq!(s.get("prefix_cache").unwrap().get("lookups").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn admission_conservation_and_gauges() {
        let m = Metrics::new();
        m.record_batch_admit();
        m.record_admission();
        m.record_join();
        m.record_admission();
        m.record_deadline_miss();
        m.set_groups(vec![("streaming", 3, 2), ("vanilla", 1, 0)], 2);
        m.set_groups(vec![("streaming", 0, 1)], 1);
        let s = m.snapshot();
        assert_eq!(s.get("admissions").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("batch_started").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("joins").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("deadline_misses").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("engines_active").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("max_engines_active").unwrap().as_usize(), Some(2));
        let depth = s.get("group_depth").unwrap();
        assert_eq!(depth.get("streaming").unwrap().get("queued").unwrap().as_usize(), Some(0));
        assert_eq!(depth.get("streaming").unwrap().get("active").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn overload_counters_and_worker_gauges() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_submitted();
        }
        m.record_answered();
        m.record_answered();
        m.record_rejected();
        m.record_shed();
        m.record_cancelled();
        m.note_queue_depth(3);
        m.note_queue_depth(7);
        m.note_queue_depth(2); // peak is a high-water mark
        m.set_workers(vec![
            WorkerGauge {
                outstanding: 2,
                capacity: 4,
                assigned: Some("streaming"),
                ready: true,
                dead: false,
            },
            WorkerGauge::default(),
        ]);
        let s = m.snapshot();
        assert_eq!(s.get("submitted").unwrap().as_usize(), Some(5));
        assert_eq!(s.get("answered").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("rejected").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("shed").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("cancelled").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("queue_depth_peak").unwrap().as_usize(), Some(7));
        let workers = s.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].get("outstanding").unwrap().as_usize(), Some(2));
        assert_eq!(workers[0].get("capacity").unwrap().as_usize(), Some(4));
        assert_eq!(workers[0].get("assigned").unwrap().as_str(), Some("streaming"));
        assert_eq!(workers[0].get("ready").unwrap().as_bool(), Some(true));
        assert!(matches!(workers[1].get("assigned"), Some(Json::Null)));
    }

    #[test]
    fn prometheus_text_is_typed_labeled_and_terminated() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_rejected();
        m.set_groups(vec![("streaming", 3, 2)], 1);
        m.set_workers(vec![WorkerGauge {
            outstanding: 2,
            capacity: 4,
            assigned: Some("streaming"),
            ready: true,
            dead: false,
        }]);
        let text = m.prometheus();
        assert!(text.contains("# TYPE sdllm_submitted counter\nsdllm_submitted 1\n"));
        assert!(text.contains("# TYPE sdllm_rejected counter\nsdllm_rejected 1\n"));
        assert!(text.contains("sdllm_queue_depth{method=\"streaming\"} 3\n"));
        assert!(text.contains("sdllm_active_rows{method=\"streaming\"} 2\n"));
        assert!(text.contains("sdllm_worker_outstanding{worker=\"0\"} 2\n"));
        assert!(text.contains("sdllm_worker_capacity{worker=\"0\"} 4\n"));
        assert!(text.contains("sdllm_worker_up{worker=\"0\",state=\"ready\"} 1\n"));
        assert!(
            text.ends_with("# EOF\n"),
            "the text body must end with the on-wire terminator"
        );
        // every non-comment line belongs to a preceding # TYPE family
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.starts_with("sdllm_"), "unprefixed line: {line}");
        }
    }

    #[test]
    fn prometheus_emits_prefix_cache_families_at_zero_traffic() {
        // a freshly started router (no requests, cache untouched, even
        // cache disabled) must still expose every cache family with its
        // # TYPE line, so scrapers see stable schemas
        let m = Metrics::new();
        let text = m.prometheus();
        for family in [
            "# TYPE sdllm_prefix_cache_lookups counter\nsdllm_prefix_cache_lookups 0\n",
            "# TYPE sdllm_prefix_cache_hits counter\nsdllm_prefix_cache_hits 0\n",
            "# TYPE sdllm_prefix_cache_partial_hits counter\nsdllm_prefix_cache_partial_hits 0\n",
            "# TYPE sdllm_prefix_cache_misses counter\nsdllm_prefix_cache_misses 0\n",
            "# TYPE sdllm_prefix_cache_inserts counter\nsdllm_prefix_cache_inserts 0\n",
            "# TYPE sdllm_prefix_cache_evictions counter\nsdllm_prefix_cache_evictions 0\n",
            "# TYPE sdllm_prefix_reused_tokens counter\nsdllm_prefix_reused_tokens 0\n",
            "# TYPE sdllm_prefix_dedup_rows counter\nsdllm_prefix_dedup_rows 0\n",
            "# TYPE sdllm_init_prefills counter\nsdllm_init_prefills 0\n",
            "# TYPE sdllm_reprefills counter\nsdllm_reprefills 0\n",
            "# TYPE sdllm_prefix_cache_bytes gauge\nsdllm_prefix_cache_bytes 0\n",
            "# TYPE sdllm_prefix_cache_nodes gauge\nsdllm_prefix_cache_nodes 0\n",
            "# TYPE sdllm_prefix_cache_entries gauge\nsdllm_prefix_cache_entries 0\n",
            "# TYPE sdllm_prefix_saved_prefill_seconds gauge\nsdllm_prefix_saved_prefill_seconds 0\n",
            "# TYPE sdllm_init_prefill_seconds gauge\nsdllm_init_prefill_seconds 0\n",
            "# TYPE sdllm_reprefill_seconds gauge\nsdllm_reprefill_seconds 0\n",
        ] {
            assert!(text.contains(family), "missing zero-traffic family:\n{family}");
        }
        // and once stats land, the numbers follow
        m.set_prefix_cache(PrefixCacheStats { hits: 7, bytes: 64, ..Default::default() });
        m.record_prefix_dedup(4);
        let text = m.prometheus();
        assert!(text.contains("sdllm_prefix_cache_hits 7\n"));
        assert!(text.contains("sdllm_prefix_cache_bytes 64\n"));
        assert!(text.contains("sdllm_prefix_dedup_rows 4\n"));
    }

    #[test]
    fn busy_time_and_parked_accumulate() {
        let m = Metrics::new();
        m.record_busy("streaming", 0.5);
        m.record_busy("vanilla", 0.25);
        m.record_busy("streaming", 0.5);
        m.record_parked();
        let s = m.snapshot();
        assert!((s.get("busy_s").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-9);
        let by = s.get("busy_by_method").unwrap();
        assert!((by.get("streaming").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((by.get("vanilla").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
        assert_eq!(s.get("parked").unwrap().as_usize(), Some(1));
    }
}
