//! L3 coordinator: admission, dynamic batching, the engine thread that
//! owns the PJRT runtime, the TCP server and a load-generating client.

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{Batcher, DEFAULT_SLA};
pub use client::{run_load, Client, LoadReport};
pub use metrics::Metrics;
pub use request::{Request, Response};
pub use router::{Job, Msg, RouterHandle};
pub use server::Server;
