//! L3 coordinator: admission, dynamic batching, per-engine worker
//! threads (each owning its backend), the pure-scheduler router, the
//! versioned wire protocol, the TCP server and a load-generating
//! client.

pub mod batcher;
pub mod client;
pub mod config;
pub mod metrics;
pub mod protocol;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use batcher::{shared_prefix_rows, Batcher, DEDUP_MIN_PREFIX, DEFAULT_SLA};
pub use client::{run_load, Client, LoadReport, ServerFrame};
pub use config::ServeConfig;
pub use metrics::{Metrics, WorkerGauge};
pub use protocol::{
    parse_client_line, ClientFrame, CommitEvent, StatsFormat, WireError, PROTOCOL_VERSION,
};
pub use request::{Request, RequestError, Response};
pub use router::{
    Job, Msg, ReplyTx, RouterHandle, RouterOptions, StreamFrame, DEFAULT_MAX_ENGINES,
    DEFAULT_MAX_QUEUE_DEPTH, DEFAULT_PREFIX_CACHE_BYTES,
};
pub use server::{Server, DEFAULT_MAX_CONNECTIONS, MAX_LINE_BYTES};
pub use worker::{AdmitReq, RowDone, WorkerCmd, WorkerEvent};
