//! Wire protocol: every (de)serialization of the line-delimited JSON
//! surface lives here — nothing in `request.rs`/`server.rs` touches
//! bytes.
//!
//! Two generations coexist on the same port:
//!
//! - **v0 (legacy)**: bare request objects (`{"id":..,"prompt":[..]}`),
//!   `{"cmd":"stats"}` / `{"cmd":"ping"}` control lines, flat response
//!   objects, and bare `{"error":..}` lines *without* an id. Any line
//!   with no `"v"` key parses as v0 and is answered in v0 shapes, so
//!   old clients keep working byte-for-byte.
//! - **v1 (versioned envelope)**: `{"v":1,"type":...}` plus the same
//!   flat fields. Types from clients: `generate`, `subscribe`, `stats`,
//!   `ping`; from the server: `done`, `commit`, `stats`, `pong`,
//!   `error`. `subscribe` is v1-only — it opens a per-request stream of
//!   out-of-order [`CommitEvent`] frames (the committed canvas
//!   frontier) terminated by a `done` frame.

use std::collections::BTreeMap;
use std::fmt;

use crate::engine::{DecodePolicy, SpatialPolicy, TemporalPolicy};
use crate::util::json::Json;

use super::request::{Request, RequestError, Response};

/// Current envelope version. Lines carrying any other `"v"` are
/// rejected with a versioned error frame.
pub const PROTOCOL_VERSION: u64 = 1;

/// Wrap a flat object body in the v1 envelope (insert `v` + `type`).
fn with_envelope(ty: &str, body: Json) -> Json {
    let mut m = match body {
        Json::Obj(m) => m,
        other => {
            let mut m = BTreeMap::new();
            m.insert("body".to_string(), other);
            m
        }
    };
    m.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    m.insert("type".to_string(), Json::Str(ty.to_string()));
    Json::Obj(m)
}

// ---------------------------------------------------------------------
// Decode-policy wire form
// ---------------------------------------------------------------------

/// Wire form of a decode policy: the canonical preset name when the
/// policy is one (`"streaming"`, `"attenuating"`, …), otherwise the
/// explicit two-axis object
/// `{"spatial":{"kind":…},"temporal":{"kind":…}}`.
pub fn policy_to_json(p: &DecodePolicy) -> Json {
    if let Some(name) = p.name() {
        return Json::Str(name.to_string());
    }
    let spatial = match p.spatial {
        SpatialPolicy::FullSuffix => Json::obj(vec![("kind", Json::Str("full".to_string()))]),
        SpatialPolicy::Window { window, trailing } => Json::obj(vec![
            ("kind", Json::Str("window".to_string())),
            ("window", Json::Num(window as f64)),
            ("trailing", Json::Bool(trailing)),
        ]),
        SpatialPolicy::Attenuating { window, min_window, trailing } => Json::obj(vec![
            ("kind", Json::Str("attenuating".to_string())),
            ("window", Json::Num(window as f64)),
            ("min_window", Json::Num(min_window as f64)),
            ("trailing", Json::Bool(trailing)),
        ]),
        SpatialPolicy::Dropout { window, stride, seed, trailing } => Json::obj(vec![
            ("kind", Json::Str("dropout".to_string())),
            ("window", Json::Num(window as f64)),
            ("stride", Json::Num(stride as f64)),
            // seeds round-trip exactly up to 2^53 (JSON numbers)
            ("seed", Json::Num(seed as f64)),
            ("trailing", Json::Bool(trailing)),
        ]),
    };
    let temporal = match p.temporal {
        TemporalPolicy::OnePerStep => {
            Json::obj(vec![("kind", Json::Str("one-per-step".to_string()))])
        }
        TemporalPolicy::FixedTau { tau } => Json::obj(vec![
            ("kind", Json::Str("fixed".to_string())),
            ("tau", Json::Num(tau as f64)),
        ]),
        TemporalPolicy::DynamicTau { tau0, alpha } => Json::obj(vec![
            ("kind", Json::Str("dynamic".to_string())),
            ("tau0", Json::Num(tau0 as f64)),
            ("alpha", Json::Num(alpha as f64)),
        ]),
        TemporalPolicy::Extrapolating { tau0, alpha, gain, floor, min_streak } => Json::obj(vec![
            ("kind", Json::Str("extrapolating".to_string())),
            ("tau0", Json::Num(tau0 as f64)),
            ("alpha", Json::Num(alpha as f64)),
            ("gain", Json::Num(gain as f64)),
            ("floor", Json::Num(floor as f64)),
            ("min_streak", Json::Num(min_streak as f64)),
        ]),
    };
    Json::obj(vec![("spatial", spatial), ("temporal", temporal)])
}

fn bad_policy(msg: impl Into<String>) -> RequestError {
    RequestError::InvalidPolicy(msg.into())
}

fn policy_usize(o: &Json, key: &'static str) -> Result<usize, RequestError> {
    o.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| bad_policy(format!("{key} must be a non-negative integer")))
}

fn policy_f32(o: &Json, key: &'static str) -> Result<f32, RequestError> {
    o.get(key)
        .and_then(|v| v.as_f64())
        .map(|v| v as f32)
        .ok_or_else(|| bad_policy(format!("{key} must be a number")))
}

fn policy_trailing(o: &Json) -> bool {
    o.get("trailing").and_then(|v| v.as_bool()).unwrap_or(true)
}

/// Parse a wire policy: a string names a preset
/// ([`RequestError::UnknownPolicy`] otherwise); an object selects the
/// two axes explicitly and is validated before acceptance
/// ([`RequestError::InvalidPolicy`] on shape or range problems).
pub fn policy_from_json(j: &Json) -> Result<DecodePolicy, RequestError> {
    if let Some(name) = j.as_str() {
        return DecodePolicy::parse(name)
            .ok_or_else(|| RequestError::UnknownPolicy(name.to_string()));
    }
    let (sj, tj) = match (j.get("spatial"), j.get("temporal")) {
        (Some(s), Some(t)) => (s, t),
        _ => return Err(bad_policy("expected a preset name or {spatial, temporal} object")),
    };
    let spatial = match sj.get("kind").and_then(|k| k.as_str()) {
        Some("full") => SpatialPolicy::FullSuffix,
        Some("window") => SpatialPolicy::Window {
            window: policy_usize(sj, "window")?,
            trailing: policy_trailing(sj),
        },
        Some("attenuating") => SpatialPolicy::Attenuating {
            window: policy_usize(sj, "window")?,
            min_window: policy_usize(sj, "min_window")?,
            trailing: policy_trailing(sj),
        },
        Some("dropout") => SpatialPolicy::Dropout {
            window: policy_usize(sj, "window")?,
            stride: policy_usize(sj, "stride")?,
            seed: sj.get("seed").and_then(|v| v.as_i64()).unwrap_or(0).max(0) as u64,
            trailing: policy_trailing(sj),
        },
        Some(other) => return Err(bad_policy(format!("unknown spatial kind '{other}'"))),
        None => return Err(bad_policy("spatial kind missing")),
    };
    let temporal = match tj.get("kind").and_then(|k| k.as_str()) {
        Some("one-per-step") => TemporalPolicy::OnePerStep,
        Some("fixed") => TemporalPolicy::FixedTau { tau: policy_f32(tj, "tau")? },
        Some("dynamic") => TemporalPolicy::DynamicTau {
            tau0: policy_f32(tj, "tau0")?,
            alpha: policy_f32(tj, "alpha")?,
        },
        Some("extrapolating") => TemporalPolicy::Extrapolating {
            tau0: policy_f32(tj, "tau0")?,
            alpha: policy_f32(tj, "alpha")?,
            gain: policy_f32(tj, "gain")?,
            floor: policy_f32(tj, "floor")?,
            min_streak: policy_usize(tj, "min_streak")? as u32,
        },
        Some(other) => return Err(bad_policy(format!("unknown temporal kind '{other}'"))),
        None => return Err(bad_policy("temporal kind missing")),
    };
    let p = DecodePolicy { spatial, temporal };
    p.validate().map_err(RequestError::InvalidPolicy)?;
    Ok(p)
}

// ---------------------------------------------------------------------
// Request / Response wire forms (v0 flat objects; v1 adds the envelope)
// ---------------------------------------------------------------------

impl Request {
    /// v0 flat object. Optional fields are omitted when default so the
    /// legacy bytes are unchanged for legacy requests.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("prompt", Json::Arr(self.prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("method", Json::Str(self.method.name().to_string())),
            ("gen_len", Json::Num(self.gen_len as f64)),
        ];
        if let Some(p) = &self.policy {
            fields.push(("policy", policy_to_json(p)));
        }
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::Num(d as f64)));
        }
        if self.park_on_miss {
            fields.push(("park_on_miss", Json::Bool(true)));
        }
        Json::obj(fields)
    }

    /// Parse the flat fields (v0 bare object, or a v1 envelope — the
    /// extra `v`/`type` keys are simply ignored) through the validating
    /// builder.
    pub fn from_json(j: &Json) -> Result<Request, RequestError> {
        let mut b = Request::builder();
        if let Some(id) = j.get("id").and_then(|v| v.as_i64()) {
            b = b.id(id as u64);
        }
        let prompt: Vec<i32> = j
            .get("prompt")
            .and_then(|v| v.as_arr())
            .ok_or(RequestError::MissingField("prompt"))?
            .iter()
            .map(|x| x.as_i64().unwrap_or(0) as i32)
            .collect();
        b = b.prompt(prompt);
        if let Some(m) = j.get("method").and_then(|v| v.as_str()) {
            b = b.method_name(m);
        }
        if let Some(pj) = j.get("policy") {
            b = b.policy(policy_from_json(pj)?);
        }
        if let Some(g) = j.get("gen_len").and_then(|v| v.as_usize()) {
            b = b.gen_len(g);
        }
        if let Some(d) = j.get("deadline_ms").and_then(|v| v.as_i64()) {
            // negative values clamp to zero (immediately due)
            b = b.deadline_ms(d.max(0) as u64);
        }
        if let Some(p) = j.get("park_on_miss").and_then(|v| v.as_bool()) {
            b = b.park_on_miss(p);
        }
        b.build()
    }

    /// v1 envelope carrying this request (`ty` is `"generate"` or
    /// `"subscribe"`).
    pub fn to_frame(&self, ty: &str) -> Json {
        with_envelope(ty, self.to_json())
    }
}

impl Response {
    /// v0 flat object. Terminal states ride as `"state":"parked"` /
    /// `"rejected"` / `"shed"` and are omitted otherwise, so ordinary
    /// legacy responses are byte-identical to the pre-v1 wire; rejects
    /// additionally carry `retry_after_ms`.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            ("non_eos_tokens", Json::Num(self.non_eos_tokens as f64)),
            ("latency_s", Json::Num(self.latency_s)),
            ("queue_s", Json::Num(self.queue_s)),
        ];
        if self.parked {
            fields.push(("state", Json::Str("parked".to_string())));
        } else if self.rejected {
            fields.push(("state", Json::Str("rejected".to_string())));
        } else if self.shed {
            fields.push(("state", Json::Str("shed".to_string())));
        }
        if let Some(ms) = self.retry_after_ms {
            fields.push(("retry_after_ms", Json::Num(ms as f64)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        Json::obj(fields)
    }

    /// Parse the flat fields of either generation (extra envelope keys
    /// are ignored).
    pub fn from_json(j: &Json) -> Result<Response, RequestError> {
        let state = j.get("state").and_then(|v| v.as_str());
        Ok(Response {
            id: j.get("id").and_then(|v| v.as_i64()).ok_or(RequestError::MissingField("id"))?
                as u64,
            text: j.get("text").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            non_eos_tokens: j.get("non_eos_tokens").and_then(|v| v.as_usize()).unwrap_or(0),
            latency_s: j.get("latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            queue_s: j.get("queue_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            parked: state == Some("parked"),
            rejected: state == Some("rejected"),
            shed: state == Some("shed"),
            retry_after_ms: j
                .get("retry_after_ms")
                .and_then(|v| v.as_i64())
                .map(|v| v.max(0) as u64),
            error: j.get("error").and_then(|v| v.as_str()).map(|s| s.to_string()),
        })
    }
}

// ---------------------------------------------------------------------
// Commit events (v1-only server frames on a subscribe stream)
// ---------------------------------------------------------------------

/// One committed-canvas delta for a subscribed row, as shipped on the
/// wire: applying the `writes` of events in `seq` order onto an
/// all-mask canvas rebuilds the generation region exactly — including
/// out-of-order confidence commits, early-exit EOS fills and remask
/// retractions (confidence 0, token back to mask).
#[derive(Debug, Clone, PartialEq)]
pub struct CommitEvent {
    pub id: u64,
    /// per-row sequence number, gapless from 0
    pub seq: u64,
    /// the row's block cursor when the delta was captured
    pub block: usize,
    /// (generation-region offset, new token, commit confidence)
    pub writes: Vec<(usize, i32, f32)>,
}

impl CommitEvent {
    pub fn to_json(&self) -> Json {
        with_envelope(
            "commit",
            Json::obj(vec![
                ("id", Json::Num(self.id as f64)),
                ("seq", Json::Num(self.seq as f64)),
                ("block", Json::Num(self.block as f64)),
                (
                    "writes",
                    Json::Arr(
                        self.writes
                            .iter()
                            .map(|&(off, tok, conf)| {
                                Json::Arr(vec![
                                    Json::Num(off as f64),
                                    Json::Num(tok as f64),
                                    Json::Num(conf as f64),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        )
    }

    pub fn from_json(j: &Json) -> Result<CommitEvent, RequestError> {
        let writes = j
            .get("writes")
            .and_then(|v| v.as_arr())
            .ok_or(RequestError::MissingField("writes"))?
            .iter()
            .map(|w| {
                let t = w.as_arr().unwrap_or(&[]);
                (
                    t.first().and_then(|x| x.as_usize()).unwrap_or(0),
                    t.get(1).and_then(|x| x.as_i64()).unwrap_or(0) as i32,
                    t.get(2).and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                )
            })
            .collect();
        Ok(CommitEvent {
            id: j.get("id").and_then(|v| v.as_i64()).ok_or(RequestError::MissingField("id"))?
                as u64,
            seq: j.get("seq").and_then(|v| v.as_i64()).ok_or(RequestError::MissingField("seq"))?
                as u64,
            block: j.get("block").and_then(|v| v.as_usize()).unwrap_or(0),
            writes,
        })
    }
}

// ---------------------------------------------------------------------
// Client-line parsing (both generations) and server frame builders
// ---------------------------------------------------------------------

/// Requested rendering of the `stats` endpoint: the JSON snapshot
/// (default, both generations) or the scrapeable Prometheus-style text
/// body terminated by a literal `# EOF` line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsFormat {
    Json,
    Prometheus,
}

impl StatsFormat {
    /// Parse the optional `"format"` key of a stats line. Absent →
    /// JSON; `"prometheus"`/`"text"` → the text rendering; anything
    /// else is a protocol error.
    fn parse(j: &Json) -> Result<StatsFormat, String> {
        match j.get("format").and_then(|f| f.as_str()) {
            None | Some("json") => Ok(StatsFormat::Json),
            Some("prometheus") | Some("text") => Ok(StatsFormat::Prometheus),
            Some(other) => Err(format!("unknown stats format '{other}'")),
        }
    }
}

/// A parsed client line. `v` records which generation the line spoke so
/// the reply can match it.
#[derive(Debug)]
pub enum ClientFrame {
    Generate { v: u64, request: Request },
    /// v1-only: generate with a streaming commit-event subscription.
    Subscribe { request: Request },
    Stats { v: u64, format: StatsFormat },
    Ping { v: u64 },
}

/// A protocol-level error plus the generation (and, for v1, the request
/// id when one was parseable) to shape the error frame with.
#[derive(Debug, Clone)]
pub struct WireError {
    pub v: u64,
    pub id: Option<u64>,
    pub msg: String,
}

/// Parse one client line: a `"v"` key selects the v1 envelope, a
/// `"cmd"` key the legacy control lines, anything else a legacy bare
/// request.
pub fn parse_client_line(line: &str) -> Result<ClientFrame, WireError> {
    let j = Json::parse(line).map_err(|e| WireError { v: 0, id: None, msg: format!("{e}") })?;
    if let Some(v) = j.get("v").and_then(|v| v.as_i64()) {
        let id = j.get("id").and_then(|x| x.as_i64()).map(|x| x as u64);
        if v != PROTOCOL_VERSION as i64 {
            return Err(WireError { v: 1, id, msg: format!("unsupported protocol version {v}") });
        }
        let ty = j.get("type").and_then(|t| t.as_str()).unwrap_or("");
        match ty {
            "generate" => Request::from_json(&j)
                .map(|request| ClientFrame::Generate { v: 1, request })
                .map_err(|e| WireError { v: 1, id, msg: e.to_string() }),
            "subscribe" => Request::from_json(&j)
                .map(|request| ClientFrame::Subscribe { request })
                .map_err(|e| WireError { v: 1, id, msg: e.to_string() }),
            "stats" => StatsFormat::parse(&j)
                .map(|format| ClientFrame::Stats { v: 1, format })
                .map_err(|msg| WireError { v: 1, id, msg }),
            "ping" => Ok(ClientFrame::Ping { v: 1 }),
            other => Err(WireError { v: 1, id, msg: format!("unknown type '{other}'") }),
        }
    } else if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
        match cmd {
            "stats" => StatsFormat::parse(&j)
                .map(|format| ClientFrame::Stats { v: 0, format })
                .map_err(|msg| WireError { v: 0, id: None, msg }),
            "ping" => Ok(ClientFrame::Ping { v: 0 }),
            other => Err(WireError { v: 0, id: None, msg: format!("unknown cmd '{other}'") }),
        }
    } else {
        Request::from_json(&j)
            .map(|request| ClientFrame::Generate { v: 0, request })
            .map_err(|e| WireError { v: 0, id: None, msg: e.to_string() })
    }
}

/// Health-check reply in the requested generation.
pub fn pong_frame(v: u64) -> Json {
    let body = Json::obj(vec![("pong", Json::Bool(true))]);
    if v == 0 {
        body
    } else {
        with_envelope("pong", body)
    }
}

/// Metrics snapshot: raw in v0 (legacy bytes), wrapped under `"stats"`
/// in the v1 envelope.
pub fn stats_frame(v: u64, snapshot: Json) -> Json {
    if v == 0 {
        snapshot
    } else {
        with_envelope("stats", Json::obj(vec![("stats", snapshot)]))
    }
}

/// Terminal response: the flat v0 object, or a v1 `done` envelope.
pub fn response_frame(v: u64, resp: &Response) -> Json {
    if v == 0 {
        resp.to_json()
    } else {
        with_envelope("done", resp.to_json())
    }
}

/// Backpressure reject: the flat response (with `"state":"rejected"`
/// and `retry_after_ms`) in v0 — legacy clients see it as an answered
/// request — or a dedicated v1 `reject` envelope.
pub fn reject_frame(v: u64, resp: &Response) -> Json {
    if v == 0 {
        resp.to_json()
    } else {
        with_envelope("reject", resp.to_json())
    }
}

/// Connection-level busy error, sent (and the socket closed) when the
/// server is at `max_connections`. Always the v1 error envelope — the
/// connection never got to speak a generation, and the `busy:` prefix
/// is the machine-matchable discriminator.
pub fn busy_frame(max_connections: usize) -> Json {
    error_frame(1, None, &format!("busy: connection limit {max_connections} reached"))
}

/// Error frame. v0 is exactly `{"error":msg}` with **no id** — legacy
/// clients distinguish protocol errors from failed requests by the
/// missing id, so that shape is load-bearing. v1 carries the id when
/// one was parsed.
pub fn error_frame(v: u64, id: Option<u64>, msg: &str) -> Json {
    if v == 0 {
        return Json::obj(vec![("error", Json::Str(msg.to_string()))]);
    }
    let mut fields = vec![("error", Json::Str(msg.to_string()))];
    if let Some(id) = id {
        fields.push(("id", Json::Num(id as f64)));
    }
    with_envelope("error", Json::obj(fields))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Method;

    #[test]
    fn request_roundtrip_v0() {
        let r = Request::builder()
            .id(7)
            .prompt(vec![2, 10, 11])
            .method(Method::Streaming)
            .gen_len(64)
            .build()
            .unwrap();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = Request::from_json(&j).unwrap();
        assert_eq!(r2.id, 7);
        assert_eq!(r2.prompt, vec![2, 10, 11]);
        assert_eq!(r2.method, Method::Streaming);
        assert_eq!(r2.gen_len, 64);
        assert_eq!(r2.deadline_ms, None);
        assert!(!r2.park_on_miss);
    }

    #[test]
    fn request_roundtrip_v1_envelope() {
        let r = Request::builder()
            .id(9)
            .prompt(vec![2, 5])
            .method(Method::Vanilla)
            .gen_len(32)
            .deadline_ms(250)
            .park_on_miss(true)
            .build()
            .unwrap();
        let line = r.to_frame("generate").to_string();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("v").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("type").unwrap().as_str(), Some("generate"));
        let r2 = Request::from_json(&j).unwrap();
        assert_eq!(r2.id, 9);
        assert_eq!(r2.method, Method::Vanilla);
        assert_eq!(r2.deadline_ms, Some(250));
        assert!(r2.park_on_miss);
    }

    #[test]
    fn deadline_roundtrip_and_default() {
        let j = Json::parse("{\"id\":1,\"prompt\":[2]}").unwrap();
        assert_eq!(Request::from_json(&j).unwrap().deadline_ms, None);
        // negative values clamp to zero
        let j = Json::parse("{\"id\":1,\"prompt\":[2],\"deadline_ms\":-5}").unwrap();
        assert_eq!(Request::from_json(&j).unwrap().deadline_ms, Some(0));
    }

    #[test]
    fn response_roundtrip_with_error_and_parked() {
        let r = Response {
            id: 1,
            text: "a9;81".into(),
            non_eos_tokens: 5,
            latency_s: 0.25,
            queue_s: 0.01,
            parked: false,
            rejected: false,
            shed: false,
            retry_after_ms: None,
            error: Some("boom".into()),
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = Response::from_json(&j).unwrap();
        assert_eq!(r2.error.as_deref(), Some("boom"));
        assert_eq!(r2.text, "a9;81");
        assert!(!r2.parked);

        let parked = Response { parked: true, error: None, ..r };
        let j = Json::parse(&parked.to_json().to_string()).unwrap();
        assert_eq!(j.get("state").unwrap().as_str(), Some("parked"));
        assert!(Response::from_json(&j).unwrap().parked);
    }

    #[test]
    fn rejects_bad_requests_with_typed_errors() {
        let e = Request::from_json(&Json::parse("{\"id\":1}").unwrap()).unwrap_err();
        assert_eq!(e, RequestError::MissingField("prompt"));
        let e = Request::from_json(&Json::parse("{\"id\":1,\"prompt\":[]}").unwrap()).unwrap_err();
        assert_eq!(e, RequestError::EmptyPrompt);
        let e = Request::from_json(
            &Json::parse("{\"id\":1,\"prompt\":[2],\"method\":\"bogus\"}").unwrap(),
        )
        .unwrap_err();
        assert_eq!(e, RequestError::UnknownMethod("bogus".into()));
        let e = Request::from_json(&Json::parse("{\"id\":1,\"prompt\":[2],\"gen_len\":9}").unwrap())
            .unwrap_err();
        assert!(matches!(e, RequestError::MisalignedGenLen { gen_len: 9, .. }));
    }

    #[test]
    fn policy_field_roundtrips_as_preset_name() {
        let r = Request::builder()
            .id(4)
            .prompt(vec![2])
            .policy_name("attenuating")
            .build()
            .unwrap();
        let line = r.to_json().to_string();
        assert!(line.contains("\"policy\":\"attenuating\""), "{line}");
        let r2 = Request::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(r2.policy, Some(DecodePolicy::parse("attenuating").unwrap()));
        assert_eq!(r2.group_key(), r.group_key());
    }

    #[test]
    fn policy_field_roundtrips_as_object() {
        // a non-preset combination encodes as the explicit two-axis
        // object and survives the round trip bit-for-bit
        let p = DecodePolicy {
            spatial: crate::engine::SpatialPolicy::Dropout {
                window: 12,
                stride: 3,
                seed: 77,
                trailing: false,
            },
            temporal: crate::engine::TemporalPolicy::Extrapolating {
                tau0: 0.85,
                alpha: 0.25,
                gain: 2.0,
                floor: 0.75,
                min_streak: 3,
            },
        };
        assert_eq!(p.name(), None);
        let j = policy_to_json(&p);
        assert_eq!(policy_from_json(&j).unwrap(), p);
        let r = Request::builder().id(5).prompt(vec![2]).policy(p).build().unwrap();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(Request::from_json(&j).unwrap().policy, Some(p));
    }

    #[test]
    fn legacy_request_bytes_unchanged_without_policy() {
        let r = Request::builder().id(7).prompt(vec![2, 10]).build().unwrap();
        let line = r.to_json().to_string();
        assert!(!line.contains("policy"), "legacy bytes must not grow a policy field: {line}");
    }

    #[test]
    fn malformed_policies_are_typed_errors() {
        let j = Json::parse("{\"id\":1,\"prompt\":[2],\"policy\":\"bogus\"}").unwrap();
        assert_eq!(
            Request::from_json(&j).unwrap_err(),
            RequestError::UnknownPolicy("bogus".into())
        );
        let j = Json::parse("{\"id\":1,\"prompt\":[2],\"policy\":42}").unwrap();
        assert!(matches!(Request::from_json(&j).unwrap_err(), RequestError::InvalidPolicy(_)));
        let j = Json::parse(
            "{\"policy\":{\"spatial\":{\"kind\":\"warp\"},\"temporal\":{\"kind\":\"fixed\",\"tau\":0.9}}}",
        )
        .unwrap();
        let e = policy_from_json(j.get("policy").unwrap()).unwrap_err();
        assert_eq!(e.to_string(), "invalid policy: unknown spatial kind 'warp'");
        // shape is right but the parameters are out of range
        let j = Json::parse(
            "{\"spatial\":{\"kind\":\"full\"},\"temporal\":{\"kind\":\"fixed\",\"tau\":1.5}}",
        )
        .unwrap();
        assert!(matches!(policy_from_json(&j).unwrap_err(), RequestError::InvalidPolicy(_)));
    }

    #[test]
    fn commit_event_roundtrips() {
        let ev = CommitEvent {
            id: 3,
            seq: 12,
            block: 2,
            writes: vec![(0, 17, 0.75), (5, 4, 0.0), (19, 123, 1.0)],
        };
        let line = ev.to_json().to_string();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("type").unwrap().as_str(), Some("commit"));
        assert_eq!(CommitEvent::from_json(&j).unwrap(), ev);
    }

    #[test]
    fn parse_client_line_both_generations() {
        // legacy bare request
        match parse_client_line("{\"id\":1,\"prompt\":[2]}").unwrap() {
            ClientFrame::Generate { v: 0, request } => assert_eq!(request.id, 1),
            f => panic!("wrong frame: {f:?}"),
        }
        // legacy control lines
        assert!(matches!(
            parse_client_line("{\"cmd\":\"stats\"}").unwrap(),
            ClientFrame::Stats { v: 0, format: StatsFormat::Json }
        ));
        assert!(matches!(
            parse_client_line("{\"cmd\":\"ping\"}").unwrap(),
            ClientFrame::Ping { v: 0 }
        ));
        // v1 envelope
        match parse_client_line("{\"v\":1,\"type\":\"generate\",\"id\":4,\"prompt\":[2]}").unwrap()
        {
            ClientFrame::Generate { v: 1, request } => assert_eq!(request.id, 4),
            f => panic!("wrong frame: {f:?}"),
        }
        assert!(matches!(
            parse_client_line("{\"v\":1,\"type\":\"subscribe\",\"id\":5,\"prompt\":[2]}").unwrap(),
            ClientFrame::Subscribe { .. }
        ));
        assert!(matches!(
            parse_client_line("{\"v\":1,\"type\":\"ping\"}").unwrap(),
            ClientFrame::Ping { v: 1 }
        ));
    }

    #[test]
    fn parse_client_line_errors_carry_generation() {
        let e = parse_client_line("{\"cmd\":\"nope\"}").unwrap_err();
        assert_eq!(e.v, 0);
        assert!(e.msg.contains("unknown cmd 'nope'"));
        let e = parse_client_line("{\"v\":2,\"type\":\"generate\"}").unwrap_err();
        assert_eq!(e.v, 1);
        assert!(e.msg.contains("unsupported protocol version 2"));
        let e = parse_client_line("{\"v\":1,\"type\":\"frob\",\"id\":8}").unwrap_err();
        assert_eq!((e.v, e.id), (1, Some(8)));
        let e = parse_client_line("not json").unwrap_err();
        assert_eq!(e.v, 0);
    }

    #[test]
    fn reject_and_shed_states_roundtrip() {
        let r = Response::rejected(11, 240);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("state").unwrap().as_str(), Some("rejected"));
        assert_eq!(j.get("retry_after_ms").unwrap().as_i64(), Some(240));
        let r2 = Response::from_json(&j).unwrap();
        assert!(r2.rejected && !r2.shed && !r2.parked);
        assert_eq!(r2.retry_after_ms, Some(240));
        assert!(r2.error.is_none());

        let s = Response::shed(12, 0.5);
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.get("state").unwrap().as_str(), Some("shed"));
        assert!(j.get("retry_after_ms").is_none());
        let s2 = Response::from_json(&j).unwrap();
        assert!(s2.shed && !s2.rejected && !s2.parked);
    }

    #[test]
    fn reject_frame_matches_generation() {
        let r = Response::rejected(7, 90);
        // v0: flat response bytes — legacy clients see an answered request
        let v0 = reject_frame(0, &r);
        assert!(v0.get("v").is_none());
        assert_eq!(v0.get("state").unwrap().as_str(), Some("rejected"));
        // v1: a dedicated reject envelope with the retry hint
        let v1 = reject_frame(1, &r);
        assert_eq!(v1.get("type").unwrap().as_str(), Some("reject"));
        assert_eq!(v1.get("v").unwrap().as_i64(), Some(1));
        assert_eq!(v1.get("id").unwrap().as_i64(), Some(7));
        assert_eq!(v1.get("retry_after_ms").unwrap().as_i64(), Some(90));
    }

    #[test]
    fn busy_frame_is_v1_error_with_prefix() {
        let f = busy_frame(64);
        assert_eq!(f.get("type").unwrap().as_str(), Some("error"));
        let msg = f.get("error").unwrap().as_str().unwrap();
        assert!(msg.starts_with("busy: "), "machine-matchable prefix, got '{msg}'");
        assert!(msg.contains("64"));
    }

    #[test]
    fn stats_format_parses_both_generations() {
        assert!(matches!(
            parse_client_line("{\"cmd\":\"stats\",\"format\":\"prometheus\"}").unwrap(),
            ClientFrame::Stats { v: 0, format: StatsFormat::Prometheus }
        ));
        assert!(matches!(
            parse_client_line("{\"v\":1,\"type\":\"stats\",\"format\":\"text\"}").unwrap(),
            ClientFrame::Stats { v: 1, format: StatsFormat::Prometheus }
        ));
        assert!(matches!(
            parse_client_line("{\"v\":1,\"type\":\"stats\",\"format\":\"json\"}").unwrap(),
            ClientFrame::Stats { v: 1, format: StatsFormat::Json }
        ));
        let e = parse_client_line("{\"v\":1,\"type\":\"stats\",\"format\":\"xml\"}").unwrap_err();
        assert_eq!(e.v, 1);
        assert!(e.msg.contains("unknown stats format 'xml'"));
    }

    #[test]
    fn v0_error_frame_has_no_id() {
        // legacy clients detect protocol errors by error-without-id
        assert_eq!(error_frame(0, Some(7), "boom").to_string(), "{\"error\":\"boom\"}");
        let v1 = error_frame(1, Some(7), "boom");
        assert_eq!(v1.get("id").unwrap().as_i64(), Some(7));
        assert_eq!(v1.get("type").unwrap().as_str(), Some("error"));
    }

    #[test]
    fn stats_and_pong_frames_match_generation() {
        let snap = Json::obj(vec![("requests_ok", Json::Num(3.0))]);
        assert_eq!(stats_frame(0, snap.clone()).to_string(), snap.to_string());
        let v1 = stats_frame(1, snap);
        assert_eq!(v1.get("type").unwrap().as_str(), Some("stats"));
        assert_eq!(
            v1.get("stats").unwrap().get("requests_ok").unwrap().as_usize(),
            Some(3)
        );
        assert_eq!(pong_frame(0).to_string(), "{\"pong\":true}");
        assert_eq!(pong_frame(1).get("type").unwrap().as_str(), Some("pong"));
    }
}
