//! Request/response types and their wire encoding (line-delimited JSON
//! over TCP — the offline toolchain has no HTTP stack, and a line
//! protocol keeps the client trivially scriptable).

use crate::engine::Method;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub method: Method,
    pub gen_len: usize,
    /// SLA budget in milliseconds from submission. Drives slot
    /// claiming: the batcher orders every queue by effective deadline
    /// (`arrival + deadline_ms`, or a default SLA when `None`), so
    /// tighter-deadline requests claim freed slots first. Purely a
    /// scheduling priority — a missed deadline is still answered, and
    /// counted in the `deadline_misses` metric.
    pub deadline_ms: Option<u64>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub non_eos_tokens: usize,
    pub latency_s: f64,
    pub queue_s: f64,
    pub error: Option<String>,
}

impl Request {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("prompt", Json::Arr(self.prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("method", Json::Str(self.method.name().to_string())),
            ("gen_len", Json::Num(self.gen_len as f64)),
        ];
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::Num(d as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        let id = j.get("id").and_then(|v| v.as_i64()).ok_or("missing id")? as u64;
        let prompt: Vec<i32> = j
            .get("prompt")
            .and_then(|v| v.as_arr())
            .ok_or("missing prompt")?
            .iter()
            .map(|x| x.as_i64().unwrap_or(0) as i32)
            .collect();
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        let method = Method::parse(j.get("method").and_then(|v| v.as_str()).unwrap_or("streaming"))
            .ok_or("unknown method")?;
        let gen_len = j.get("gen_len").and_then(|v| v.as_usize()).unwrap_or(64);
        let deadline_ms = j.get("deadline_ms").and_then(|v| v.as_i64()).map(|d| d.max(0) as u64);
        Ok(Request { id, prompt, method, gen_len, deadline_ms })
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            ("non_eos_tokens", Json::Num(self.non_eos_tokens as f64)),
            ("latency_s", Json::Num(self.latency_s)),
            ("queue_s", Json::Num(self.queue_s)),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Response, String> {
        Ok(Response {
            id: j.get("id").and_then(|v| v.as_i64()).ok_or("missing id")? as u64,
            text: j.get("text").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            non_eos_tokens: j.get("non_eos_tokens").and_then(|v| v.as_usize()).unwrap_or(0),
            latency_s: j.get("latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            queue_s: j.get("queue_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            error: j.get("error").and_then(|v| v.as_str()).map(|s| s.to_string()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request {
            id: 7,
            prompt: vec![2, 10, 11],
            method: Method::Streaming,
            gen_len: 64,
            deadline_ms: None,
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = Request::from_json(&j).unwrap();
        assert_eq!(r2.id, 7);
        assert_eq!(r2.prompt, vec![2, 10, 11]);
        assert_eq!(r2.method, Method::Streaming);
        assert_eq!(r2.gen_len, 64);
        assert_eq!(r2.deadline_ms, None);
    }

    #[test]
    fn deadline_roundtrip_and_default() {
        let r = Request {
            id: 8,
            prompt: vec![2],
            method: Method::Vanilla,
            gen_len: 32,
            deadline_ms: Some(250),
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(Request::from_json(&j).unwrap().deadline_ms, Some(250));
        // absent on the wire → None; negative values clamp to zero
        let j = Json::parse("{\"id\":1,\"prompt\":[2]}").unwrap();
        assert_eq!(Request::from_json(&j).unwrap().deadline_ms, None);
        let j = Json::parse("{\"id\":1,\"prompt\":[2],\"deadline_ms\":-5}").unwrap();
        assert_eq!(Request::from_json(&j).unwrap().deadline_ms, Some(0));
    }

    #[test]
    fn response_roundtrip_with_error() {
        let r = Response {
            id: 1,
            text: "a9;81".into(),
            non_eos_tokens: 5,
            latency_s: 0.25,
            queue_s: 0.01,
            error: Some("boom".into()),
        };
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let r2 = Response::from_json(&j).unwrap();
        assert_eq!(r2.error.as_deref(), Some("boom"));
        assert_eq!(r2.text, "a9;81");
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(Request::from_json(&Json::parse("{\"id\":1}").unwrap()).is_err());
        assert!(Request::from_json(&Json::parse("{\"id\":1,\"prompt\":[]}").unwrap()).is_err());
        assert!(Request::from_json(
            &Json::parse("{\"id\":1,\"prompt\":[2],\"method\":\"bogus\"}").unwrap()
        )
        .is_err());
    }
}
