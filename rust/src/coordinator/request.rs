//! Request/response types, the validating [`Request::builder`] and the
//! typed [`RequestError`] it returns. Wire encoding (v0 line JSON and
//! the v1 envelope) lives in [`super::protocol`] — this module is pure
//! data so every layer (batcher, router, workers, tests) shares one
//! validated shape.

use std::fmt;

use crate::engine::{DecodePolicy, GenConfig, Method};

use super::batcher::MAX_DEADLINE_MS;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub method: Method,
    /// Decode-policy override (v1 wire `policy` field / served default).
    /// `None` means the method's preset policy; `Some` selects any
    /// spatial × temporal combination. Engine sharing keys on
    /// [`Request::group_key`], so rows with different policies never
    /// land in the same batch round.
    pub policy: Option<DecodePolicy>,
    pub gen_len: usize,
    /// SLA budget in milliseconds from submission. Drives slot
    /// claiming: the batcher orders every queue by effective deadline
    /// (`arrival + deadline_ms`, or a default SLA when `None`), so
    /// tighter-deadline requests claim freed slots first. Purely a
    /// scheduling priority — a missed deadline is still answered, and
    /// counted in the `deadline_misses` metric — unless
    /// [`Request::park_on_miss`] opts into eviction.
    pub deadline_ms: Option<u64>,
    /// SLA-aware eviction opt-in: when the effective deadline passes
    /// while the row is mid-decode, the router evicts it from its
    /// engine and answers immediately with whatever the canvas holds,
    /// marked with the `parked` terminal state. Off by default — the
    /// classic behavior is to finish late and count a deadline miss.
    pub park_on_miss: bool,
}

/// Engine-compatibility key: requests may share a `BatchEngine` round
/// iff their keys are equal. Keying on (method, resolved policy) — not
/// the bare method — is what lets one served fleet decode different
/// policies concurrently without ever mixing them inside a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub method: Method,
    pub policy: DecodePolicy,
}

impl From<Method> for GroupKey {
    /// The key a bare method resolves to: its preset policy.
    fn from(method: Method) -> GroupKey {
        GroupKey { method, policy: DecodePolicy::for_method(method) }
    }
}

impl Request {
    /// The policy this request decodes under: its explicit override, or
    /// the method's preset.
    pub fn effective_policy(&self) -> DecodePolicy {
        self.policy.unwrap_or_else(|| DecodePolicy::for_method(self.method))
    }

    /// The engine-compatibility key (see [`GroupKey`]).
    pub fn group_key(&self) -> GroupKey {
        GroupKey { method: self.method, policy: self.effective_policy() }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub non_eos_tokens: usize,
    pub latency_s: f64,
    pub queue_s: f64,
    /// Terminal state for SLA-evicted rows: the decode was cut short at
    /// a block boundary because the deadline budget was blown and the
    /// request opted into `park_on_miss`. `text` holds the partial
    /// canvas; `error` stays `None` (parking is an answered outcome,
    /// not a failure).
    pub parked: bool,
    /// Terminal state for backpressure: the method queue was at
    /// `max_queue_depth`, so the request was never admitted.
    /// [`Response::retry_after_ms`] tells the client when capacity is
    /// plausibly back.
    pub rejected: bool,
    /// Terminal state for load shedding: the request was queued but its
    /// effective deadline passed before an engine slot opened, and it
    /// opted into `park_on_miss` — decoding it could only produce an
    /// instantly-evicted empty park.
    pub shed: bool,
    /// Backoff hint accompanying `rejected`: current queue depth ×
    /// observed per-block service time, always finite and ≥ 1.
    pub retry_after_ms: Option<u64>,
    pub error: Option<String>,
}

impl Response {
    /// An error response for `id` — the single construction point for
    /// failure replies, so the shape can't drift between the router's
    /// admission errors and the server's protocol errors.
    pub fn failure(id: u64, msg: impl Into<String>) -> Response {
        Response {
            id,
            text: String::new(),
            non_eos_tokens: 0,
            latency_s: 0.0,
            queue_s: 0.0,
            parked: false,
            rejected: false,
            shed: false,
            retry_after_ms: None,
            error: Some(msg.into()),
        }
    }

    /// A backpressure reject for `id`: never admitted, answered
    /// immediately with a finite retry hint. Not an error — the client
    /// should back off `retry_after_ms` and resubmit.
    pub fn rejected(id: u64, retry_after_ms: u64) -> Response {
        Response {
            id,
            text: String::new(),
            non_eos_tokens: 0,
            latency_s: 0.0,
            queue_s: 0.0,
            parked: false,
            rejected: true,
            shed: false,
            retry_after_ms: Some(retry_after_ms.max(1)),
            error: None,
        }
    }

    /// A shed response for `id`: queued, but its deadline became
    /// unmeetable before an engine slot opened. `queue_s` records how
    /// long it waited before being dropped.
    pub fn shed(id: u64, queue_s: f64) -> Response {
        Response {
            id,
            text: String::new(),
            non_eos_tokens: 0,
            latency_s: 0.0,
            queue_s,
            parked: false,
            rejected: false,
            shed: true,
            retry_after_ms: None,
            error: None,
        }
    }
}

/// Typed construction/validation errors, replacing the old stringly
/// `Result<_, String>` from `Request::from_json`. `Display` renders the
/// exact messages the wire protocol ships, so matching on the enum and
/// matching on the text can't disagree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// A required wire field was absent (`id`, `prompt`, ...).
    MissingField(&'static str),
    EmptyPrompt,
    UnknownMethod(String),
    /// The wire `policy` field named a preset that doesn't exist.
    UnknownPolicy(String),
    /// The wire `policy` field parsed structurally but failed
    /// [`DecodePolicy::validate`] (parameter out of range), or was the
    /// wrong JSON shape. Carries the validator's message.
    InvalidPolicy(String),
    /// `gen_len` must be a positive multiple of the method's block size
    /// — checked at construction so misaligned requests never reach an
    /// engine.
    MisalignedGenLen { gen_len: usize, block_size: usize },
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RequestError::MissingField(name) => write!(f, "missing {name}"),
            RequestError::EmptyPrompt => write!(f, "empty prompt"),
            RequestError::UnknownMethod(m) => write!(f, "unknown method '{m}'"),
            RequestError::UnknownPolicy(p) => write!(f, "unknown policy '{p}'"),
            RequestError::InvalidPolicy(msg) => write!(f, "invalid policy: {msg}"),
            RequestError::MisalignedGenLen { gen_len, block_size } => {
                write!(f, "gen_len {gen_len} is not a positive multiple of block size {block_size}")
            }
        }
    }
}

impl std::error::Error for RequestError {}

impl Request {
    /// Fluent builder with validation at construction: gen_len block
    /// alignment, deadline clamping and method parsing all happen in
    /// [`RequestBuilder::build`], so a `Request` that exists is a
    /// `Request` an engine can admit (prompt length permitting).
    pub fn builder() -> RequestBuilder {
        RequestBuilder {
            id: None,
            prompt: Vec::new(),
            method: Method::Streaming,
            bad_method: None,
            policy: None,
            bad_policy: None,
            gen_len: 64,
            deadline_ms: None,
            park_on_miss: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RequestBuilder {
    id: Option<u64>,
    prompt: Vec<i32>,
    method: Method,
    /// an unparseable name passed to `method_name`, surfaced by `build`
    bad_method: Option<String>,
    policy: Option<DecodePolicy>,
    /// an unparseable name passed to `policy_name`, surfaced by `build`
    bad_policy: Option<String>,
    gen_len: usize,
    deadline_ms: Option<u64>,
    park_on_miss: bool,
}

impl RequestBuilder {
    pub fn id(mut self, id: u64) -> Self {
        self.id = Some(id);
        self
    }

    pub fn prompt(mut self, prompt: Vec<i32>) -> Self {
        self.prompt = prompt;
        self
    }

    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self.bad_method = None;
        self
    }

    /// Parse a method from its wire name; an unknown name is recorded
    /// and reported by `build` (the builder stays fluent either way).
    pub fn method_name(mut self, name: &str) -> Self {
        match Method::parse(name) {
            Some(m) => {
                self.method = m;
                self.bad_method = None;
            }
            None => self.bad_method = Some(name.to_string()),
        }
        self
    }

    /// Select an explicit decode policy (validated by `build`).
    pub fn policy(mut self, policy: DecodePolicy) -> Self {
        self.policy = Some(policy);
        self.bad_policy = None;
        self
    }

    /// Parse a policy preset from its wire name; an unknown name is
    /// recorded and reported by `build` (the builder stays fluent).
    pub fn policy_name(mut self, name: &str) -> Self {
        match DecodePolicy::parse(name) {
            Some(p) => {
                self.policy = Some(p);
                self.bad_policy = None;
            }
            None => self.bad_policy = Some(name.to_string()),
        }
        self
    }

    pub fn gen_len(mut self, gen_len: usize) -> Self {
        self.gen_len = gen_len;
        self
    }

    /// Deadline budget in ms, clamped to [`MAX_DEADLINE_MS`] — a bogus
    /// client value must not overflow `Instant + Duration` downstream.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms.min(MAX_DEADLINE_MS));
        self
    }

    pub fn park_on_miss(mut self, park: bool) -> Self {
        self.park_on_miss = park;
        self
    }

    pub fn build(self) -> Result<Request, RequestError> {
        let id = self.id.ok_or(RequestError::MissingField("id"))?;
        if let Some(name) = self.bad_method {
            return Err(RequestError::UnknownMethod(name));
        }
        if let Some(name) = self.bad_policy {
            return Err(RequestError::UnknownPolicy(name));
        }
        if let Some(p) = &self.policy {
            p.validate().map_err(RequestError::InvalidPolicy)?;
        }
        if self.prompt.is_empty() {
            return Err(RequestError::EmptyPrompt);
        }
        let block_size = GenConfig::preset(self.method, self.gen_len.max(1)).block_size;
        if self.gen_len == 0 || self.gen_len % block_size != 0 {
            return Err(RequestError::MisalignedGenLen { gen_len: self.gen_len, block_size });
        }
        Ok(Request {
            id,
            prompt: self.prompt,
            method: self.method,
            policy: self.policy,
            gen_len: self.gen_len,
            deadline_ms: self.deadline_ms,
            park_on_miss: self.park_on_miss,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_builds_defaults() {
        let r = Request::builder().id(7).prompt(vec![2, 10, 11]).build().unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.method, Method::Streaming);
        assert_eq!(r.gen_len, 64);
        assert_eq!(r.deadline_ms, None);
        assert!(!r.park_on_miss);
        assert_eq!(r.policy, None);
        assert_eq!(r.group_key(), GroupKey::from(Method::Streaming));
    }

    #[test]
    fn policy_selection_shapes_the_group_key() {
        let default = Request::builder().id(1).prompt(vec![2]).build().unwrap();
        assert_eq!(default.effective_policy(), DecodePolicy::for_method(Method::Streaming));

        let att = Request::builder()
            .id(2)
            .prompt(vec![2])
            .policy_name("attenuating")
            .build()
            .unwrap();
        assert_eq!(att.policy, Some(DecodePolicy::parse("attenuating").unwrap()));
        // a policy override must key a different engine group...
        assert_ne!(att.group_key(), default.group_key());
        // ...while naming the method's own preset keys the same group
        let named = Request::builder()
            .id(3)
            .prompt(vec![2])
            .policy_name("streaming")
            .build()
            .unwrap();
        assert_eq!(named.group_key(), default.group_key());
    }

    #[test]
    fn bad_policies_are_typed_errors() {
        let e = Request::builder()
            .id(1)
            .prompt(vec![2])
            .policy_name("bogus")
            .build()
            .unwrap_err();
        assert_eq!(e, RequestError::UnknownPolicy("bogus".into()));
        assert_eq!(e.to_string(), "unknown policy 'bogus'");

        // structurally valid but out of range → rejected at build time
        let mut p = DecodePolicy::parse("fast-dllm").unwrap();
        p.temporal = crate::engine::TemporalPolicy::FixedTau { tau: 1.5 };
        let e = Request::builder().id(1).prompt(vec![2]).policy(p).build().unwrap_err();
        assert!(matches!(e, RequestError::InvalidPolicy(_)));
        assert!(e.to_string().starts_with("invalid policy: "));

        // a later valid selection clears an earlier bad name
        let r = Request::builder()
            .id(1)
            .prompt(vec![2])
            .policy_name("bogus")
            .policy_name("dropout")
            .build()
            .unwrap();
        assert_eq!(r.policy, Some(DecodePolicy::parse("dropout").unwrap()));
    }

    #[test]
    fn builder_validates() {
        assert_eq!(
            Request::builder().prompt(vec![2]).build().unwrap_err(),
            RequestError::MissingField("id")
        );
        assert_eq!(Request::builder().id(1).build().unwrap_err(), RequestError::EmptyPrompt);
        assert_eq!(
            Request::builder().id(1).prompt(vec![2]).method_name("bogus").build().unwrap_err(),
            RequestError::UnknownMethod("bogus".into())
        );
        let err =
            Request::builder().id(1).prompt(vec![2]).gen_len(13).build().unwrap_err();
        assert_eq!(err, RequestError::MisalignedGenLen { gen_len: 13, block_size: 8 });
        assert_eq!(err.to_string(), "gen_len 13 is not a positive multiple of block size 8");
        assert!(matches!(
            Request::builder().id(1).prompt(vec![2]).gen_len(0).build().unwrap_err(),
            RequestError::MisalignedGenLen { gen_len: 0, .. }
        ));
    }

    #[test]
    fn builder_clamps_absurd_deadline() {
        let r = Request::builder()
            .id(1)
            .prompt(vec![2])
            .deadline_ms(u64::MAX)
            .build()
            .unwrap();
        assert_eq!(r.deadline_ms, Some(MAX_DEADLINE_MS));
        let r = Request::builder().id(1).prompt(vec![2]).deadline_ms(250).build().unwrap();
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn method_name_parses_all_wire_names() {
        for m in Method::all() {
            let r = Request::builder()
                .id(1)
                .prompt(vec![2])
                .method_name(m.name())
                .build()
                .unwrap();
            assert_eq!(r.method, m);
        }
    }

    #[test]
    fn failure_helper_shapes_error_response() {
        let r = Response::failure(9, "boom");
        assert_eq!(r.id, 9);
        assert_eq!(r.error.as_deref(), Some("boom"));
        assert!(!r.parked);
        assert!(!r.rejected && !r.shed);
        assert_eq!(r.retry_after_ms, None);
        assert_eq!(r.non_eos_tokens, 0);
    }

    #[test]
    fn reject_and_shed_helpers_shape_terminal_states() {
        let r = Response::rejected(4, 120);
        assert!(r.rejected && !r.shed && !r.parked);
        assert_eq!(r.retry_after_ms, Some(120));
        assert!(r.error.is_none(), "reject is backpressure, not failure");
        // the hint is clamped to ≥ 1 so clients never busy-loop on 0
        assert_eq!(Response::rejected(4, 0).retry_after_ms, Some(1));

        let s = Response::shed(5, 0.25);
        assert!(s.shed && !s.rejected && !s.parked);
        assert_eq!(s.retry_after_ms, None);
        assert!(s.error.is_none());
        assert!((s.queue_s - 0.25).abs() < 1e-12);
    }
}
