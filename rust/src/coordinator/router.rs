//! Router: the engine thread. Model backends are generally not `Send`
//! (PJRT handles wrap raw pointers), so one dedicated thread *builds*
//! and owns the backend; everything else talks to it through a channel
//! of jobs.
//!
//! The admission loop is *continuous at block granularity* and
//! **multi-engine**: every method group that becomes ready gets its own
//! slot-based [`BatchEngine`], and each scheduling pass drives one
//! block round per active engine — Streaming and Vanilla traffic decode
//! concurrently instead of blocking each other, which also removes the
//! old join-pause rule (a starving group now simply starts its own
//! engine on the next pass). Between block rounds the loop admits
//! queued same-method requests into slots freed by finished or
//! early-exited rows, earliest effective deadline first; rows carry
//! their own `gen_len`, so mixed-length requests share one engine and
//! a short row's retirement frees its slot while long rows continue.
//! Finished rows are answered the moment their own decode completes.
//!
//! Construction is a factory closure executed on the engine thread
//! (`spawn_with`), with two conveniences: `spawn_reference` (pure-Rust
//! backend, always available) and `spawn` (PJRT artifacts, behind the
//! `pjrt` feature).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{
    Backend, BatchEngine, GenConfig, Method, RefMode, ReferenceBackend, REFERENCE_SEED,
};

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::request::{Request, Response};

/// A submitted request plus its reply channel and arrival time.
pub struct Job {
    pub request: Request,
    pub reply: Sender<Response>,
    pub arrived: Instant,
}

/// Control messages for the engine thread.
pub enum Msg {
    Submit(Job),
    Shutdown,
}

pub struct RouterHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
}

impl RouterHandle {
    /// Spawn the engine thread around a backend built *on that thread*
    /// by `factory` (backends need not be `Send`).
    pub fn spawn_with<B, F>(factory: F, max_batch: usize, max_wait: Duration) -> RouterHandle
    where
        B: Backend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name("sdllm-router".into())
            .spawn(move || {
                let backend = factory()?;
                engine_loop(&backend, max_batch, max_wait, rx, m2)
            })
            .expect("spawn router thread");
        RouterHandle { tx, join: Some(join), metrics }
    }

    /// Engine thread over the deterministic reference backend (toy
    /// mode) — serves on a bare checkout, no artifacts or accelerator
    /// required.
    pub fn spawn_reference(max_batch: usize, max_wait: Duration) -> RouterHandle {
        RouterHandle::spawn_reference_mode(RefMode::Toy, max_batch, max_wait)
    }

    /// Engine thread over a reference backend in the given mode (the
    /// serve-path analogue of `--ref-mode`; scripted maps to toy).
    pub fn spawn_reference_mode(
        mode: RefMode,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        RouterHandle::spawn_with(
            move || {
                Ok(match mode {
                    RefMode::Causal => ReferenceBackend::causal(REFERENCE_SEED),
                    _ => ReferenceBackend::toy(REFERENCE_SEED),
                })
            },
            max_batch,
            max_wait,
        )
    }

    /// Engine thread serving `model` from `artifacts_root` on PJRT.
    #[cfg(feature = "pjrt")]
    pub fn spawn(
        artifacts_root: std::path::PathBuf,
        model: String,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        use crate::runtime::{warmup, ArtifactsIndex, ModelRuntime, Runtime};
        RouterHandle::spawn_with(
            move || {
                let rt = Runtime::cpu()?;
                let index = ArtifactsIndex::load(&artifacts_root)?;
                let model_rt = ModelRuntime::load(&rt, &index.model_dir(&model))?;
                // Pre-warm the default serving path so first requests
                // don't pay lazy executable compilation (best effort:
                // unknown methods/lengths still compile on demand).
                let warm_cfg = GenConfig::preset(crate::engine::Method::Streaming, 64);
                if let Ok(n) = warmup::warm_for(&model_rt, &warm_cfg, 224, max_batch) {
                    if n > 0 {
                        eprintln!("[router] pre-warmed {n} executables");
                    }
                }
                Ok(model_rt)
            },
            max_batch,
            max_wait,
        )
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, arrived: Instant::now() };
        // If the engine thread died the reply channel is dropped and the
        // caller sees a disconnect — no panic here.
        let _ = self.tx.send(Msg::Submit(job));
        reply_rx
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r,
                Err(_) => anyhow::bail!("router thread panicked"),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Placeholder gen length for the per-method engine config. Rows carry
/// their own `gen_len` at admission — this only has to satisfy
/// `GenConfig::validate` (positive, block-aligned).
const ENGINE_CFG_GEN_LEN: usize = 64;

/// Per-request bookkeeping held until the reply is sent: the channel,
/// arrival time, and the effective deadline — `arrival + deadline_ms`,
/// or `arrival + default SLA` when none was given — for the miss
/// metric, mirroring the batcher's ordering semantics.
struct ReplySlot {
    tx: Sender<Response>,
    arrived: Instant,
    deadline: Instant,
}

/// One in-flight engine (there is at most one per method) plus
/// per-request admission times for queue / latency accounting.
struct EngineRun<'b, B: Backend> {
    method: Method,
    engine: BatchEngine<'b, B>,
    admitted: HashMap<u64, Instant>,
}

/// Refresh the scheduling gauges: per-method (queued, active) depth
/// and the engines-active gauge + high-water mark. Called right after
/// engines start (so short-lived engines that drain within the same
/// pass still count toward the peak) and again at the end of the pass
/// (so the current-state gauges reflect retirements).
fn refresh_gauges<B: Backend>(batcher: &Batcher, runs: &[EngineRun<'_, B>], metrics: &Metrics) {
    let depths: Vec<(&'static str, usize, usize)> = Method::all()
        .into_iter()
        .filter_map(|m| {
            let queued = batcher.depth(m);
            let active =
                runs.iter().find(|r| r.method == m).map(|r| r.engine.active()).unwrap_or(0);
            (queued + active > 0).then_some((m.name(), queued, active))
        })
        .collect();
    metrics.set_groups(depths, runs.len());
}

/// Answer a request with an error and account for it.
fn fail(replies: &mut HashMap<u64, ReplySlot>, metrics: &Metrics, id: u64, err: &str) {
    if let Some(slot) = replies.remove(&id) {
        metrics.record_response(false, 0, 0.0, 0.0);
        let _ = slot.tx.send(Response {
            id,
            text: String::new(),
            non_eos_tokens: 0,
            latency_s: 0.0,
            queue_s: 0.0,
            error: Some(err.to_string()),
        });
    }
}

/// Try to admit `req` into `run`'s engine; answers the request with an
/// error (and returns false) when it can never decode there.
fn admit_or_fail<B: Backend>(
    run: &mut EngineRun<'_, B>,
    req: &Request,
    replies: &mut HashMap<u64, ReplySlot>,
    metrics: &Metrics,
) -> bool {
    if !run.engine.valid_gen_len(req.gen_len) {
        let k = run.engine.config().block_size;
        fail(
            replies,
            metrics,
            req.id,
            &format!("gen_len {} is not a positive multiple of block size {k}", req.gen_len),
        );
        return false;
    }
    if !run.engine.fits(req.prompt.len(), req.gen_len) {
        // fail the oversized request alone — it must not poison the
        // rows already (or about to be) mid-decode
        fail(replies, metrics, req.id, "prompt exceeds backend buckets");
        return false;
    }
    if run.engine.admit(req.id, &req.prompt, req.gen_len) {
        run.admitted.insert(req.id, Instant::now());
        metrics.record_admission();
        true
    } else {
        fail(replies, metrics, req.id, "engine slots exhausted");
        false
    }
}

fn engine_loop<B: Backend>(
    backend: &B,
    max_batch: usize,
    max_wait: Duration,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    metrics.start_clock();

    // Clamp the serving batch to what the backend's batch buckets carry
    // up front, so the batcher never hands an engine more rows than it
    // has slots (keeps record_batch and the admission metrics honest).
    let engine_cap = crate::engine::clamp_batch(backend, max_batch);
    let mut batcher = Batcher::new(engine_cap, max_wait);
    let mut replies: HashMap<u64, ReplySlot> = HashMap::new();
    let mut shutdown = false;
    let mut runs: Vec<EngineRun<'_, B>> = Vec::new();

    let enqueue = |job: Job, batcher: &mut Batcher, replies: &mut HashMap<u64, ReplySlot>| {
        let deadline = batcher.effective_deadline(&job.request, job.arrived);
        let slot = ReplySlot { tx: job.reply, arrived: job.arrived, deadline };
        replies.insert(job.request.id, slot);
        batcher.push_at(job.request, job.arrived);
    };

    loop {
        // Drain the inbox. With engines mid-flight we must not block —
        // decode keeps moving and new arrivals join at the next block
        // boundary; when idle, wait out the batcher's flush deadline.
        if !runs.is_empty() {
            loop {
                match rx.try_recv() {
                    Ok(Msg::Submit(job)) => enqueue(job, &mut batcher, &mut replies),
                    Ok(Msg::Shutdown) => shutdown = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
        } else {
            // A group can already be runnable (full, or flushed by a
            // deadline that passed while the engines were busy) — never
            // sleep on the inbox in that case.
            let now = Instant::now();
            let timeout = if batcher.has_ready(now) {
                Duration::ZERO
            } else {
                batcher.next_deadline(now).unwrap_or(Duration::from_millis(50))
            };
            match rx.recv_timeout(timeout) {
                Ok(Msg::Submit(job)) => {
                    enqueue(job, &mut batcher, &mut replies);
                    // opportunistically drain whatever else is queued
                    while let Ok(msg) = rx.try_recv() {
                        match msg {
                            Msg::Submit(j) => enqueue(j, &mut batcher, &mut replies),
                            Msg::Shutdown => shutdown = true,
                        }
                    }
                }
                Ok(Msg::Shutdown) => shutdown = true,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => shutdown = true,
            }
        }

        // Start an engine for every ready group that doesn't have one —
        // distinct methods decode concurrently, so a ready group never
        // waits behind another method's batch.
        loop {
            let busy: Vec<Method> = runs.iter().map(|r| r.method).collect();
            let Some((method, batch)) = batcher.pop_ready(Instant::now(), &busy) else { break };
            metrics.record_batch(batch.len());
            let cfg = GenConfig::preset(method, ENGINE_CFG_GEN_LEN);
            match BatchEngine::new(backend, cfg, engine_cap) {
                Ok(engine) => {
                    let mut run = EngineRun { method, engine, admitted: HashMap::new() };
                    for req in batch {
                        if run.engine.has_free_slot() {
                            if admit_or_fail(&mut run, &req, &mut replies, &metrics) {
                                metrics.record_batch_admit();
                            }
                        } else {
                            // defensive: the batcher flush size is
                            // clamped to engine capacity, but if the two
                            // ever drift, requeue (original arrival
                            // preserved) — the overflow joins as rows
                            // finish and free slots
                            let arrived = replies
                                .get(&req.id)
                                .map(|s| s.arrived)
                                .unwrap_or_else(Instant::now);
                            batcher.push_at(req, arrived);
                        }
                    }
                    if run.engine.active() > 0 {
                        runs.push(run);
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for req in &batch {
                        fail(&mut replies, &metrics, req.id, &msg);
                    }
                }
            }
        }

        // Peak sampled before any same-pass retirement, so an engine
        // that starts and drains within one pass still registers in
        // max_engines_active.
        refresh_gauges(&batcher, &runs, &metrics);

        // For each engine: admit same-method waiters (earliest deadline
        // first) into free slots, run one block round, answer whoever
        // finished; retire engines that drained.
        let mut i = 0;
        while i < runs.len() {
            let run = &mut runs[i];
            while run.engine.has_free_slot() {
                let Some(req) = batcher.pop_compatible(run.method) else { break };
                if admit_or_fail(run, &req, &mut replies, &metrics) {
                    metrics.record_join();
                }
            }
            let mut retire = false;
            match run.engine.step_block() {
                Ok(done) => {
                    let now = Instant::now();
                    for f in done {
                        let started = run.admitted.remove(&f.tag);
                        if let Some(slot) = replies.remove(&f.tag) {
                            let started = started.unwrap_or(slot.arrived);
                            let queue_s = started.duration_since(slot.arrived).as_secs_f64();
                            let latency_s = now.duration_since(started).as_secs_f64();
                            let resp = Response {
                                id: f.tag,
                                text: backend.detokenize(f.seq.generated()),
                                non_eos_tokens: f.seq.non_eos_tokens(),
                                latency_s,
                                queue_s,
                                error: None,
                            };
                            metrics.record_response(true, resp.non_eos_tokens, latency_s, queue_s);
                            if now > slot.deadline {
                                metrics.record_deadline_miss();
                            }
                            let _ = slot.tx.send(resp);
                        }
                    }
                    retire = run.engine.active() == 0;
                }
                Err(e) => {
                    // engine poisoned: fail every row still inside
                    let msg = format!("{e:#}");
                    for (id, _) in run.admitted.drain() {
                        fail(&mut replies, &metrics, id, &msg);
                    }
                    retire = true;
                }
            }
            if retire {
                let run = runs.swap_remove(i);
                metrics.record_engine(
                    run.engine.report(),
                    run.engine.rounds(),
                    run.engine.mixed_rounds(),
                );
            } else {
                i += 1;
            }
        }

        // Refresh the current-state gauges after retirements.
        refresh_gauges(&batcher, &runs, &metrics);

        if shutdown && runs.is_empty() && batcher.pending() == 0 {
            return Ok(());
        }
    }
}
