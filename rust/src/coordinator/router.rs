//! Router: the engine thread. Model backends are generally not `Send`
//! (PJRT handles wrap raw pointers), so one dedicated thread *builds*
//! and owns the backend; everything else talks to it through a channel
//! of jobs. The router runs the admission loop: drain the inbox into
//! the `Batcher`, pop ready batches, decode them with the `Generator`,
//! and reply per request.
//!
//! Construction is a factory closure executed on the engine thread
//! (`spawn_with`), with two conveniences: `spawn_reference` (pure-Rust
//! backend, always available) and `spawn` (PJRT artifacts, behind the
//! `pjrt` feature).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{
    Backend, GenConfig, Generator, RefMode, ReferenceBackend, SeqState, REFERENCE_SEED,
};

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::request::{Request, Response};

/// A submitted request plus its reply channel and arrival time.
pub struct Job {
    pub request: Request,
    pub reply: Sender<Response>,
    pub arrived: Instant,
}

/// Control messages for the engine thread.
pub enum Msg {
    Submit(Job),
    Shutdown,
}

pub struct RouterHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
}

impl RouterHandle {
    /// Spawn the engine thread around a backend built *on that thread*
    /// by `factory` (backends need not be `Send`).
    pub fn spawn_with<B, F>(factory: F, max_batch: usize, max_wait: Duration) -> RouterHandle
    where
        B: Backend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name("sdllm-router".into())
            .spawn(move || {
                let backend = factory()?;
                engine_loop(&backend, max_batch, max_wait, rx, m2)
            })
            .expect("spawn router thread");
        RouterHandle { tx, join: Some(join), metrics }
    }

    /// Engine thread over the deterministic reference backend (toy
    /// mode) — serves on a bare checkout, no artifacts or accelerator
    /// required.
    pub fn spawn_reference(max_batch: usize, max_wait: Duration) -> RouterHandle {
        RouterHandle::spawn_reference_mode(RefMode::Toy, max_batch, max_wait)
    }

    /// Engine thread over a reference backend in the given mode (the
    /// serve-path analogue of `--ref-mode`; scripted maps to toy).
    pub fn spawn_reference_mode(
        mode: RefMode,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        RouterHandle::spawn_with(
            move || {
                Ok(match mode {
                    RefMode::Causal => ReferenceBackend::causal(REFERENCE_SEED),
                    _ => ReferenceBackend::toy(REFERENCE_SEED),
                })
            },
            max_batch,
            max_wait,
        )
    }

    /// Engine thread serving `model` from `artifacts_root` on PJRT.
    #[cfg(feature = "pjrt")]
    pub fn spawn(
        artifacts_root: std::path::PathBuf,
        model: String,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        use crate::runtime::{warmup, ArtifactsIndex, ModelRuntime, Runtime};
        RouterHandle::spawn_with(
            move || {
                let rt = Runtime::cpu()?;
                let index = ArtifactsIndex::load(&artifacts_root)?;
                let model_rt = ModelRuntime::load(&rt, &index.model_dir(&model))?;
                // Pre-warm the default serving path so first requests
                // don't pay lazy executable compilation (best effort:
                // unknown methods/lengths still compile on demand).
                let warm_cfg = GenConfig::preset(crate::engine::Method::Streaming, 64);
                if let Ok(n) = warmup::warm_for(&model_rt, &warm_cfg, 224, max_batch) {
                    if n > 0 {
                        eprintln!("[router] pre-warmed {n} executables");
                    }
                }
                Ok(model_rt)
            },
            max_batch,
            max_wait,
        )
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, arrived: Instant::now() };
        // If the engine thread died the reply channel is dropped and the
        // caller sees a disconnect — no panic here.
        let _ = self.tx.send(Msg::Submit(job));
        reply_rx
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r,
                Err(_) => anyhow::bail!("router thread panicked"),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_loop<B: Backend>(
    backend: &B,
    max_batch: usize,
    max_wait: Duration,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    metrics.start_clock();

    let mut batcher = Batcher::new(max_batch, max_wait);
    let mut replies: std::collections::HashMap<u64, (Sender<Response>, Instant)> =
        std::collections::HashMap::new();
    let mut shutdown = false;

    loop {
        // Drain inbox (bounded wait so timed-out groups flush).
        let timeout = batcher
            .next_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(job)) => {
                replies.insert(job.request.id, (job.reply, job.arrived));
                batcher.push_at(job.request, job.arrived);
                // opportunistically drain whatever else is queued
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        Msg::Submit(j) => {
                            replies.insert(j.request.id, (j.reply, j.arrived));
                            batcher.push_at(j.request, j.arrived);
                        }
                        Msg::Shutdown => shutdown = true,
                    }
                }
            }
            Ok(Msg::Shutdown) => shutdown = true,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => shutdown = true,
        }

        while let Some((key, batch)) = batcher.pop_ready(Instant::now()) {
            metrics.record_batch(batch.len());
            let t0 = Instant::now();
            let cfg = GenConfig::preset(key.method, key.gen_len);
            let result = run_batch(backend, &cfg, &batch, t0);
            match result {
                Ok(responses) => {
                    for resp in responses {
                        if let Some((tx, arrived)) = replies.remove(&resp.id) {
                            let queue_s = t0.duration_since(arrived).as_secs_f64();
                            let resp = Response { queue_s, ..resp };
                            metrics.record_response(
                                resp.error.is_none(),
                                resp.non_eos_tokens,
                                resp.latency_s,
                                queue_s,
                            );
                            let _ = tx.send(resp);
                        }
                    }
                }
                Err(e) => {
                    for req in &batch {
                        if let Some((tx, _)) = replies.remove(&req.id) {
                            metrics.record_response(false, 0, 0.0, 0.0);
                            let _ = tx.send(Response {
                                id: req.id,
                                text: String::new(),
                                non_eos_tokens: 0,
                                latency_s: 0.0,
                                queue_s: 0.0,
                                error: Some(format!("{e:#}")),
                            });
                        }
                    }
                }
            }
        }

        if shutdown && batcher.pending() == 0 {
            return Ok(());
        }
    }
}

fn run_batch<B: Backend>(
    backend: &B,
    cfg: &GenConfig,
    batch: &[Request],
    t0: Instant,
) -> Result<Vec<Response>> {
    let generator = Generator::new(backend, cfg.clone())?;
    let special = backend.special();
    let mut seqs: Vec<SeqState> =
        batch.iter().map(|r| SeqState::new(&r.prompt, cfg.gen_len, &special)).collect();
    generator.generate(&mut seqs, None)?;
    let latency = t0.elapsed().as_secs_f64();
    Ok(batch
        .iter()
        .zip(seqs.iter())
        .map(|(req, seq)| Response {
            id: req.id,
            text: backend.detokenize(seq.generated()),
            non_eos_tokens: seq.non_eos_tokens(),
            latency_s: latency,
            queue_s: 0.0,
            error: None,
        })
        .collect())
}
