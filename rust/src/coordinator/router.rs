//! Router: a pure scheduler. It admits requests, routes them to
//! per-engine worker threads (see [`super::worker`]), fans streamed
//! commit events out to subscribers and aggregates metrics — it never
//! touches a decode loop. Backends are `Send`, so each worker *builds
//! and owns* its own backend instance; distinct methods decode on
//! distinct OS threads and their wall-clocks genuinely overlap (the
//! `engines_overlap` bench asserts busy-time sum > router elapsed).
//!
//! Scheduling is continuous at block granularity: ready policy groups
//! (keyed by [`GroupKey`] — method × decode policy, so requests naming
//! different policies never share an engine) start engines on idle
//! workers (spawning lazily up to [`RouterOptions::max_engines`]); once
//! every worker is live, further groups multiplex — their batches queue
//! behind the least-loaded worker and run when its current engine
//! retires. Between block rounds, freed slots are topped up with
//! same-group waiters, earliest effective deadline first. SLA-aware eviction (`park_on_miss`) pulls
//! rows whose deadline budget blew mid-decode out of their engine at
//! the next block boundary and answers them with the `parked` terminal
//! state.
//!
//! Construction is a factory closure executed on every worker thread
//! (`spawn_with`/`spawn_opts`), with conveniences: `spawn_reference`
//! (pure-Rust backend, always available) and `spawn` (PJRT artifacts,
//! behind the `pjrt` feature).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{Backend, Method, RefMode, ReferenceBackend, SharedPrefixCache, REFERENCE_SEED};

use super::batcher::{shared_prefix_rows, Batcher, DEDUP_MIN_PREFIX};
use super::metrics::{Metrics, WorkerGauge};
use super::protocol::CommitEvent;
use super::request::{GroupKey, Request, Response};
use super::worker::{spawn_worker, AdmitReq, RowDone, WorkerCmd, WorkerEvent};

/// Default cap on concurrently live worker threads (= engines).
pub const DEFAULT_MAX_ENGINES: usize = 4;

/// Default per-method queued-request bound. A full queue answers a
/// typed reject with `retry_after_ms` instead of growing without limit.
pub const DEFAULT_MAX_QUEUE_DEPTH: usize = 256;

/// Default byte budget for the cross-request prefix cache (0 disables
/// caching entirely — no cache is built and engines decode cold).
pub const DEFAULT_PREFIX_CACHE_BYTES: usize = 32 * 1024 * 1024;

/// Frames delivered to a streaming subscription (see
/// [`RouterHandle::subscribe`]): out-of-order commit events as blocks
/// retire, then exactly one terminal `Done`.
#[derive(Debug)]
pub enum StreamFrame {
    Commit(CommitEvent),
    Done(Response),
}

/// Reply channel for one request: classic one-shot, or a commit-event
/// stream. Streamed rows are admitted traced so the engine produces
/// per-round canvas diffs for them.
pub enum ReplyTx {
    Oneshot(Sender<Response>),
    Stream(Sender<StreamFrame>),
}

impl ReplyTx {
    fn send_done(&self, resp: Response) {
        match self {
            ReplyTx::Oneshot(tx) => {
                let _ = tx.send(resp);
            }
            ReplyTx::Stream(tx) => {
                let _ = tx.send(StreamFrame::Done(resp));
            }
        }
    }
}

/// A submitted request plus its reply channel and arrival time.
pub struct Job {
    pub request: Request,
    pub reply: ReplyTx,
    pub arrived: Instant,
}

/// The router's single inbox: submissions, shutdown, and every worker
/// event (workers write through a clone of the router's own sender, so
/// each worker's events arrive in the order it sent them).
pub enum Msg {
    Submit(Job),
    /// Detach request `id`: its client is gone, so free the engine slot
    /// (or pull it out of the queue) without delivering a response.
    Cancel { id: u64 },
    Shutdown,
    Worker(WorkerEvent),
}

/// Serving knobs consumed by `spawn_opts` (the `spawn_with` signature
/// keeps the historical two-knob form).
#[derive(Debug, Clone, Copy)]
pub struct RouterOptions {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// cap on live worker threads; more methods than workers multiplex
    pub max_engines: usize,
    /// per-method queued-request bound; a full queue rejects with
    /// `retry_after_ms` instead of enqueueing
    pub max_queue_depth: usize,
    /// byte budget for the cross-request prefix cache; 0 disables it
    pub prefix_cache_bytes: usize,
    /// per-engine host-side row parallelism in the decode inner loop
    /// (bit-identical output at any setting; 1 = off)
    pub decode_threads: usize,
}

impl Default for RouterOptions {
    fn default() -> RouterOptions {
        RouterOptions {
            max_batch: 4,
            max_wait: Duration::from_millis(20),
            max_engines: DEFAULT_MAX_ENGINES,
            max_queue_depth: DEFAULT_MAX_QUEUE_DEPTH,
            prefix_cache_bytes: DEFAULT_PREFIX_CACHE_BYTES,
            decode_threads: 1,
        }
    }
}

pub struct RouterHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
}

impl RouterHandle {
    /// Spawn the scheduler around a backend factory executed on every
    /// worker thread (each worker builds and owns its own instance —
    /// backends must be `Send` but need not be `Sync`).
    pub fn spawn_with<B, F>(factory: F, max_batch: usize, max_wait: Duration) -> RouterHandle
    where
        B: Backend + 'static,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        RouterHandle::spawn_opts(
            factory,
            RouterOptions { max_batch, max_wait, ..RouterOptions::default() },
        )
    }

    /// Spawn with the full option set.
    pub fn spawn_opts<B, F>(factory: F, opts: RouterOptions) -> RouterHandle
    where
        B: Backend + 'static,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let events = tx.clone();
        let factory = Arc::new(factory);
        let join = std::thread::Builder::new()
            .name("sdllm-router".into())
            .spawn(move || scheduler_loop(factory, opts, rx, events, m2))
            .expect("spawn router thread");
        RouterHandle { tx, join: Some(join), metrics }
    }

    /// Scheduler over the deterministic reference backend (toy mode) —
    /// serves on a bare checkout, no artifacts or accelerator required.
    pub fn spawn_reference(max_batch: usize, max_wait: Duration) -> RouterHandle {
        RouterHandle::spawn_reference_mode(RefMode::Toy, max_batch, max_wait)
    }

    /// Scheduler over a reference backend in the given mode (the
    /// serve-path analogue of `--ref-mode`; scripted maps to toy).
    pub fn spawn_reference_mode(
        mode: RefMode,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        RouterHandle::spawn_reference_opts(
            mode,
            RouterOptions { max_batch, max_wait, ..RouterOptions::default() },
        )
    }

    /// Reference backend with the full option set (the `ServeConfig`
    /// entry point).
    pub fn spawn_reference_opts(mode: RefMode, opts: RouterOptions) -> RouterHandle {
        RouterHandle::spawn_opts(
            move || {
                Ok(match mode {
                    RefMode::Causal => ReferenceBackend::causal(REFERENCE_SEED),
                    _ => ReferenceBackend::toy(REFERENCE_SEED),
                })
            },
            opts,
        )
    }

    /// Scheduler serving `model` from `artifacts_root` on PJRT.
    #[cfg(feature = "pjrt")]
    pub fn spawn(
        artifacts_root: std::path::PathBuf,
        model: String,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        RouterHandle::spawn_pjrt_opts(
            artifacts_root,
            model,
            RouterOptions { max_batch, max_wait, ..RouterOptions::default() },
        )
    }

    /// PJRT scheduler with the full option set (each worker thread
    /// loads its own `ModelRuntime` from the shared artifacts).
    #[cfg(feature = "pjrt")]
    pub fn spawn_pjrt_opts(
        artifacts_root: std::path::PathBuf,
        model: String,
        opts: RouterOptions,
    ) -> RouterHandle {
        use crate::runtime::{warmup, ArtifactsIndex, ModelRuntime, Runtime};
        let max_batch = opts.max_batch;
        RouterHandle::spawn_opts(
            move || {
                let rt = Runtime::cpu()?;
                let index = ArtifactsIndex::load(&artifacts_root)?;
                let model_rt = ModelRuntime::load(&rt, &index.model_dir(&model))?;
                // Pre-warm the default serving path so first requests
                // don't pay lazy executable compilation (best effort:
                // unknown methods/lengths still compile on demand).
                let warm_cfg =
                    crate::engine::GenConfig::preset(crate::engine::Method::Streaming, 64);
                if let Ok(n) = warmup::warm_for(&model_rt, &warm_cfg, 224, max_batch) {
                    if n > 0 {
                        eprintln!("[router] pre-warmed {n} executables");
                    }
                }
                Ok(model_rt)
            },
            opts,
        )
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: ReplyTx::Oneshot(reply_tx), arrived: Instant::now() };
        // If the scheduler thread died the reply channel is dropped and
        // the caller sees a disconnect — no panic here.
        let _ = self.tx.send(Msg::Submit(job));
        reply_rx
    }

    /// Submit with a streaming subscription: the row is traced, and the
    /// receiver yields its commit events as blocks retire, terminated
    /// by exactly one [`StreamFrame::Done`].
    pub fn subscribe(&self, request: Request) -> Receiver<StreamFrame> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: ReplyTx::Stream(reply_tx), arrived: Instant::now() };
        let _ = self.tx.send(Msg::Submit(job));
        reply_rx
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        Ok(rx.recv()?)
    }

    /// Detach a request whose client is gone (a subscriber that
    /// disconnected mid-stream): the row is pulled from the queue or
    /// evicted from its engine, counted in the `cancelled` metric, and
    /// no response is delivered. Benign no-op for unknown or
    /// already-answered ids.
    pub fn cancel(&self, id: u64) {
        let _ = self.tx.send(Msg::Cancel { id });
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r,
                Err(_) => anyhow::bail!("router thread panicked"),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// How a row was admitted — picks the conservation counter its
/// `Admitted` event bumps (`joins + batch_started == admissions`).
#[derive(Debug, Clone, Copy)]
enum AdmitKind {
    BatchStart,
    Join,
}

/// Per-request scheduler state, held from submission to reply.
struct RowState {
    reply: ReplyTx,
    arrived: Instant,
    /// effective deadline (batcher semantics) for the miss metric and
    /// SLA eviction
    deadline: Instant,
    park_on_miss: bool,
    kind: AdmitKind,
    /// set when the worker confirms the engine admission
    admitted_at: Option<Instant>,
    /// the worker this row was last routed to
    worker: Option<usize>,
    /// an eviction was already requested — never evict twice
    evict_sent: bool,
    /// the subscriber disconnected: resolve the row silently into the
    /// `cancelled` counter instead of answering it
    detached: bool,
}

/// One worker thread as the scheduler sees it. Slots are never removed
/// (worker indices are stable); dead ones are skipped.
struct WorkerSlot {
    tx: Sender<WorkerCmd>,
    join: Option<JoinHandle<()>>,
    /// the policy group whose engine the worker is currently running
    /// (None between engines; multiplexed batches queue without
    /// setting it)
    assigned: Option<GroupKey>,
    /// rows routed to this worker and not yet answered/bounced
    outstanding: usize,
    /// engine slot count; a guess (`opts.max_batch`) until `Ready`
    capacity: usize,
    ready: bool,
    dead: bool,
}

/// The scheduler's whole mutable state, grouped so the event handlers
/// stay methods instead of 8-argument free functions.
struct Sched<B, F> {
    factory: Arc<F>,
    opts: RouterOptions,
    events: Sender<Msg>,
    metrics: Arc<Metrics>,
    batcher: Batcher,
    rows: HashMap<u64, RowState>,
    workers: Vec<WorkerSlot>,
    /// cross-request prefix cache shared by every worker (None when
    /// `prefix_cache_bytes` is 0)
    prefix_cache: Option<SharedPrefixCache>,
    shutdown: bool,
    /// EWMA of observed per-block decode seconds across all workers —
    /// the service-time term in `retry_after_ms` (depth × per-block).
    est_block_secs: Option<f64>,
    _backend: std::marker::PhantomData<fn() -> B>,
}

fn scheduler_loop<B, F>(
    factory: Arc<F>,
    opts: RouterOptions,
    rx: Receiver<Msg>,
    events: Sender<Msg>,
    metrics: Arc<Metrics>,
) -> Result<()>
where
    B: Backend + 'static,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    metrics.start_clock();
    let mut batcher = Batcher::new(opts.max_batch, opts.max_wait);
    batcher.max_depth = opts.max_queue_depth.max(1);
    let prefix_cache = (opts.prefix_cache_bytes > 0)
        .then(|| SharedPrefixCache::new(opts.prefix_cache_bytes));
    let mut s = Sched::<B, F> {
        factory,
        batcher,
        opts: RouterOptions { max_engines: opts.max_engines.max(1), ..opts },
        events,
        metrics,
        rows: HashMap::new(),
        workers: Vec::new(),
        prefix_cache,
        shutdown: false,
        est_block_secs: None,
        _backend: std::marker::PhantomData,
    };
    loop {
        // Block until something happens (a message, a batcher flush
        // deadline, a park deadline), then drain the inbox. The timeout
        // is never zero — progress while blocked on workers comes from
        // their events, not from spinning.
        match rx.recv_timeout(s.poll_timeout(Instant::now())) {
            Ok(msg) => s.handle(msg),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => s.shutdown = true,
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => s.handle(msg),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    s.shutdown = true;
                    break;
                }
            }
        }
        // One scheduling pass: sheds, evictions, engine starts, top-ups.
        s.shed_blown();
        s.park_blown_rows();
        s.start_engines();
        s.top_up();
        s.refresh_gauges();
        if s.shutdown && s.batcher.pending() == 0 && s.rows.is_empty() {
            return s.finish(&rx);
        }
    }
}

impl<B, F> Sched<B, F>
where
    B: Backend + 'static,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    /// Next wake-up: the batcher's flush deadline or the nearest park
    /// deadline, clamped to [1ms, 50ms] so a ready-but-blocked queue
    /// re-polls instead of spinning at zero.
    fn poll_timeout(&self, now: Instant) -> Duration {
        let mut t = self.batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
        // park_on_miss deadlines wake the scheduler whether the row is
        // mid-decode (eviction) or still queued (shedding)
        for r in self.rows.values() {
            if r.park_on_miss && !r.evict_sent {
                t = t.min(r.deadline.saturating_duration_since(now));
            }
        }
        t.clamp(Duration::from_millis(1), Duration::from_millis(50))
    }

    fn handle(&mut self, msg: Msg) {
        match msg {
            Msg::Submit(job) => self.enqueue(job),
            Msg::Cancel { id } => self.cancel_row(id),
            Msg::Shutdown => self.shutdown = true,
            Msg::Worker(ev) => self.on_worker_event(ev),
        }
    }

    /// Backoff hint for a reject: current queue depth × observed
    /// per-block service time. Before the first observed block round
    /// the batcher's flush window stands in, so the hint is always
    /// finite, and it is clamped to [1ms, 60s] — a cold-start EWMA fed
    /// one pathological block round must not tell clients to go away
    /// for hours.
    fn retry_after_ms(&self, key: GroupKey) -> u64 {
        let per_block = self
            .est_block_secs
            .unwrap_or_else(|| self.opts.max_wait.as_secs_f64().max(0.001));
        let depth = self.batcher.depth(key).max(1) as f64;
        (depth * per_block * 1000.0).ceil().clamp(1.0, 60_000.0) as u64
    }

    fn enqueue(&mut self, job: Job) {
        self.metrics.record_submitted();
        // Bounded admission: a full group queue answers a typed reject
        // with a retry hint instead of growing without limit. Checked
        // only here — internal requeues (worker overflow bounces) are
        // in-flight work and always re-enter the queue.
        if self.batcher.is_full(job.request.group_key()) {
            self.metrics.record_rejected();
            let hint = self.retry_after_ms(job.request.group_key());
            job.reply.send_done(Response::rejected(job.request.id, hint));
            return;
        }
        let deadline = self.batcher.effective_deadline(&job.request, job.arrived);
        let row = RowState {
            reply: job.reply,
            arrived: job.arrived,
            deadline,
            park_on_miss: job.request.park_on_miss,
            kind: AdmitKind::BatchStart,
            admitted_at: None,
            worker: None,
            evict_sent: false,
            detached: false,
        };
        self.rows.insert(job.request.id, row);
        self.batcher.push_at(job.request, job.arrived);
        self.metrics.note_queue_depth(self.batcher.pending());
    }

    /// Resolve a cancel: a still-queued row leaves the batcher now; an
    /// admitted row is evicted at the next block boundary; a row in
    /// flight to a worker (admit sent, not yet confirmed) is only
    /// flagged and resolves silently when it completes. All three paths
    /// land in the `cancelled` counter exactly once.
    fn cancel_row(&mut self, id: u64) {
        let Some(r) = self.rows.get(&id) else { return };
        if r.admitted_at.is_none() && r.worker.is_none() && self.batcher.remove(id).is_some() {
            self.rows.remove(&id);
            self.metrics.record_cancelled();
            return;
        }
        let Some(r) = self.rows.get_mut(&id) else { return };
        r.detached = true;
        // only a confirmed engine admission can be evicted — the worker
        // treats Evict for unknown ids as a no-op, so a row parked in a
        // worker's cross-method pending queue must resolve at completion
        if r.admitted_at.is_some() && !r.evict_sent {
            if let Some(w) = r.worker {
                r.evict_sent = true;
                let _ = self.workers[w].tx.send(WorkerCmd::Evict { id });
            }
        }
    }

    /// Load shedding: queued `park_on_miss` rows whose effective
    /// deadline already passed are answered as shed — decoding them
    /// could only produce an instantly-evicted empty park, so the slot
    /// goes to a request that can still meet its deadline. Counted
    /// separately from `deadline_misses` (late completions).
    fn shed_blown(&mut self) {
        let now = Instant::now();
        for req in self.batcher.drain_blown(now) {
            if let Some(row) = self.rows.remove(&req.id) {
                self.metrics.record_shed();
                let queue_s = now.duration_since(row.arrived).as_secs_f64();
                row.reply.send_done(Response::shed(req.id, queue_s));
            }
        }
    }

    fn on_worker_event(&mut self, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Ready { worker, capacity } => {
                self.workers[worker].ready = true;
                self.workers[worker].capacity = capacity;
                // the batcher's flush size must not exceed the smallest
                // live worker's slot count, or batches would overflow
                let min_cap = self
                    .workers
                    .iter()
                    .filter(|w| !w.dead && w.ready)
                    .map(|w| w.capacity)
                    .min()
                    .unwrap_or(self.opts.max_batch);
                self.batcher.max_batch = min_cap.min(self.opts.max_batch).max(1);
            }
            WorkerEvent::Died { worker, error } => {
                self.workers[worker].dead = true;
                self.workers[worker].ready = false;
                self.workers[worker].assigned = None;
                let lost: Vec<u64> = self
                    .rows
                    .iter()
                    .filter(|(_, r)| r.worker == Some(worker))
                    .map(|(&id, _)| id)
                    .collect();
                for id in lost {
                    self.fail(id, &error);
                }
            }
            WorkerEvent::Admitted { worker: _, id } => {
                if let Some(r) = self.rows.get_mut(&id) {
                    r.admitted_at = Some(Instant::now());
                    let kind = r.kind;
                    self.metrics.record_admission();
                    match kind {
                        AdmitKind::BatchStart => self.metrics.record_batch_admit(),
                        AdmitKind::Join => self.metrics.record_join(),
                    }
                }
            }
            WorkerEvent::AdmitFailed { worker, id, error } => {
                self.workers[worker].outstanding =
                    self.workers[worker].outstanding.saturating_sub(1);
                self.fail(id, &error);
            }
            WorkerEvent::Overflow { worker, req } => {
                self.workers[worker].outstanding =
                    self.workers[worker].outstanding.saturating_sub(1);
                let arrived = match self.rows.get_mut(&req.id) {
                    Some(r) => {
                        r.worker = None;
                        r.arrived
                    }
                    None => return,
                };
                self.batcher.push_at(req, arrived);
            }
            WorkerEvent::Round { worker, key, commits, done, busy_secs } => {
                if busy_secs > 0.0 {
                    self.metrics.record_busy(key.method.name(), busy_secs);
                    // smooth the per-block service time the reject
                    // hint is derived from (EWMA, α = 0.2)
                    self.est_block_secs = Some(match self.est_block_secs {
                        Some(est) => 0.8 * est + 0.2 * busy_secs,
                        None => busy_secs,
                    });
                }
                // self-correct after multiplexing: the worker reports
                // which policy group it is actually decoding
                if self.workers[worker].assigned.is_none() {
                    self.workers[worker].assigned = Some(key);
                }
                for c in commits {
                    if let Some(r) = self.rows.get(&c.tag) {
                        if let ReplyTx::Stream(tx) = &r.reply {
                            let _ = tx.send(StreamFrame::Commit(CommitEvent {
                                id: c.tag,
                                seq: c.seq,
                                block: c.block,
                                writes: c.writes,
                            }));
                        }
                    }
                }
                for d in done {
                    self.complete(worker, d);
                }
            }
            WorkerEvent::EngineFailed { worker, ids, error } => {
                for id in ids {
                    self.workers[worker].outstanding =
                        self.workers[worker].outstanding.saturating_sub(1);
                    self.fail(id, &error);
                }
            }
            WorkerEvent::Retired { worker, key, report, rounds, mixed_rounds } => {
                self.metrics.record_engine(&report, rounds, mixed_rounds);
                if self.workers[worker].assigned == Some(key) {
                    self.workers[worker].assigned = None;
                }
            }
        }
    }

    /// Send eviction requests for admitted `park_on_miss` rows whose
    /// effective deadline has passed. Queued-not-yet-admitted rows are
    /// never parked — they decode normally (and count a miss) later.
    fn park_blown_rows(&mut self) {
        let now = Instant::now();
        let mut evict: Vec<(u64, usize)> = Vec::new();
        for (&id, r) in self.rows.iter_mut() {
            if r.park_on_miss && !r.evict_sent && now > r.deadline && r.admitted_at.is_some() {
                if let Some(w) = r.worker {
                    r.evict_sent = true;
                    evict.push((id, w));
                }
            }
        }
        for (id, w) in evict {
            let _ = self.workers[w].tx.send(WorkerCmd::Evict { id });
        }
    }

    /// Start an engine for every ready policy group without one:
    /// idle worker first, then a fresh spawn under the `max_engines`
    /// cap, then multiplexing onto the least-loaded live worker.
    fn start_engines(&mut self) {
        loop {
            let now = Instant::now();
            let busy: Vec<GroupKey> =
                self.workers.iter().filter(|w| !w.dead).filter_map(|w| w.assigned).collect();
            let Some((key, batch)) = self.batcher.pop_ready(now, &busy) else { return };
            self.metrics.record_batch(batch.len());
            // Intra-batch dedup accounting: rows in this flush that
            // share a common prompt prefix with the first row decode
            // their template from one shared prefill (via the prefix
            // cache) instead of N independent ones.
            if self.prefix_cache.is_some() {
                let dedup = shared_prefix_rows(&batch, DEDUP_MIN_PREFIX);
                if dedup > 0 {
                    self.metrics.record_prefix_dedup(dedup as u64);
                }
            }
            let Some(wix) = self.pick_worker() else {
                // no routable worker (all dead at the cap): requeue with
                // original arrivals and retry on a later pass
                for req in batch {
                    let arrived = self.rows.get(&req.id).map(|r| r.arrived).unwrap_or(now);
                    self.batcher.push_at(req, arrived);
                }
                return;
            };
            if self.workers[wix].assigned.is_none() {
                self.workers[wix].assigned = Some(key);
            }
            for req in batch {
                self.send_admit(wix, req, AdmitKind::BatchStart);
            }
        }
    }

    fn pick_worker(&mut self) -> Option<usize> {
        if let Some(i) = self.workers.iter().position(|w| !w.dead && w.assigned.is_none()) {
            return Some(i);
        }
        let live = self.workers.iter().filter(|w| !w.dead).count();
        if live < self.opts.max_engines {
            let i = self.workers.len();
            let (tx, join) = spawn_worker(
                i,
                self.factory.clone(),
                self.opts.max_batch,
                self.opts.decode_threads.max(1),
                self.prefix_cache.clone(),
                self.events.clone(),
            );
            self.workers.push(WorkerSlot {
                tx,
                join: Some(join),
                assigned: None,
                outstanding: 0,
                capacity: self.opts.max_batch,
                ready: false,
                dead: false,
            });
            return Some(i);
        }
        self.workers
            .iter()
            .enumerate()
            .filter(|(_, w)| !w.dead)
            .min_by_key(|(_, w)| w.outstanding)
            .map(|(i, _)| i)
    }

    fn send_admit(&mut self, wix: usize, req: Request, kind: AdmitKind) {
        let id = req.id;
        let traced = match self.rows.get_mut(&id) {
            Some(row) => {
                row.kind = kind;
                row.worker = Some(wix);
                matches!(row.reply, ReplyTx::Stream(_))
            }
            None => return,
        };
        self.workers[wix].outstanding += 1;
        let cmd = WorkerCmd::Admit(AdmitReq { request: req, traced });
        if self.workers[wix].tx.send(cmd).is_err() {
            self.workers[wix].dead = true;
            self.workers[wix].assigned = None;
            self.workers[wix].outstanding = self.workers[wix].outstanding.saturating_sub(1);
            self.fail(id, "worker thread died");
        }
    }

    /// Fill freed slots on running engines with same-group waiters,
    /// earliest effective deadline first (mid-flight joins).
    fn top_up(&mut self) {
        for i in 0..self.workers.len() {
            if self.workers[i].dead || !self.workers[i].ready {
                continue;
            }
            let Some(key) = self.workers[i].assigned else { continue };
            while self.workers[i].outstanding < self.workers[i].capacity {
                let Some(req) = self.batcher.pop_compatible(key) else { break };
                self.send_admit(i, req, AdmitKind::Join);
            }
        }
    }

    /// Answer a retired (finished or parked) row.
    fn complete(&mut self, worker: usize, d: RowDone) {
        self.workers[worker].outstanding = self.workers[worker].outstanding.saturating_sub(1);
        let Some(row) = self.rows.remove(&d.id) else { return };
        if row.detached {
            // the subscriber is gone: resolve silently; dropping the
            // reply sender is what disconnects the relay loop
            self.metrics.record_cancelled();
            return;
        }
        let now = Instant::now();
        let started = row.admitted_at.unwrap_or(row.arrived);
        let queue_s = started.duration_since(row.arrived).as_secs_f64();
        let latency_s = now.duration_since(started).as_secs_f64();
        let resp = Response {
            id: d.id,
            text: d.text,
            non_eos_tokens: d.non_eos_tokens,
            latency_s,
            queue_s,
            parked: d.parked,
            rejected: false,
            shed: false,
            retry_after_ms: None,
            error: None,
        };
        self.metrics.record_response(true, resp.non_eos_tokens, latency_s, queue_s);
        if d.parked {
            self.metrics.record_parked();
        } else {
            self.metrics.record_answered();
            if now > row.deadline {
                self.metrics.record_deadline_miss();
            }
        }
        row.reply.send_done(resp);
    }

    /// Answer a request with an error and account for it.
    fn fail(&mut self, id: u64, err: &str) {
        if let Some(row) = self.rows.remove(&id) {
            if row.detached {
                self.metrics.record_cancelled();
                return;
            }
            self.metrics.record_response(false, 0, 0.0, 0.0);
            self.metrics.record_answered();
            row.reply.send_done(Response::failure(id, err));
        }
    }

    /// Refresh the scheduling gauges: per-method (queued, routed) depth
    /// and the engines-active gauge + high-water mark. Gauges stay
    /// method-labeled (their historical meaning): policy groups within
    /// a method fold into one row via [`Batcher::method_depth`].
    fn refresh_gauges(&self) {
        let engines = self.workers.iter().filter(|w| !w.dead && w.assigned.is_some()).count();
        let depths: Vec<(&'static str, usize, usize)> = Method::all()
            .into_iter()
            .filter_map(|m| {
                let queued = self.batcher.method_depth(m);
                let active: usize = self
                    .workers
                    .iter()
                    .filter(|w| !w.dead && w.assigned.map(|k| k.method) == Some(m))
                    .map(|w| w.outstanding)
                    .sum();
                (queued + active > 0).then_some((m.name(), queued, active))
            })
            .collect();
        self.metrics.set_groups(depths, engines);
        let workers: Vec<WorkerGauge> = self
            .workers
            .iter()
            .map(|w| WorkerGauge {
                outstanding: w.outstanding,
                capacity: w.capacity,
                assigned: w.assigned.map(|k| k.method.name()),
                ready: w.ready,
                dead: w.dead,
            })
            .collect();
        self.metrics.set_workers(workers);
        if let Some(cache) = &self.prefix_cache {
            self.metrics.set_prefix_cache(cache.stats());
        }
    }

    /// Orderly shutdown: stop every worker, join them, then drain the
    /// inbox so final `Retired` totals land in the metrics.
    fn finish(mut self, rx: &Receiver<Msg>) -> Result<()> {
        for w in &self.workers {
            if !w.dead {
                let _ = w.tx.send(WorkerCmd::Shutdown);
            }
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
        while let Ok(msg) = rx.try_recv() {
            match msg {
                Msg::Worker(ev) => self.on_worker_event(ev),
                Msg::Submit(job) => {
                    let id = job.request.id;
                    job.reply.send_done(Response::failure(id, "router shut down"));
                }
                Msg::Cancel { .. } => {}
                Msg::Shutdown => {}
            }
        }
        self.refresh_gauges();
        Ok(())
    }
}
