//! Router: the engine thread. Model backends are generally not `Send`
//! (PJRT handles wrap raw pointers), so one dedicated thread *builds*
//! and owns the backend; everything else talks to it through a channel
//! of jobs.
//!
//! The admission loop is *continuous at block granularity*: ready
//! batches from the `Batcher` start a slot-based [`BatchEngine`], and
//! between block rounds the loop admits compatible queued requests into
//! slots freed by finished or early-exited rows — a request that
//! arrives while a batch is decoding joins it mid-flight instead of
//! waiting for the full drain. Finished rows are answered the moment
//! their own decode completes.
//!
//! Construction is a factory closure executed on the engine thread
//! (`spawn_with`), with two conveniences: `spawn_reference` (pure-Rust
//! backend, always available) and `spawn` (PJRT artifacts, behind the
//! `pjrt` feature).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{Backend, BatchEngine, GenConfig, RefMode, ReferenceBackend, REFERENCE_SEED};

use super::batcher::{Batcher, GroupKey};
use super::metrics::Metrics;
use super::request::{Request, Response};

/// A submitted request plus its reply channel and arrival time.
pub struct Job {
    pub request: Request,
    pub reply: Sender<Response>,
    pub arrived: Instant,
}

/// Control messages for the engine thread.
pub enum Msg {
    Submit(Job),
    Shutdown,
}

pub struct RouterHandle {
    tx: Sender<Msg>,
    join: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
}

impl RouterHandle {
    /// Spawn the engine thread around a backend built *on that thread*
    /// by `factory` (backends need not be `Send`).
    pub fn spawn_with<B, F>(factory: F, max_batch: usize, max_wait: Duration) -> RouterHandle
    where
        B: Backend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let join = std::thread::Builder::new()
            .name("sdllm-router".into())
            .spawn(move || {
                let backend = factory()?;
                engine_loop(&backend, max_batch, max_wait, rx, m2)
            })
            .expect("spawn router thread");
        RouterHandle { tx, join: Some(join), metrics }
    }

    /// Engine thread over the deterministic reference backend (toy
    /// mode) — serves on a bare checkout, no artifacts or accelerator
    /// required.
    pub fn spawn_reference(max_batch: usize, max_wait: Duration) -> RouterHandle {
        RouterHandle::spawn_reference_mode(RefMode::Toy, max_batch, max_wait)
    }

    /// Engine thread over a reference backend in the given mode (the
    /// serve-path analogue of `--ref-mode`; scripted maps to toy).
    pub fn spawn_reference_mode(
        mode: RefMode,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        RouterHandle::spawn_with(
            move || {
                Ok(match mode {
                    RefMode::Causal => ReferenceBackend::causal(REFERENCE_SEED),
                    _ => ReferenceBackend::toy(REFERENCE_SEED),
                })
            },
            max_batch,
            max_wait,
        )
    }

    /// Engine thread serving `model` from `artifacts_root` on PJRT.
    #[cfg(feature = "pjrt")]
    pub fn spawn(
        artifacts_root: std::path::PathBuf,
        model: String,
        max_batch: usize,
        max_wait: Duration,
    ) -> RouterHandle {
        use crate::runtime::{warmup, ArtifactsIndex, ModelRuntime, Runtime};
        RouterHandle::spawn_with(
            move || {
                let rt = Runtime::cpu()?;
                let index = ArtifactsIndex::load(&artifacts_root)?;
                let model_rt = ModelRuntime::load(&rt, &index.model_dir(&model))?;
                // Pre-warm the default serving path so first requests
                // don't pay lazy executable compilation (best effort:
                // unknown methods/lengths still compile on demand).
                let warm_cfg = GenConfig::preset(crate::engine::Method::Streaming, 64);
                if let Ok(n) = warmup::warm_for(&model_rt, &warm_cfg, 224, max_batch) {
                    if n > 0 {
                        eprintln!("[router] pre-warmed {n} executables");
                    }
                }
                Ok(model_rt)
            },
            max_batch,
            max_wait,
        )
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, request: Request) -> Receiver<Response> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { request, reply: reply_tx, arrived: Instant::now() };
        // If the engine thread died the reply channel is dropped and the
        // caller sees a disconnect — no panic here.
        let _ = self.tx.send(Msg::Submit(job));
        reply_rx
    }

    /// Blocking convenience: submit and wait.
    pub fn call(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request);
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            match j.join() {
                Ok(r) => r,
                Err(_) => anyhow::bail!("router thread panicked"),
            }
        } else {
            Ok(())
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The in-flight engine plus per-request admission times (for queue /
/// latency accounting).
struct EngineRun<'b, B: Backend> {
    key: GroupKey,
    engine: BatchEngine<'b, B>,
    admitted: HashMap<u64, Instant>,
}

/// Answer a request with an error and account for it.
fn fail(
    replies: &mut HashMap<u64, (Sender<Response>, Instant)>,
    metrics: &Metrics,
    id: u64,
    err: &str,
) {
    if let Some((tx, _)) = replies.remove(&id) {
        metrics.record_response(false, 0, 0.0, 0.0);
        let _ = tx.send(Response {
            id,
            text: String::new(),
            non_eos_tokens: 0,
            latency_s: 0.0,
            queue_s: 0.0,
            error: Some(err.to_string()),
        });
    }
}

fn engine_loop<B: Backend>(
    backend: &B,
    max_batch: usize,
    max_wait: Duration,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    metrics.start_clock();

    // Clamp the serving batch to what the backend's batch buckets carry
    // up front, so the batcher never hands an engine more rows than it
    // has slots (keeps record_batch and the joins metric honest).
    let engine_cap = crate::engine::clamp_batch(backend, max_batch);
    let mut batcher = Batcher::new(engine_cap, max_wait);
    let mut replies: HashMap<u64, (Sender<Response>, Instant)> = HashMap::new();
    let mut shutdown = false;
    let mut active: Option<EngineRun<'_, B>> = None;

    loop {
        // Drain the inbox. With an engine mid-flight we must not block —
        // decode keeps moving and new arrivals join at the next block
        // boundary; when idle, wait out the batcher's flush deadline.
        if active.is_some() {
            loop {
                match rx.try_recv() {
                    Ok(Msg::Submit(job)) => {
                        replies.insert(job.request.id, (job.reply, job.arrived));
                        batcher.push_at(job.request, job.arrived);
                    }
                    Ok(Msg::Shutdown) => shutdown = true,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
        } else {
            // A group can already be runnable (full, or flushed by a
            // deadline that passed while the last engine was busy) —
            // never sleep on the inbox in that case.
            let now = Instant::now();
            let timeout = if batcher.has_ready(now) {
                Duration::ZERO
            } else {
                batcher.next_deadline(now).unwrap_or(Duration::from_millis(50))
            };
            match rx.recv_timeout(timeout) {
                Ok(Msg::Submit(job)) => {
                    replies.insert(job.request.id, (job.reply, job.arrived));
                    batcher.push_at(job.request, job.arrived);
                    // opportunistically drain whatever else is queued
                    while let Ok(msg) = rx.try_recv() {
                        match msg {
                            Msg::Submit(j) => {
                                replies.insert(j.request.id, (j.reply, j.arrived));
                                batcher.push_at(j.request, j.arrived);
                            }
                            Msg::Shutdown => shutdown = true,
                        }
                    }
                }
                Ok(Msg::Shutdown) => shutdown = true,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => shutdown = true,
            }
        }

        // Start an engine when idle and a group is ready.
        if active.is_none() {
            if let Some((key, batch)) = batcher.pop_ready(Instant::now()) {
                metrics.record_batch(batch.len());
                let cfg = GenConfig::preset(key.method, key.gen_len);
                match BatchEngine::new(backend, cfg, engine_cap) {
                    Ok(engine) => {
                        let mut run = EngineRun { key, engine, admitted: HashMap::new() };
                        let now = Instant::now();
                        for req in batch {
                            if !run.engine.fits(req.prompt.len()) {
                                // fail the oversized request alone — its
                                // batchmates keep decoding
                                fail(
                                    &mut replies,
                                    &metrics,
                                    req.id,
                                    "prompt exceeds backend buckets",
                                );
                            } else if run.engine.admit(req.id, &req.prompt) {
                                run.admitted.insert(req.id, now);
                            } else {
                                // defensive: the batcher flush size is
                                // clamped to engine capacity, but if the
                                // two ever drift, requeue (original
                                // arrival preserved) — the overflow joins
                                // as rows finish and free slots
                                let arrived = replies
                                    .get(&req.id)
                                    .map(|(_, a)| *a)
                                    .unwrap_or_else(Instant::now);
                                batcher.push_at(req, arrived);
                            }
                        }
                        active = Some(run);
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        for req in &batch {
                            fail(&mut replies, &metrics, req.id, &msg);
                        }
                    }
                }
            }
        }

        // Admit compatible waiters into free slots, run one block
        // round, answer whoever finished. Joins pause the moment some
        // *other* group's front request outlives max_wait: the engine
        // then drains naturally and the starving group gets scheduled —
        // a hot compatible stream can't keep one engine alive forever.
        let mut retire = false;
        if let Some(run) = active.as_mut() {
            let now = Instant::now();
            while run.engine.has_free_slot() && !batcher.starving_other(run.key, now) {
                let Some(req) = batcher.pop_compatible(run.key) else { break };
                if !run.engine.fits(req.prompt.len()) {
                    // oversized joiner: fail it alone, keep admitting —
                    // it must not poison the rows already mid-decode
                    fail(&mut replies, &metrics, req.id, "prompt exceeds backend buckets");
                    continue;
                }
                if run.engine.admit(req.id, &req.prompt) {
                    run.admitted.insert(req.id, Instant::now());
                    metrics.record_join();
                } else {
                    fail(&mut replies, &metrics, req.id, "engine slots exhausted");
                }
            }
            match run.engine.step_block() {
                Ok(done) => {
                    let now = Instant::now();
                    for f in done {
                        let started = run.admitted.remove(&f.tag);
                        if let Some((tx, arrived)) = replies.remove(&f.tag) {
                            let started = started.unwrap_or(arrived);
                            let queue_s = started.duration_since(arrived).as_secs_f64();
                            let latency_s = now.duration_since(started).as_secs_f64();
                            let resp = Response {
                                id: f.tag,
                                text: backend.detokenize(f.seq.generated()),
                                non_eos_tokens: f.seq.non_eos_tokens(),
                                latency_s,
                                queue_s,
                                error: None,
                            };
                            metrics.record_response(true, resp.non_eos_tokens, latency_s, queue_s);
                            let _ = tx.send(resp);
                        }
                    }
                    retire = run.engine.active() == 0;
                }
                Err(e) => {
                    // engine poisoned: fail every row still inside
                    let msg = format!("{e:#}");
                    for (id, _) in run.admitted.drain() {
                        fail(&mut replies, &metrics, id, &msg);
                    }
                    retire = true;
                }
            }
        }
        if retire {
            if let Some(run) = active.take() {
                metrics.record_engine(run.engine.report(), run.engine.rounds());
            }
        }

        if shutdown && active.is_none() && batcher.pending() == 0 {
            return Ok(());
        }
    }
}
