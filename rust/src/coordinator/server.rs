//! TCP server: line-delimited JSON requests in, responses out.
//! One thread per connection (request parsing is trivial; the heavy
//! lifting serializes on the router's engine thread anyway). The special
//! line `{"cmd":"stats"}` returns the metrics snapshot; `{"cmd":"ping"}`
//! health-checks.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::request::Request;
use super::router::RouterHandle;

pub struct Server {
    listener: TcpListener,
    router: Arc<RouterHandle>,
}

impl Server {
    pub fn bind(addr: &str, router: RouterHandle) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { listener, router: Arc::new(router) })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until the process exits (each connection on its own thread).
    pub fn serve_forever(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = stream?;
            let router = self.router.clone();
            std::thread::spawn(move || {
                let peer = stream.peer_addr().ok();
                if let Err(e) = handle_conn(stream, &router) {
                    eprintln!("[server] connection {peer:?} error: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Serve exactly `n` connections then return (used by tests and the
    /// serve_batch example to terminate cleanly).
    pub fn serve_n(&self, n: usize) -> Result<()> {
        let mut handles = vec![];
        for stream in self.listener.incoming().take(n) {
            let stream = stream?;
            let router = self.router.clone();
            handles.push(std::thread::spawn(move || {
                let _ = handle_conn(stream, &router);
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, router: &RouterHandle) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Ok(j) => {
                if let Some(cmd) = j.get("cmd").and_then(|c| c.as_str()) {
                    match cmd {
                        "stats" => router.metrics.snapshot(),
                        "ping" => Json::obj(vec![("pong", Json::Bool(true))]),
                        other => Json::obj(vec![(
                            "error",
                            Json::Str(format!("unknown cmd '{other}'")),
                        )]),
                    }
                } else {
                    match Request::from_json(&j) {
                        Ok(req) => match router.call(req) {
                            Ok(resp) => resp.to_json(),
                            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
                        },
                        Err(e) => Json::obj(vec![("error", Json::Str(e))]),
                    }
                }
            }
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e}")))]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}
