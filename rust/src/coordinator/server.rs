//! TCP server: line-delimited JSON in, frames out. One thread per
//! connection (request parsing is trivial; decode happens on the
//! router's worker threads). All byte shapes live in
//! [`super::protocol`] — both generations are served on the same port:
//! legacy v0 lines (`{"id":..,"prompt":[..]}`, `{"cmd":"stats"}`,
//! `{"cmd":"ping"}`) answer in legacy shapes, and v1 envelopes
//! (`{"v":1,"type":...}`) unlock `subscribe`, which streams per-row
//! commit frames as blocks retire before the terminal `done` frame.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::protocol::{error_frame, parse_client_line, pong_frame, response_frame, stats_frame};
use super::protocol::ClientFrame;
use super::router::{RouterHandle, StreamFrame};

pub struct Server {
    listener: TcpListener,
    router: Arc<RouterHandle>,
}

impl Server {
    pub fn bind(addr: &str, router: RouterHandle) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server { listener, router: Arc::new(router) })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until the process exits (each connection on its own thread).
    pub fn serve_forever(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = stream?;
            let router = self.router.clone();
            std::thread::spawn(move || {
                let peer = stream.peer_addr().ok();
                if let Err(e) = handle_conn(stream, &router) {
                    eprintln!("[server] connection {peer:?} error: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Serve exactly `n` connections then return (used by tests and the
    /// serve_batch example to terminate cleanly).
    pub fn serve_n(&self, n: usize) -> Result<()> {
        let mut handles = vec![];
        for stream in self.listener.incoming().take(n) {
            let stream = stream?;
            let router = self.router.clone();
            handles.push(std::thread::spawn(move || {
                let _ = handle_conn(stream, &router);
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn write_frame(writer: &mut TcpStream, frame: &Json) -> Result<()> {
    writer.write_all(frame.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn handle_conn(stream: TcpStream, router: &RouterHandle) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_client_line(&line) {
            Ok(ClientFrame::Stats { v }) => {
                write_frame(&mut writer, &stats_frame(v, router.metrics.snapshot()))?;
            }
            Ok(ClientFrame::Ping { v }) => {
                write_frame(&mut writer, &pong_frame(v))?;
            }
            Ok(ClientFrame::Generate { v, request }) => {
                let id = request.id;
                match router.call(request) {
                    Ok(resp) => write_frame(&mut writer, &response_frame(v, &resp))?,
                    Err(e) => {
                        // router gone: v0 keeps the bare no-id error
                        // shape, v1 attributes the failure to the id
                        let id = (v > 0).then_some(id);
                        write_frame(&mut writer, &error_frame(v, id, &format!("{e:#}")))?;
                    }
                }
            }
            Ok(ClientFrame::Subscribe { request }) => {
                // v1-only: relay the row's commit stream as it arrives,
                // then the terminal done frame; the connection then goes
                // back to line dispatch.
                let id = request.id;
                let rx = router.subscribe(request);
                loop {
                    match rx.recv() {
                        Ok(StreamFrame::Commit(ev)) => write_frame(&mut writer, &ev.to_json())?,
                        Ok(StreamFrame::Done(resp)) => {
                            write_frame(&mut writer, &response_frame(1, &resp))?;
                            break;
                        }
                        Err(_) => {
                            write_frame(
                                &mut writer,
                                &error_frame(1, Some(id), "router shut down"),
                            )?;
                            break;
                        }
                    }
                }
            }
            Err(we) => {
                write_frame(&mut writer, &error_frame(we.v, we.id, &we.msg))?;
            }
        }
    }
    Ok(())
}
