//! TCP server: line-delimited JSON in, frames out. One thread per
//! connection (request parsing is trivial; decode happens on the
//! router's worker threads), capped at
//! [`Server::with_max_connections`] — connections over the cap are
//! answered with one `busy` error frame and closed, so a connection
//! flood degrades into fast refusals instead of unbounded threads.
//! All byte shapes live in [`super::protocol`] — both generations are
//! served on the same port: legacy v0 lines
//! (`{"id":..,"prompt":[..]}`, `{"cmd":"stats"}`, `{"cmd":"ping"}`)
//! answer in legacy shapes, and v1 envelopes (`{"v":1,"type":...}`)
//! unlock `subscribe`, which streams per-row commit frames as blocks
//! retire before the terminal `done` frame.
//!
//! Connection lifecycle is overload-safe: lines are read through a
//! bounded reader ([`MAX_LINE_BYTES`]) so an oversized or non-UTF-8
//! line answers a typed error frame and the connection lives on (only
//! hard IO errors close it), and a subscriber that disconnects
//! mid-stream cancels its row on the router so no engine slot keeps
//! decoding into the void.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::engine::DecodePolicy;
use crate::util::json::Json;

use super::metrics::Metrics;
use super::protocol::{
    busy_frame, error_frame, parse_client_line, pong_frame, reject_frame, response_frame,
    stats_frame,
};
use super::protocol::{ClientFrame, StatsFormat};
use super::router::{RouterHandle, StreamFrame};

/// Hard cap on one protocol line. A line that exceeds it is discarded
/// (never buffered whole) and answered with a typed error frame — the
/// connection survives.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Default cap on concurrently served connections.
pub const DEFAULT_MAX_CONNECTIONS: usize = 64;

pub struct Server {
    listener: TcpListener,
    router: Arc<RouterHandle>,
    max_connections: usize,
    /// served default decode policy, applied to generate/subscribe
    /// requests that don't name one (requests that do always win)
    default_policy: Option<DecodePolicy>,
    active: Arc<AtomicUsize>,
}

/// Releases one connection slot when the handler thread finishes, on
/// every exit path (normal close, protocol error, panic unwind).
struct ConnGuard {
    active: Arc<AtomicUsize>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Server {
    pub fn bind(addr: &str, router: RouterHandle) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server {
            listener,
            router: Arc::new(router),
            max_connections: DEFAULT_MAX_CONNECTIONS,
            default_policy: None,
            active: Arc::new(AtomicUsize::new(0)),
        })
    }

    /// Cap concurrently served connections (min 1). Connections over
    /// the cap get one `busy` error frame and are closed immediately.
    pub fn with_max_connections(mut self, max: usize) -> Server {
        self.max_connections = max.max(1);
        self
    }

    /// Serve `policy` as the default decode policy: requests that don't
    /// carry a `policy` field decode with it (`--policy`/`SDLLM_POLICY`
    /// on the CLI). Explicit per-request policies always win.
    pub fn with_default_policy(mut self, policy: Option<DecodePolicy>) -> Server {
        self.default_policy = policy;
        self
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The router's shared metrics — lets tests and operators poll the
    /// capacity picture through the serving surface without holding a
    /// router handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.router.metrics.clone()
    }

    /// Claim a connection slot, or `None` at the cap.
    fn try_admit(&self) -> Option<ConnGuard> {
        let prev = self.active.fetch_add(1, Ordering::SeqCst);
        if prev >= self.max_connections {
            self.active.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        Some(ConnGuard { active: self.active.clone() })
    }

    /// Serve until the process exits (each connection on its own
    /// thread, at most `max_connections` concurrently).
    pub fn serve_forever(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let mut stream = stream?;
            let Some(guard) = self.try_admit() else {
                let _ = write_frame(&mut stream, &busy_frame(self.max_connections));
                continue; // dropping the stream closes the refused socket
            };
            let router = self.router.clone();
            let default_policy = self.default_policy;
            std::thread::spawn(move || {
                let _guard = guard;
                let peer = stream.peer_addr().ok();
                if let Err(e) = handle_conn(stream, &router, default_policy) {
                    eprintln!("[server] connection {peer:?} error: {e:#}");
                }
            });
        }
        Ok(())
    }

    /// Serve exactly `n` accepted connections then return (used by
    /// tests and the serve_batch example to terminate cleanly). A
    /// connection refused at the cap still counts toward `n`.
    pub fn serve_n(&self, n: usize) -> Result<()> {
        let mut handles = vec![];
        for stream in self.listener.incoming().take(n) {
            let mut stream = stream?;
            let Some(guard) = self.try_admit() else {
                let _ = write_frame(&mut stream, &busy_frame(self.max_connections));
                continue;
            };
            let router = self.router.clone();
            let default_policy = self.default_policy;
            handles.push(std::thread::spawn(move || {
                let _guard = guard;
                let _ = handle_conn(stream, &router, default_policy);
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn write_frame(writer: &mut TcpStream, frame: &Json) -> Result<()> {
    writer.write_all(frame.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

/// Multi-line payload (the Prometheus-style stats body, already
/// terminated by its `# EOF` line).
fn write_text(writer: &mut TcpStream, body: &str) -> Result<()> {
    writer.write_all(body.as_bytes())?;
    writer.flush()?;
    Ok(())
}

/// One logical read off the socket. Recoverable problems (oversized
/// line, invalid UTF-8) are *values*, not errors — the caller answers
/// a typed error frame and keeps the connection; only hard IO errors
/// propagate.
enum LineRead {
    Line(String),
    /// total byte length of a line that exceeded [`MAX_LINE_BYTES`]
    /// (the payload itself was discarded, never buffered whole)
    TooLong(usize),
    BadUtf8,
    Eof,
}

/// Read one `\n`-terminated line without ever buffering more than
/// [`MAX_LINE_BYTES`] of it. Mirrors `BufRead::lines` semantics
/// otherwise: a trailing `\r` is stripped, and a final unterminated
/// line at EOF is still dispatched.
fn read_line_capped<R: BufRead>(reader: &mut R) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut dropped = 0usize;
    loop {
        let (done, used) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                if buf.is_empty() && dropped == 0 {
                    return Ok(LineRead::Eof);
                }
                (true, 0)
            } else {
                match available.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        if dropped == 0 && buf.len() + i <= MAX_LINE_BYTES {
                            buf.extend_from_slice(&available[..i]);
                        } else {
                            dropped += i;
                        }
                        (true, i + 1)
                    }
                    None => {
                        let n = available.len();
                        if dropped == 0 && buf.len() + n <= MAX_LINE_BYTES {
                            buf.extend_from_slice(available);
                        } else {
                            dropped += n;
                        }
                        (false, n)
                    }
                }
            }
        };
        reader.consume(used);
        if done {
            if dropped > 0 {
                return Ok(LineRead::TooLong(buf.len() + dropped));
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(match String::from_utf8(buf) {
                Ok(s) => LineRead::Line(s),
                Err(_) => LineRead::BadUtf8,
            });
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    router: &RouterHandle,
    default_policy: Option<DecodePolicy>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_capped(&mut reader)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong(n) => {
                write_frame(
                    &mut writer,
                    &error_frame(
                        1,
                        None,
                        &format!("line too long ({n} bytes > {MAX_LINE_BYTES} max)"),
                    ),
                )?;
                continue;
            }
            LineRead::BadUtf8 => {
                write_frame(&mut writer, &error_frame(1, None, "invalid utf-8"))?;
                continue;
            }
            LineRead::Line(line) => line,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_client_line(&line) {
            Ok(ClientFrame::Stats { v, format }) => match format {
                StatsFormat::Json => {
                    write_frame(&mut writer, &stats_frame(v, router.metrics.snapshot()))?;
                }
                StatsFormat::Prometheus => {
                    write_text(&mut writer, &router.metrics.prometheus())?;
                }
            },
            Ok(ClientFrame::Ping { v }) => {
                write_frame(&mut writer, &pong_frame(v))?;
            }
            Ok(ClientFrame::Generate { v, mut request }) => {
                if let Some(p) = default_policy {
                    request.policy.get_or_insert(p);
                }
                let id = request.id;
                match router.call(request) {
                    Ok(resp) if resp.rejected => {
                        write_frame(&mut writer, &reject_frame(v, &resp))?;
                    }
                    Ok(resp) => write_frame(&mut writer, &response_frame(v, &resp))?,
                    Err(e) => {
                        // router gone: v0 keeps the bare no-id error
                        // shape, v1 attributes the failure to the id
                        let id = (v > 0).then_some(id);
                        write_frame(&mut writer, &error_frame(v, id, &format!("{e:#}")))?;
                    }
                }
            }
            Ok(ClientFrame::Subscribe { mut request }) => {
                if let Some(p) = default_policy {
                    request.policy.get_or_insert(p);
                }
                // v1-only: relay the row's commit stream as it arrives,
                // then the terminal frame; the connection then goes
                // back to line dispatch. A write failure means the
                // subscriber is gone: cancel the row on the router so
                // its engine slot is reclaimed, keep draining the
                // channel (writes suppressed) until it closes, then
                // surface the IO error to end the connection.
                let id = request.id;
                let rx = router.subscribe(request);
                let mut dead: Option<anyhow::Error> = None;
                loop {
                    match rx.recv() {
                        Ok(StreamFrame::Commit(ev)) => {
                            if dead.is_none() {
                                if let Err(e) = write_frame(&mut writer, &ev.to_json()) {
                                    router.cancel(id);
                                    dead = Some(e);
                                }
                            }
                        }
                        Ok(StreamFrame::Done(resp)) => {
                            if dead.is_none() {
                                let frame = if resp.rejected {
                                    reject_frame(1, &resp)
                                } else {
                                    response_frame(1, &resp)
                                };
                                write_frame(&mut writer, &frame)?;
                            }
                            break;
                        }
                        Err(_) => {
                            // channel closed with no terminal frame:
                            // the row was cancelled or the router died
                            if dead.is_none() {
                                write_frame(
                                    &mut writer,
                                    &error_frame(1, Some(id), "router shut down"),
                                )?;
                            }
                            break;
                        }
                    }
                }
                if let Some(e) = dead {
                    return Err(e);
                }
            }
            Err(we) => {
                write_frame(&mut writer, &error_frame(we.v, we.id, &we.msg))?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read(bytes: &[u8]) -> LineRead {
        read_line_capped(&mut Cursor::new(bytes.to_vec())).unwrap()
    }

    #[test]
    fn capped_reader_mirrors_lines_semantics() {
        assert!(matches!(read(b""), LineRead::Eof));
        match read(b"hello\nworld\n") {
            LineRead::Line(s) => assert_eq!(s, "hello"),
            _ => panic!("expected a line"),
        }
        // trailing \r is stripped, like BufRead::lines
        match read(b"hello\r\n") {
            LineRead::Line(s) => assert_eq!(s, "hello"),
            _ => panic!("expected a line"),
        }
        // a final unterminated line is still dispatched
        match read(b"partial") {
            LineRead::Line(s) => assert_eq!(s, "partial"),
            _ => panic!("expected a line"),
        }
    }

    #[test]
    fn capped_reader_flags_bad_utf8_and_oversize() {
        assert!(matches!(read(&[0xff, 0xfe, b'\n']), LineRead::BadUtf8));
        let huge = vec![b'x'; MAX_LINE_BYTES + 5];
        let mut input = huge.clone();
        input.push(b'\n');
        input.extend_from_slice(b"next\n");
        let mut cur = Cursor::new(input);
        match read_line_capped(&mut cur).unwrap() {
            LineRead::TooLong(n) => assert_eq!(n, MAX_LINE_BYTES + 5),
            _ => panic!("expected TooLong"),
        }
        // the reader resynchronizes on the next line
        match read_line_capped(&mut cur).unwrap() {
            LineRead::Line(s) => assert_eq!(s, "next"),
            _ => panic!("expected the next line"),
        }
    }
}
