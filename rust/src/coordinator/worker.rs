//! Per-engine worker threads: each worker builds and owns its own
//! [`Backend`] instance (hence `Backend: Send`, not `Sync`) and runs
//! [`BatchEngine::step_block`] loops for one policy group — a
//! [`GroupKey`] of (method, decode policy) — at a time. The router
//! never touches a decode loop — it feeds workers admissions over a
//! command channel and hears back through [`WorkerEvent`]s merged into
//! its own message inbox (a clone of the router's sender, so
//! per-worker event order is the channel's FIFO order).
//!
//! Mid-flight joins land between block rounds: the worker drains its
//! command channel without blocking after every round. A same-group
//! admission with no free slot bounces back as [`WorkerEvent::Overflow`]
//! (the router re-queues it — capacity is only known to the router
//! after [`WorkerEvent::Ready`], so over-admission must be recoverable,
//! never fatal). A cross-group admission parks in a local pending
//! queue — group multiplexing under the router's `max_engines` cap —
//! and starts its own engine once the current one retires.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{
    clamp_batch, prefix_scope_for, Backend, BatchEngine, GenConfig, GenReport, PrefixHandle,
    RowCommit, SharedPrefixCache,
};

use super::request::{GroupKey, Request};
use super::router::Msg;

/// Placeholder gen length for the per-group engine config. Rows carry
/// their own `gen_len` at admission — this only has to satisfy
/// `GenConfig::validate` (positive, block-aligned).
pub const ENGINE_CFG_GEN_LEN: usize = 64;

/// An admission handed to a worker: the request plus whether the row
/// has a streaming subscriber (traced rows pay the per-round canvas
/// diff that produces commit events).
#[derive(Debug)]
pub struct AdmitReq {
    pub request: Request,
    pub traced: bool,
}

/// Commands a worker accepts on its channel.
pub enum WorkerCmd {
    Admit(AdmitReq),
    /// SLA eviction: drop the row at the next block boundary and report
    /// it as a parked [`RowDone`]. A stale id (row already finished) is
    /// a benign no-op.
    Evict { id: u64 },
    Shutdown,
}

/// A row that left a worker's engine, already detokenized on the worker
/// thread (the router must stay decode-free).
#[derive(Debug)]
pub struct RowDone {
    pub id: u64,
    pub text: String,
    pub non_eos_tokens: usize,
    /// true when the row was SLA-evicted rather than finished
    pub parked: bool,
}

/// Everything a worker reports back to the router.
pub enum WorkerEvent {
    /// Backend built; `capacity` is the engine slot count after bucket
    /// clamping. Until this arrives the router schedules on its
    /// configured `max_batch` guess and relies on `Overflow` bounces.
    Ready { worker: usize, capacity: usize },
    /// Backend construction failed — the worker thread is gone.
    Died { worker: usize, error: String },
    Admitted { worker: usize, id: u64 },
    AdmitFailed { worker: usize, id: u64, error: String },
    /// Same-group admission with no free slot: bounced back for
    /// re-queueing (original arrival preserved by the router).
    Overflow { worker: usize, req: Request },
    /// One block round (or an eviction, with `busy_secs` 0): commit
    /// events for traced rows, retired rows, and the decode wall-clock
    /// spent — the per-engine busy time the overlap bench sums.
    Round {
        worker: usize,
        key: GroupKey,
        commits: Vec<RowCommit>,
        done: Vec<RowDone>,
        busy_secs: f64,
    },
    /// The engine poisoned mid-round; `ids` are the rows lost with it.
    EngineFailed { worker: usize, ids: Vec<u64>, error: String },
    /// The engine drained and its totals folded into the report.
    Retired {
        worker: usize,
        key: GroupKey,
        report: GenReport,
        rounds: u64,
        mixed_rounds: u64,
    },
}

/// Spawn worker thread `worker`: build a backend from `factory`, report
/// `Ready`/`Died`, then serve admissions until `Shutdown`. Events flow
/// into `events` (the router's own inbox sender).
pub fn spawn_worker<B, F>(
    worker: usize,
    factory: Arc<F>,
    max_batch: usize,
    decode_threads: usize,
    prefix_cache: Option<SharedPrefixCache>,
    events: Sender<Msg>,
) -> (Sender<WorkerCmd>, JoinHandle<()>)
where
    B: Backend + 'static,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    let (tx, rx) = channel::<WorkerCmd>();
    let join = std::thread::Builder::new()
        .name(format!("sdllm-worker-{worker}"))
        .spawn(move || {
            worker_loop(worker, factory, max_batch, decode_threads, prefix_cache, rx, events)
        })
        .expect("spawn worker thread");
    (tx, join)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<B, F>(
    worker: usize,
    factory: Arc<F>,
    max_batch: usize,
    decode_threads: usize,
    prefix_cache: Option<SharedPrefixCache>,
    rx: Receiver<WorkerCmd>,
    events: Sender<Msg>,
) where
    B: Backend + 'static,
    F: Fn() -> Result<B> + Send + Sync + 'static,
{
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            let _ = events
                .send(Msg::Worker(WorkerEvent::Died { worker, error: format!("{e:#}") }));
            return;
        }
    };
    let capacity = clamp_batch(&backend, max_batch);
    if events.send(Msg::Worker(WorkerEvent::Ready { worker, capacity })).is_err() {
        return;
    }
    // Cross-group admissions parked while another group's engine ran.
    let mut pending: VecDeque<AdmitReq> = VecDeque::new();
    loop {
        let first = if let Some(a) = pending.pop_front() {
            a
        } else {
            match rx.recv() {
                Ok(WorkerCmd::Admit(a)) => a,
                // the row already left an engine — stale eviction
                Ok(WorkerCmd::Evict { .. }) => continue,
                Ok(WorkerCmd::Shutdown) | Err(_) => return,
            }
        };
        if run_engine(
            worker,
            &backend,
            capacity,
            decode_threads,
            first,
            &prefix_cache,
            &mut pending,
            &rx,
            &events,
        ) {
            return;
        }
    }
}

/// Try to admit one request; emits `Admitted` or `AdmitFailed`. The
/// misfit checks mirror the engine's admission contract so an oversized
/// or misaligned request fails alone without poisoning batchmates.
fn admit_one<B: Backend>(
    worker: usize,
    engine: &mut BatchEngine<'_, B>,
    a: AdmitReq,
    events: &Sender<Msg>,
) {
    let req = a.request;
    let ev = if !engine.valid_gen_len(req.gen_len) {
        let k = engine.config().block_size;
        WorkerEvent::AdmitFailed {
            worker,
            id: req.id,
            error: format!("gen_len {} is not a positive multiple of block size {k}", req.gen_len),
        }
    } else if !engine.fits(req.prompt.len(), req.gen_len) {
        WorkerEvent::AdmitFailed {
            worker,
            id: req.id,
            error: "prompt exceeds backend buckets".to_string(),
        }
    } else if engine.admit_traced(req.id, &req.prompt, req.gen_len, a.traced) {
        WorkerEvent::Admitted { worker, id: req.id }
    } else {
        WorkerEvent::AdmitFailed { worker, id: req.id, error: "engine slots exhausted".to_string() }
    };
    let _ = events.send(Msg::Worker(ev));
}

/// Drive one engine to retirement, starting from admission `first`.
/// Returns true when shutdown was requested (or the router vanished).
#[allow(clippy::too_many_arguments)]
fn run_engine<B: Backend>(
    worker: usize,
    backend: &B,
    capacity: usize,
    decode_threads: usize,
    first: AdmitReq,
    prefix_cache: &Option<SharedPrefixCache>,
    pending: &mut VecDeque<AdmitReq>,
    rx: &Receiver<WorkerCmd>,
    events: &Sender<Msg>,
) -> bool {
    let key = first.request.group_key();
    // The engine config is the method preset with the group's decode
    // policy swapped in — every row in this engine shares it, so one
    // served fleet can decode different policies concurrently.
    let mut cfg = GenConfig::preset(key.method, ENGINE_CFG_GEN_LEN);
    cfg.policy = key.policy;
    cfg.decode_threads = decode_threads.max(1);
    let mut engine = match BatchEngine::new(backend, cfg, capacity) {
        Ok(e) => e,
        Err(e) => {
            let _ = events.send(Msg::Worker(WorkerEvent::AdmitFailed {
                worker,
                id: first.request.id,
                error: format!("{e:#}"),
            }));
            return false;
        }
    };
    if let Some(cache) = prefix_cache {
        // scope = (method, policy, backend identity): engines of the
        // same group on different workers share captures; everything
        // else is isolated
        let scope = prefix_scope_for(backend, engine.config());
        engine.set_prefix_cache(PrefixHandle { cache: cache.clone(), scope });
    }
    let mut shutdown = false;
    admit_one(worker, &mut engine, first, events);
    loop {
        // Same-group admissions parked from an earlier run claim free
        // slots first (they are older than anything in the channel).
        while engine.has_free_slot() {
            let Some(i) = pending.iter().position(|a| a.request.group_key() == key) else {
                break;
            };
            let a = pending.remove(i).expect("position is in bounds");
            admit_one(worker, &mut engine, a, events);
        }
        // Drain the command channel without blocking: joins and
        // evictions land between block rounds, decode keeps moving.
        loop {
            match rx.try_recv() {
                Ok(WorkerCmd::Admit(a)) => {
                    if a.request.group_key() != key {
                        pending.push_back(a);
                    } else if engine.has_free_slot() {
                        admit_one(worker, &mut engine, a, events);
                    } else {
                        let _ = events.send(Msg::Worker(WorkerEvent::Overflow {
                            worker,
                            req: a.request,
                        }));
                    }
                }
                Ok(WorkerCmd::Evict { id }) => {
                    if let Some(seq) = engine.evict(id) {
                        let done = RowDone {
                            id,
                            text: backend.detokenize(seq.generated()),
                            non_eos_tokens: seq.non_eos_tokens(),
                            parked: true,
                        };
                        let _ = events.send(Msg::Worker(WorkerEvent::Round {
                            worker,
                            key,
                            commits: engine.take_commits(),
                            done: vec![done],
                            busy_secs: 0.0,
                        }));
                    }
                }
                Ok(WorkerCmd::Shutdown) => shutdown = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if engine.active() == 0 {
            let _ = events.send(Msg::Worker(WorkerEvent::Retired {
                worker,
                key,
                report: engine.report().clone(),
                rounds: engine.rounds(),
                mixed_rounds: engine.mixed_rounds(),
            }));
            return shutdown;
        }
        let t0 = Instant::now();
        match engine.step_block() {
            Ok(finished) => {
                let busy_secs = t0.elapsed().as_secs_f64();
                let commits = engine.take_commits();
                let done: Vec<RowDone> = finished
                    .into_iter()
                    .map(|f| RowDone {
                        id: f.tag,
                        text: backend.detokenize(f.seq.generated()),
                        non_eos_tokens: f.seq.non_eos_tokens(),
                        parked: false,
                    })
                    .collect();
                let ev = WorkerEvent::Round { worker, key, commits, done, busy_secs };
                if events.send(Msg::Worker(ev)).is_err() {
                    return true;
                }
            }
            Err(e) => {
                // engine poisoned: report every row lost with it, then
                // retire so the totals (and the router's assignment)
                // still settle
                let ids = engine.live_tags();
                let _ = events.send(Msg::Worker(WorkerEvent::EngineFailed {
                    worker,
                    ids,
                    error: format!("{e:#}"),
                }));
                let _ = events.send(Msg::Worker(WorkerEvent::Retired {
                    worker,
                    key,
                    report: engine.report().clone(),
                    rounds: engine.rounds(),
                    mixed_rounds: engine.mixed_rounds(),
                }));
                return shutdown;
            }
        }
    }
}
