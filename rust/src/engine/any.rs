//! `AnyBackend`: runtime selection between the pure-Rust reference
//! model and the PJRT runtime (when compiled with `--features pjrt`).
//!
//! The generator/eval/coordinator layers are generic over
//! `engine::Backend`; binaries and benches that pick a backend from CLI
//! flags or the environment need a single concrete type — this enum is
//! that type, delegating every trait method to the active variant.

use anyhow::Result;

use super::backend::{Backend, CachedSpan, PrefixCapture};
use super::reference::{RefKv, RefMode, ReferenceBackend, REFERENCE_SEED};
use super::types::{DecodeOut, SpecialTokens};

#[cfg(feature = "pjrt")]
use crate::runtime::{ArtifactsIndex, KvCache, ModelRuntime, Runtime};

pub enum AnyBackend {
    Reference(ReferenceBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(ModelRuntime),
}

pub enum AnyKv {
    Reference(RefKv),
    #[cfg(feature = "pjrt")]
    Pjrt(KvCache),
}

impl AnyBackend {
    /// The deterministic reference model (toy mode) with the shared
    /// default seed.
    pub fn reference() -> AnyBackend {
        AnyBackend::Reference(ReferenceBackend::toy(REFERENCE_SEED))
    }

    /// The confidence-coupled causal reference model with the shared
    /// default seed.
    pub fn reference_causal() -> AnyBackend {
        AnyBackend::Reference(ReferenceBackend::causal(REFERENCE_SEED))
    }

    /// A reference backend in the given mode (scripted maps to toy —
    /// it is test-only and not selectable).
    pub fn reference_with(mode: RefMode) -> AnyBackend {
        match mode {
            RefMode::Causal => AnyBackend::reference_causal(),
            _ => AnyBackend::reference(),
        }
    }

    /// The reference-mode selection every auto-selecting entry point
    /// shares: `SDLLM_REF_MODE=toy|causal`, default toy. A set-but-
    /// unrecognized value panics loudly rather than silently running the
    /// toy model (which would upload a flat-100%-accuracy "frontier"
    /// from CI with no failure anywhere).
    pub fn env_ref_mode() -> RefMode {
        match std::env::var("SDLLM_REF_MODE") {
            Err(_) => RefMode::Toy,
            Ok(s) if s.trim().is_empty() => RefMode::Toy,
            Ok(s) => RefMode::parse(s.trim().to_lowercase().as_str()).unwrap_or_else(|| {
                panic!("unrecognized SDLLM_REF_MODE {s:?} (expected toy|causal)")
            }),
        }
    }

    /// Reference backend in the env-selected mode.
    pub fn reference_from_env() -> AnyBackend {
        AnyBackend::reference_with(AnyBackend::env_ref_mode())
    }

    /// The one shared selection predicate: can this build serve `root`
    /// over PJRT? True iff the `pjrt` feature is compiled in *and* AOT
    /// artifacts exist. Every auto-selecting entry point (CLI, server
    /// router, benches, examples) must route through this.
    pub fn pjrt_available(root: &std::path::Path) -> bool {
        cfg!(feature = "pjrt") && root.join("index.json").exists()
    }

    /// Load the PJRT backend for `model` from `root`.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(root: &std::path::Path, model: &str) -> Result<AnyBackend> {
        let index = ArtifactsIndex::load(root)?;
        let rt = Runtime::cpu()?;
        let mrt = ModelRuntime::load(&rt, &index.model_dir(model))?;
        Ok(AnyBackend::Pjrt(mrt))
    }

    /// Pick the best available backend for `model`: the PJRT runtime
    /// when [`AnyBackend::pjrt_available`] says so; the reference model
    /// (in the `SDLLM_REF_MODE` env-selected mode) otherwise.
    pub fn auto(root: &std::path::Path, model: &str) -> Result<AnyBackend> {
        AnyBackend::auto_with(root, model, AnyBackend::env_ref_mode())
    }

    /// [`AnyBackend::auto`] with an explicit reference-mode fallback —
    /// the single selection predicate the CLI threads `--ref-mode`
    /// through (so the availability rule can't drift between callers).
    pub fn auto_with(root: &std::path::Path, model: &str, mode: RefMode) -> Result<AnyBackend> {
        #[cfg(feature = "pjrt")]
        {
            if AnyBackend::pjrt_available(root) {
                return AnyBackend::pjrt(root, model);
            }
        }
        let _ = (root, model);
        Ok(AnyBackend::reference_with(mode))
    }

    /// Human-readable description for banners/logs.
    pub fn describe(&self) -> &'static str {
        match self {
            AnyBackend::Reference(b) => match b.mode {
                RefMode::Causal => "reference (causal confidence-coupled model)",
                RefMode::Scripted { .. } => "reference (scripted test model)",
                RefMode::Toy => "reference (deterministic pure-Rust toy model)",
            },
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(_) => "pjrt (AOT executables)",
        }
    }

    /// The reference variant, if active (benches use it to reach the
    /// oracle for synthetic suites).
    pub fn as_reference(&self) -> Option<&ReferenceBackend> {
        match self {
            AnyBackend::Reference(b) => Some(b),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(_) => None,
        }
    }
}

impl Backend for AnyBackend {
    type Kv = AnyKv;

    fn special(&self) -> SpecialTokens {
        match self {
            AnyBackend::Reference(b) => b.special(),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => m.special(),
        }
    }

    fn wants_p0(&self) -> bool {
        match self {
            AnyBackend::Reference(b) => b.wants_p0(),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::wants_p0(m),
        }
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        match self {
            AnyBackend::Reference(b) => b.pick_batch(need),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::pick_batch(m, need),
        }
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        match self {
            AnyBackend::Reference(b) => b.pick_prefix(need),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::pick_prefix(m, need),
        }
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        match self {
            AnyBackend::Reference(b) => b.pick_query(need),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::pick_query(m, need),
        }
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        match self {
            AnyBackend::Reference(b) => b.pick_seq(need),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::pick_seq(m, need),
        }
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<AnyKv> {
        match self {
            AnyBackend::Reference(b) => {
                Ok(AnyKv::Reference(b.prefill(batch, p_bucket, tokens, pos, valid, p0)?))
            }
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => {
                Ok(AnyKv::Pjrt(Backend::prefill(m, batch, p_bucket, tokens, pos, valid, p0)?))
            }
        }
    }

    fn decode(
        &self,
        kv: &AnyKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        match (self, kv) {
            (AnyBackend::Reference(b), AnyKv::Reference(kv)) => {
                b.decode(kv, q_bucket, q_tok, q_pos, q_valid)
            }
            #[cfg(feature = "pjrt")]
            (AnyBackend::Pjrt(m), AnyKv::Pjrt(kv)) => {
                Backend::decode(m, kv, q_bucket, q_tok, q_pos, q_valid)
            }
            #[cfg(feature = "pjrt")]
            _ => anyhow::bail!("KV cache comes from a different backend"),
        }
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        match self {
            AnyBackend::Reference(b) => b.logits(batch, s_bucket, tokens, pos, valid, p0),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::logits(m, batch, s_bucket, tokens, pos, valid, p0),
        }
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        match self {
            AnyBackend::Reference(b) => b.detokenize(ids),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::detokenize(m, ids),
        }
    }

    fn compile_secs(&self) -> f64 {
        match self {
            AnyBackend::Reference(b) => b.compile_secs(),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::compile_secs(m),
        }
    }

    fn prefill_cached(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
        cached: &[CachedSpan],
    ) -> Result<AnyKv> {
        match self {
            AnyBackend::Reference(b) => Ok(AnyKv::Reference(
                b.prefill_cached(batch, p_bucket, tokens, pos, valid, p0, cached)?,
            )),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Ok(AnyKv::Pjrt(Backend::prefill_cached(
                m, batch, p_bucket, tokens, pos, valid, p0, cached,
            )?)),
        }
    }

    fn capture_prefix(&self, kv: &AnyKv, row: usize, prefix_len: usize) -> Option<PrefixCapture> {
        match (self, kv) {
            (AnyBackend::Reference(b), AnyKv::Reference(kv)) => {
                b.capture_prefix(kv, row, prefix_len)
            }
            #[cfg(feature = "pjrt")]
            (AnyBackend::Pjrt(m), AnyKv::Pjrt(kv)) => Backend::capture_prefix(m, kv, row, prefix_len),
            #[cfg(feature = "pjrt")]
            _ => None,
        }
    }

    fn prefix_scope(&self) -> u64 {
        match self {
            AnyBackend::Reference(b) => b.prefix_scope(),
            #[cfg(feature = "pjrt")]
            AnyBackend::Pjrt(m) => Backend::prefix_scope(m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_with_mode_selects_backend() {
        let toy = AnyBackend::reference_with(RefMode::Toy);
        let causal = AnyBackend::reference_with(RefMode::Causal);
        assert_eq!(toy.describe(), "reference (deterministic pure-Rust toy model)");
        assert_eq!(causal.describe(), "reference (causal confidence-coupled model)");
        assert_eq!(toy.as_reference().unwrap().mode, RefMode::Toy);
        assert_eq!(causal.as_reference().unwrap().mode, RefMode::Causal);
    }
}
