//! Backend abstraction: what the block-diffusion generator needs from a
//! model runtime. The production impl is `runtime::ModelRuntime` (PJRT
//! executables); tests use `MockBackend` to drive the scheduler through
//! thousands of randomized decode trajectories without artifacts —
//! termination, commit-ordering and early-exit invariants are checked
//! there (see `tests` in `generator.rs`).

use anyhow::Result;

use crate::runtime::artifact::SpecialTokens;
use crate::runtime::model::{DecodeOut, KvCache};
use crate::runtime::ModelRuntime;

pub trait Backend {
    type Kv;

    fn special(&self) -> SpecialTokens;
    fn wants_p0(&self) -> bool;
    fn pick_batch(&self, need: usize) -> Option<usize>;
    fn pick_prefix(&self, need: usize) -> Option<usize>;
    fn pick_query(&self, need: usize) -> Option<usize>;
    fn pick_seq(&self, need: usize) -> Option<usize>;

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<Self::Kv>;

    fn decode(
        &self,
        kv: &Self::Kv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut>;

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut>;
}

impl Backend for ModelRuntime {
    type Kv = KvCache;

    fn special(&self) -> SpecialTokens {
        self.manifest.special.clone()
    }

    fn wants_p0(&self) -> bool {
        self.manifest.wants_p0
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.manifest.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.manifest.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.manifest.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.manifest.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<KvCache> {
        ModelRuntime::prefill(self, batch, p_bucket, tokens, pos, valid, p0)
    }

    fn decode(
        &self,
        kv: &KvCache,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        ModelRuntime::decode(self, kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        ModelRuntime::logits(self, batch, s_bucket, tokens, pos, valid, p0)
    }
}

/// Deterministic fake backend for scheduler tests: produces confidences
/// from a seeded RNG and tokens from a configurable script ("emit EOS
/// after `answer_len` content tokens"), so tests can assert early-exit
/// and termination behavior precisely.
pub struct MockBackend {
    pub special: SpecialTokens,
    pub batch_buckets: Vec<usize>,
    pub prefix_buckets: Vec<usize>,
    pub query_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    /// content token emitted before EOS
    pub content_token: i32,
    /// per-sequence answer length: positions `< p0 + answer_len` get
    /// `content_token`, later ones EOS
    pub answer_len: usize,
    /// confidence schedule: base + step_bonus·(queries seen)
    pub base_conf: f32,
    pub conf_seed: u64,
    pub calls: std::cell::RefCell<MockStats>,
}

#[derive(Debug, Default, Clone)]
pub struct MockStats {
    pub prefills: u64,
    pub decodes: u64,
    pub logits: u64,
}

/// Mock KV: remembers what prefill saw (enough for assertions).
pub struct MockKv {
    pub batch: usize,
    pub p_bucket: usize,
    pub valid: Vec<i32>,
}

impl MockBackend {
    pub fn new(answer_len: usize) -> MockBackend {
        MockBackend {
            special: SpecialTokens { pad: 0, mask: 1, bos: 2, eos: 3, sep: 4 },
            batch_buckets: vec![1, 4],
            prefix_buckets: vec![96, 160, 224, 352, 800],
            query_buckets: vec![13, 17, 25, 41, 73, 137, 264, 520],
            seq_buckets: vec![96, 160, 224, 352, 800],
            content_token: 10,
            answer_len,
            base_conf: 0.5,
            conf_seed: 7,
            calls: Default::default(),
        }
    }

    fn out_for(&self, q_pos: &[i32], q_valid: &[i32], batch: usize, bucket: usize) -> DecodeOut {
        let mut rng = crate::util::rng::Rng::new(
            self.conf_seed ^ (q_pos.iter().map(|&p| p as u64).sum::<u64>()),
        );
        let mut data = vec![0f32; batch * bucket * 2];
        for b in 0..batch {
            for i in 0..bucket {
                let idx = (b * bucket + i) * 2;
                let pos = q_pos[b * bucket + i] as usize;
                let valid = q_valid.get(b).copied().unwrap_or(bucket as i32) as usize;
                let tok = if i < valid {
                    // p0 is unknown to the mock; tests arrange prompts so
                    // that "absolute position >= answer boundary" is the
                    // EOS rule: boundary = prompt_len + answer_len, and
                    // prompt_len is encoded by tests via answer boundary
                    // in absolute coordinates (see tests).
                    if pos >= self.answer_len {
                        self.special.eos
                    } else {
                        self.content_token
                    }
                } else {
                    self.special.pad
                };
                data[idx] = tok as f32;
                data[idx + 1] = (self.base_conf + rng.f32() * 0.5).min(1.0);
            }
        }
        DecodeOut { data, batch, q: bucket }
    }
}

impl Backend for MockBackend {
    type Kv = MockKv;

    fn special(&self) -> SpecialTokens {
        self.special.clone()
    }

    fn wants_p0(&self) -> bool {
        false
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        crate::runtime::Manifest::pick_bucket(&self.batch_buckets, need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        crate::runtime::Manifest::pick_bucket(&self.prefix_buckets, need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        crate::runtime::Manifest::pick_bucket(&self.query_buckets, need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        crate::runtime::Manifest::pick_bucket(&self.seq_buckets, need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        _tokens: &[i32],
        _pos: &[i32],
        valid: &[i32],
        _p0: Option<&[i32]>,
    ) -> Result<MockKv> {
        self.calls.borrow_mut().prefills += 1;
        Ok(MockKv { batch, p_bucket, valid: valid.to_vec() })
    }

    fn decode(
        &self,
        kv: &MockKv,
        q_bucket: usize,
        _q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        self.calls.borrow_mut().decodes += 1;
        Ok(self.out_for(q_pos, q_valid, kv.batch, q_bucket))
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        _tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        _p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        self.calls.borrow_mut().logits += 1;
        Ok(self.out_for(pos, valid, batch, s_bucket))
    }
}
