//! Backend abstraction: what the block-diffusion generator, eval
//! harness and coordinator need from a model runtime. Two impls ship:
//!
//! - `engine::ReferenceBackend` — deterministic pure-Rust toy model,
//!   always available; drives tests, CI benches and artifact-free
//!   serving.
//! - `runtime::ModelRuntime` — the PJRT path executing AOT-compiled
//!   executables (behind the `pjrt` cargo feature).
//!
//! The trait is deliberately expressed over backend-neutral types
//! (`engine::types`): nothing here references PJRT, so the default
//! build carries no xla dependency.
//!
//! `Send` is a supertrait: the coordinator gives every `BatchEngine`
//! its own worker thread, and a backend must be movable onto (and
//! owned by) that thread. Backends need not be `Sync` — each worker
//! builds and owns its own instance.

use std::any::Any;
use std::sync::Arc;

use anyhow::Result;

use super::types::{DecodeOut, SpecialTokens};

/// Opaque, backend-owned prefill state for one prompt prefix, shareable
/// across requests through the prefix cache. Each backend downcasts to
/// its own capture type (`ReferenceBackend` stores a `RefPrefix`); the
/// cache layer never looks inside.
pub type PrefixCapture = Arc<dyn Any + Send + Sync>;

/// One row's cached-prefix annotation handed to `prefill_cached`:
/// how many leading prompt tokens a capture covers, and the capture
/// itself. `len == 0` / `None` means a cold row.
#[derive(Clone, Default)]
pub struct CachedSpan {
    /// leading prompt tokens the capture covers (0 = cold)
    pub len: usize,
    pub capture: Option<PrefixCapture>,
}

impl std::fmt::Debug for CachedSpan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CachedSpan")
            .field("len", &self.len)
            .field("capture", &self.capture.is_some())
            .finish()
    }
}

pub trait Backend: Send {
    /// Backend-owned KV cache produced by `prefill`, consumed by
    /// `decode` (device-resident for PJRT, plain struct for reference).
    type Kv;

    fn special(&self) -> SpecialTokens;

    /// Whether the model graph takes per-row prompt lengths (block-
    /// causal topologies).
    fn wants_p0(&self) -> bool;

    fn pick_batch(&self, need: usize) -> Option<usize>;
    fn pick_prefix(&self, need: usize) -> Option<usize>;
    fn pick_query(&self, need: usize) -> Option<usize>;
    fn pick_seq(&self, need: usize) -> Option<usize>;

    /// Prefix forward over `[batch, p_bucket]` pre-padded rows.
    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<Self::Kv>;

    /// One diffusion decode step over the query bundle.
    fn decode(
        &self,
        kv: &Self::Kv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut>;

    /// Full-sequence forward (the vanilla baseline).
    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut>;

    /// Decode generated ids to text (stop at EOS, skip specials) —
    /// the python `tokenizer.decode_until_eos` rule.
    fn detokenize(&self, ids: &[i32]) -> String;

    /// Cumulative seconds spent lazily compiling executables. The eval
    /// harness subtracts this one-time cost from timed walls so
    /// throughput/latency ratios stay undistorted; backends without
    /// compilation report 0.
    fn compile_secs(&self) -> f64 {
        0.0
    }

    /// `prefill`, but with per-row cached-prefix annotations from the
    /// cross-request prefix cache: `cached[b]` tells the backend how
    /// many leading prompt tokens of row `b` it may restore from the
    /// attached capture instead of recomputing. Must be **bit-identical**
    /// to a cold `prefill` of the same rows (the cache only shortens
    /// work, never changes results — pinned by the parity suite). The
    /// default ignores the annotations and runs a cold prefill, so
    /// backends without capture support stay correct.
    #[allow(clippy::too_many_arguments)]
    fn prefill_cached(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
        cached: &[CachedSpan],
    ) -> Result<Self::Kv> {
        let _ = cached;
        self.prefill(batch, p_bucket, tokens, pos, valid, p0)
    }

    /// Capture row `row`'s prefill state for the first `prefix_len`
    /// prompt tokens as a shareable, backend-opaque value the prefix
    /// cache can store. `None` (the default) means this backend/mode
    /// has nothing reusable to offer and the row is never inserted.
    fn capture_prefix(&self, kv: &Self::Kv, row: usize, prefix_len: usize) -> Option<PrefixCapture> {
        let _ = (kv, row, prefix_len);
        None
    }

    /// Cache-scope discriminant folded into every prefix-cache key:
    /// captures are only reusable between backends that report the same
    /// scope (same mode, same seed, …). The default 0 is fine for
    /// backends that never produce captures.
    fn prefix_scope(&self) -> u64 {
        0
    }
}
