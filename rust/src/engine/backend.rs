//! Backend abstraction: what the block-diffusion generator, eval
//! harness and coordinator need from a model runtime. Two impls ship:
//!
//! - `engine::ReferenceBackend` — deterministic pure-Rust toy model,
//!   always available; drives tests, CI benches and artifact-free
//!   serving.
//! - `runtime::ModelRuntime` — the PJRT path executing AOT-compiled
//!   executables (behind the `pjrt` cargo feature).
//!
//! The trait is deliberately expressed over backend-neutral types
//! (`engine::types`): nothing here references PJRT, so the default
//! build carries no xla dependency.
//!
//! `Send` is a supertrait: the coordinator gives every `BatchEngine`
//! its own worker thread, and a backend must be movable onto (and
//! owned by) that thread. Backends need not be `Sync` — each worker
//! builds and owns its own instance.

use anyhow::Result;

use super::types::{DecodeOut, SpecialTokens};

pub trait Backend: Send {
    /// Backend-owned KV cache produced by `prefill`, consumed by
    /// `decode` (device-resident for PJRT, plain struct for reference).
    type Kv;

    fn special(&self) -> SpecialTokens;

    /// Whether the model graph takes per-row prompt lengths (block-
    /// causal topologies).
    fn wants_p0(&self) -> bool;

    fn pick_batch(&self, need: usize) -> Option<usize>;
    fn pick_prefix(&self, need: usize) -> Option<usize>;
    fn pick_query(&self, need: usize) -> Option<usize>;
    fn pick_seq(&self, need: usize) -> Option<usize>;

    /// Prefix forward over `[batch, p_bucket]` pre-padded rows.
    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<Self::Kv>;

    /// One diffusion decode step over the query bundle.
    fn decode(
        &self,
        kv: &Self::Kv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut>;

    /// Full-sequence forward (the vanilla baseline).
    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut>;

    /// Decode generated ids to text (stop at EOS, skip specials) —
    /// the python `tokenizer.decode_until_eos` rule.
    fn detokenize(&self, ids: &[i32]) -> String;

    /// Cumulative seconds spent lazily compiling executables. The eval
    /// harness subtracts this one-time cost from timed walls so
    /// throughput/latency ratios stay undistorted; backends without
    /// compilation report 0.
    fn compile_secs(&self) -> f64 {
        0.0
    }
}
