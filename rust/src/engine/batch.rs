//! `BatchEngine`: slot-based continuous batching over the shared
//! zero-allocation decode core.
//!
//! Where [`super::Generator::generate`] runs one fixed batch to
//! completion, the engine exposes a *resumable* `step_block` API: each
//! call decodes exactly one block round for every live row (each at its
//! own block cursor) and returns the rows that finished. Between
//! rounds, the router admits compatible queued requests into freed
//! slots — a request that arrives while a batch is mid-flight starts
//! decoding at the next block boundary instead of waiting for the full
//! drain. That turns the serving stack from batch-at-a-time into
//! streaming admission at block granularity (the dLLM analogue of
//! vLLM-style continuous batching; decode is block-synchronous, so
//! blocks are the natural admission points).
//!
//! Rows live in a dense vec (finished rows are swap-removed when
//! harvested), so the batch bucket shrinks as rows retire; padding up
//! to the bucket is done with inert buffer rows, never decoded.

use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::config::{GenConfig, Method};
use super::generator::{GenReport, WorkspaceStats};
use super::prefix_cache::PrefixHandle;
use super::sequence::SeqState;
use super::workspace::{run_block_round, run_vanilla, RowsMut, StepWorkspace};

/// A sequence that completed inside the engine, tagged with the id it
/// was admitted under.
#[derive(Debug)]
pub struct Finished {
    pub tag: u64,
    pub seq: SeqState,
}

/// Out-of-order commit record for one traced row over one block round:
/// every generation-region position whose canvas token changed since
/// the previous event (confidence-ordered commits, early-exit EOS
/// fills, and — when remasking is on — retractions back to mask).
/// Applying events in `seq` order rebuilds the canvas exactly, which is
/// what the streaming wire protocol ships to subscribed clients.
#[derive(Debug, Clone)]
pub struct RowCommit {
    /// the id the row was admitted under
    pub tag: u64,
    /// per-row event number, gapless from 0 — subscribers assert no
    /// event was dropped or reordered
    pub seq: u64,
    /// the row's block cursor when the event was captured
    pub block: usize,
    /// (generation-region offset, new token, commit confidence);
    /// retractions carry the mask token with confidence 0
    pub writes: Vec<(usize, i32, f32)>,
}

/// Per-slot bookkeeping parallel to `rows`.
struct RowMeta {
    tag: u64,
    /// next commit-event number for this row
    events: u64,
    /// canvas snapshot (generation region) at the last emitted event;
    /// empty for untraced rows — tracing is per admission, so only
    /// subscribed rows pay the per-round diff
    shadow: Vec<i32>,
}

/// Largest concurrent batch the backend's bucket grid can carry, capped
/// at `want` — shared by `BatchEngine::new` and the router so the
/// batcher's flush size and the engine's slot count can't drift apart.
pub fn clamp_batch<B: Backend>(rt: &B, want: usize) -> usize {
    let mut cap = want.max(1);
    while cap > 1 && rt.pick_batch(cap).is_none() {
        cap -= 1;
    }
    cap
}

pub struct BatchEngine<'a, B: Backend> {
    rt: &'a B,
    cfg: GenConfig,
    capacity: usize,
    rows: Vec<SeqState>,
    meta: Vec<RowMeta>,
    commits: Vec<RowCommit>,
    ws: StepWorkspace,
    report: GenReport,
    rounds: u64,
    mixed_rounds: u64,
    /// cross-request prefix cache handle (None = caching off)
    prefix: Option<PrefixHandle>,
}

impl<'a, B: Backend> BatchEngine<'a, B> {
    /// An empty engine with room for `capacity` concurrent rows
    /// (clamped to the backend's largest batch bucket).
    pub fn new(rt: &'a B, cfg: GenConfig, capacity: usize) -> Result<BatchEngine<'a, B>> {
        if let Err(e) = cfg.validate() {
            bail!("invalid GenConfig: {e}");
        }
        let cap = clamp_batch(rt, capacity);
        if rt.pick_batch(cap).is_none() {
            bail!("backend exposes no batch bucket");
        }
        Ok(BatchEngine {
            rt,
            cfg,
            capacity: cap,
            rows: Vec::new(),
            meta: Vec::new(),
            commits: Vec::new(),
            ws: StepWorkspace::new(),
            report: GenReport::default(),
            rounds: 0,
            mixed_rounds: 0,
            prefix: None,
        })
    }

    pub fn config(&self) -> &GenConfig {
        &self.cfg
    }

    /// Attach a cross-request prefix-cache handle. Cached decode is
    /// bit-identical to cold decode (pinned by the parity tests), so
    /// this only changes where prefill time goes, never the output.
    pub fn set_prefix_cache(&mut self, handle: PrefixHandle) {
        self.prefix = Some(handle);
    }

    /// Live rows currently decoding.
    pub fn active(&self) -> usize {
        self.rows.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn has_free_slot(&self) -> bool {
        self.rows.len() < self.capacity
    }

    /// Cumulative engine totals (steps, prefills, skipped blocks,
    /// per-phase seconds) across every row served so far.
    pub fn report(&self) -> &GenReport {
        &self.report
    }

    /// Block rounds driven so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Rounds driven while the live rows spanned ≥ 2 distinct gen
    /// lengths — the mixed-length occupancy numerator the metrics
    /// snapshot reports against `rounds`.
    pub fn mixed_rounds(&self) -> u64 {
        self.mixed_rounds
    }

    pub fn workspace_stats(&self) -> WorkspaceStats {
        WorkspaceStats { grows: self.ws.grows, steps: self.ws.steps }
    }

    /// Whether a gen length can be admitted at all: positive and block
    /// aligned (the same invariant `GenConfig::validate` enforces for
    /// homogeneous batches, here per row).
    pub fn valid_gen_len(&self, gen_len: usize) -> bool {
        gen_len > 0 && gen_len % self.cfg.block_size == 0
    }

    /// Whether a (prompt, gen_len) pair can decode under the backend's
    /// bucket grids: the worst-case prefix (prompt + all of this row's
    /// decoded blocks) must fit a prefix bucket, the worst-case query
    /// bundle must fit a query bucket (the whole remaining suffix for
    /// non-pruned cached methods at block 0; block + window + trailing
    /// for suffix pruning), and the vanilla full-forward path needs the
    /// whole canvas inside a seq bucket. The router checks this before
    /// admitting so one oversized request is failed alone instead of
    /// poisoning every in-flight row of the batch. Rows carry their own
    /// `gen_len`, so the check is per request, not per engine config.
    pub fn fits(&self, prompt_len: usize, gen_len: usize) -> bool {
        let k = self.cfg.block_size;
        let n_blocks = gen_len.div_ceil(k).max(1);
        let worst_prefix = prompt_len + n_blocks.saturating_sub(1) * k;
        if self.rt.pick_prefix(worst_prefix.max(1)).is_none() {
            return false;
        }
        if self.cfg.method == Method::Vanilla {
            return self.rt.pick_seq(prompt_len + gen_len).is_some();
        }
        // worst-case bundle per the spatial policy: the entire
        // generation region for the full suffix, block + window +
        // trailing for the windowed variants (dropout adds its thinned
        // far-suffix survivors)
        let q_worst = self.cfg.policy.spatial.max_bundle_len(k, gen_len);
        self.rt.pick_query(q_worst.max(1)).is_some()
    }

    /// Claim a free slot for a new request with its own generation
    /// length. Returns false when the engine is full, the gen length is
    /// invalid, or the prompt cannot fit the backend's buckets (see
    /// [`BatchEngine::fits`]); the row otherwise joins at the next
    /// block round, starting from its own block 0 regardless of where
    /// the incumbent rows are, and retires when its *own* block budget
    /// runs out — rows of different lengths share the batch freely.
    pub fn admit(&mut self, tag: u64, prompt: &[i32], gen_len: usize) -> bool {
        self.admit_traced(tag, prompt, gen_len, false)
    }

    /// [`BatchEngine::admit`] with per-row commit tracing: when `traced`
    /// the engine diffs this row's canvas after every block round and
    /// buffers a [`RowCommit`] event per change (drained with
    /// [`BatchEngine::take_commits`]). Untraced rows pay nothing.
    pub fn admit_traced(&mut self, tag: u64, prompt: &[i32], gen_len: usize, traced: bool) -> bool {
        if self.rows.len() >= self.capacity
            || !self.valid_gen_len(gen_len)
            || !self.fits(prompt.len(), gen_len)
        {
            return false;
        }
        let special = self.rt.special();
        let mut s = SeqState::new(prompt, gen_len, &special);
        s.init_block_counts(self.cfg.block_size);
        self.rows.push(s);
        self.meta.push(RowMeta {
            tag,
            events: 0,
            shadow: if traced { vec![special.mask; gen_len] } else { Vec::new() },
        });
        true
    }

    /// Drain the commit events buffered since the last call (traced rows
    /// only), in emission order.
    pub fn take_commits(&mut self) -> Vec<RowCommit> {
        std::mem::take(&mut self.commits)
    }

    /// Tags of the rows still decoding, slot order.
    pub fn live_tags(&self) -> Vec<u64> {
        self.meta.iter().map(|m| m.tag).collect()
    }

    /// Forcibly remove a live row (SLA eviction), freeing its slot for
    /// the next admission. Returns the row's partial decode state, or
    /// `None` if the tag is not live (already retired — the race is
    /// benign, callers treat it as a no-op).
    pub fn evict(&mut self, tag: u64) -> Option<SeqState> {
        let i = self.meta.iter().position(|m| m.tag == tag)?;
        self.meta.swap_remove(i);
        Some(self.rows.swap_remove(i))
    }

    /// Diff every traced row's canvas against its shadow and buffer one
    /// commit event per changed row. Confidence comes from the row's
    /// commit bookkeeping; a retraction (token back to mask) reports 0.
    fn capture_commits(&mut self) {
        let mask = self.rt.special().mask;
        for (row, meta) in self.rows.iter().zip(self.meta.iter_mut()) {
            if meta.shadow.is_empty() {
                continue;
            }
            let gen = row.generated();
            let mut writes = Vec::new();
            for (off, (&now, shadow)) in gen.iter().zip(meta.shadow.iter_mut()).enumerate() {
                if now != *shadow {
                    let conf = if now == mask {
                        0.0
                    } else {
                        row.commit_conf.get(off).copied().unwrap_or(0.0)
                    };
                    writes.push((off, now, conf));
                    *shadow = now;
                }
            }
            if !writes.is_empty() {
                self.commits.push(RowCommit {
                    tag: meta.tag,
                    seq: meta.events,
                    block: row.block,
                    writes,
                });
                meta.events += 1;
            }
        }
    }

    /// Run one block round for every live row and harvest the rows that
    /// finished (by early exit or by running out of blocks). A no-op
    /// returning no rows when the engine is idle.
    ///
    /// The vanilla method has no prefix-cache block structure, but its
    /// decode is still sliced into block-sized step budgets per call
    /// (state lives in `SeqState`), so a vanilla engine interleaves
    /// with other engines on the router thread and accepts mid-flight
    /// joins between slices instead of monopolizing the thread for a
    /// full drain.
    pub fn step_block(&mut self) -> Result<Vec<Finished>> {
        let mut done = Vec::new();
        if self.rows.is_empty() {
            return Ok(done);
        }
        let t0 = Instant::now();
        let batch = self
            .rt
            .pick_batch(self.rows.len())
            .ok_or_else(|| anyhow::anyhow!("batch {} exceeds buckets", self.rows.len()))?;
        let first_len = self.rows[0].gen_len;
        if self.rows.iter().any(|s| s.gen_len != first_len) {
            self.mixed_rounds += 1;
        }
        {
            let slice = self.cfg.block_size as u64;
            let mut hook: Option<&mut dyn FnMut(super::generator::StepEvent)> = None;
            let mut rows = RowsMut { real: &mut self.rows, pad: &mut [] };
            match self.cfg.method {
                Method::Vanilla => run_vanilla(
                    self.rt,
                    &self.cfg,
                    &mut self.ws,
                    &mut rows,
                    batch,
                    &mut self.report,
                    &mut hook,
                    slice,
                )?,
                _ => run_block_round(
                    self.rt,
                    &self.cfg,
                    &mut self.ws,
                    &mut rows,
                    batch,
                    self.prefix.as_ref(),
                    &mut self.report,
                    &mut hook,
                )?,
            }
        }
        self.rounds += 1;
        self.capture_commits();

        let mut i = 0;
        while i < self.rows.len() {
            if self.rows[i].finished {
                let seq = self.rows.swap_remove(i);
                let tag = self.meta.swap_remove(i).tag;
                self.report.non_eos_tokens += seq.non_eos_tokens() as u64;
                done.push(Finished { tag, seq });
            } else {
                i += 1;
            }
        }
        self.report.wall_secs += t0.elapsed().as_secs_f64();
        self.report.finish_phases();
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    use super::*;
    use crate::engine::{Generator, ReferenceBackend, REFERENCE_SEED};

    fn prompt(i: i32) -> Vec<i32> {
        vec![2, 20 + i, 21, 22, 23, 47]
    }

    fn drain(engine: &mut BatchEngine<ReferenceBackend>) -> HashMap<u64, String> {
        let mut out = HashMap::new();
        let mut guard = 0;
        while engine.active() > 0 {
            guard += 1;
            assert!(guard < 1000, "engine failed to drain");
            for f in engine.step_block().unwrap() {
                out.insert(f.tag, engine_text(&f.seq));
            }
        }
        out
    }

    fn engine_text(seq: &SeqState) -> String {
        ReferenceBackend::toy(REFERENCE_SEED).detokenize(seq.generated())
    }

    #[test]
    fn empty_engine_steps_are_noops() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
        assert_eq!(engine.active(), 0);
        assert!(engine.step_block().unwrap().is_empty());
        assert_eq!(engine.rounds(), 0);
    }

    #[test]
    fn capacity_clamps_to_batch_buckets() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::Streaming, 64);
        // reference buckets top out at 4
        let engine = BatchEngine::new(&be, cfg, 64).unwrap();
        assert_eq!(engine.capacity(), 4);
        assert!(engine.has_free_slot());
    }

    #[test]
    fn fits_rejects_prompts_beyond_prefix_buckets() {
        // reference prefix buckets top out at 1056; gen 64 / block 8
        // leaves 56 worst-case decoded-prefix tokens on top of the
        // prompt, so 1000 fits exactly and 1001 does not
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
        assert!(engine.fits(1000, 64));
        assert!(!engine.fits(1001, 64));
        let long = vec![2i32; 1001];
        assert!(!engine.admit(9, &long, 64), "oversized prompt must be rejected at admit");
        assert_eq!(engine.active(), 0);
    }

    #[test]
    fn fits_rejects_gen_lens_beyond_query_buckets() {
        // reference query buckets top out at 520. A non-pruned cached
        // method queries the whole generation region at block 0, so
        // gen 528 must be rejected at admission instead of poisoning
        // the engine when pick_query fails mid-decode; suffix pruning
        // bounds the bundle to block + window + 1 and still fits.
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let pc = GenConfig::preset(Method::PrefixCache, 64);
        let mut engine = BatchEngine::new(&be, pc, 4).unwrap();
        assert!(engine.fits(4, 520));
        assert!(!engine.fits(4, 528), "whole-suffix query beyond buckets must be rejected");
        assert!(!engine.admit(1, &prompt(0), 528));
        assert_eq!(engine.active(), 0);

        let streaming = GenConfig::preset(Method::Streaming, 64);
        let engine = BatchEngine::new(&be, streaming, 4).unwrap();
        assert!(engine.fits(4, 528), "pruned bundle (block + window + 1) fits fine");
    }

    #[test]
    fn admit_rejects_when_full() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut engine = BatchEngine::new(&be, cfg, 2).unwrap();
        assert!(engine.admit(1, &prompt(0), 64));
        assert!(engine.admit(2, &prompt(1), 64));
        assert!(!engine.admit(3, &prompt(2), 64));
        assert_eq!(engine.active(), 2);
    }

    #[test]
    fn engine_matches_generator_for_a_static_batch() {
        // toy mode is schedule-independent: slot decoding must converge
        // to the same text as the batch generator
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut engine = BatchEngine::new(&be, cfg.clone(), 4).unwrap();
        for i in 0..3 {
            assert!(engine.admit(i as u64, &prompt(i), 64));
        }
        let texts = drain(&mut engine);
        assert!(engine.report().steps > 0);

        let be2 = ReferenceBackend::toy(REFERENCE_SEED);
        let mut generator = Generator::new(&be2, cfg).unwrap();
        for i in 0..3 {
            let mut seqs = vec![SeqState::new(&prompt(i), 64, &be2.special)];
            generator.generate(&mut seqs, None).unwrap();
            assert_eq!(texts[&(i as u64)], be2.detokenize(seqs[0].generated()), "row {i}");
        }
    }

    #[test]
    fn mixed_gen_lens_retire_per_row() {
        // rows with different gen lengths share the batch; the short
        // rows retire when their own block budget runs out while the
        // long row keeps decoding — PrefixCache commits exactly one
        // token per step with no early exit, so round counts are exact
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::PrefixCache, 64);
        let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
        assert!(engine.admit(0, &prompt(0), 64));
        assert!(engine.admit(1, &prompt(1), 16));
        assert!(engine.admit(2, &prompt(2), 32));
        assert!(!engine.admit(3, &prompt(3), 12), "misaligned gen_len must be rejected");
        assert!(!engine.admit(3, &prompt(3), 0), "zero gen_len must be rejected");

        let mut finish_round = HashMap::new();
        let mut texts = HashMap::new();
        let mut round = 0u64;
        while engine.active() > 0 {
            round += 1;
            assert!(round < 100, "engine failed to drain");
            for f in engine.step_block().unwrap() {
                assert_eq!(
                    f.seq.generated().len(),
                    match f.tag {
                        0 => 64,
                        1 => 16,
                        _ => 32,
                    },
                    "row decoded to its own gen_len"
                );
                finish_round.insert(f.tag, round);
                texts.insert(f.tag, engine_text(&f.seq));
            }
        }
        // 8-token blocks: gen 16 → 2 rounds, 32 → 4, 64 → 8
        assert_eq!(finish_round[&1], 2);
        assert_eq!(finish_round[&2], 4);
        assert_eq!(finish_round[&0], 8);
        // rounds 1..4 ran with ≥2 distinct gen lengths live
        assert_eq!(engine.mixed_rounds(), 4);

        // each row's text equals its solo decode at its own length
        // (toy mode is schedule-independent)
        let be2 = ReferenceBackend::toy(REFERENCE_SEED);
        for (i, len) in [(0usize, 64usize), (1, 16), (2, 32)] {
            let cfg = GenConfig::preset(Method::PrefixCache, len);
            let mut generator = Generator::new(&be2, cfg).unwrap();
            let mut seqs = vec![SeqState::new(&prompt(i as i32), len, &be2.special)];
            generator.generate(&mut seqs, None).unwrap();
            assert_eq!(
                texts[&(i as u64)],
                be2.detokenize(seqs[0].generated()),
                "row {i} (gen {len}) diverged from its solo decode"
            );
        }
    }

    #[test]
    fn traced_commits_reassemble_canvas_with_gapless_seqs() {
        // replaying a traced row's commit events over a fresh all-mask
        // canvas must rebuild exactly the finished canvas, and the
        // per-row event numbers must count up from 0 with no gaps
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let mask = be.special().mask;
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
        assert!(engine.admit_traced(7, &prompt(0), 64, true));
        assert!(engine.admit(8, &prompt(1), 64), "untraced row shares the batch");

        let mut commits = Vec::new();
        let mut finals = HashMap::new();
        let mut guard = 0;
        while engine.active() > 0 {
            guard += 1;
            assert!(guard < 1000, "engine failed to drain");
            for f in engine.step_block().unwrap() {
                finals.insert(f.tag, f.seq.generated().to_vec());
            }
            commits.extend(engine.take_commits());
        }
        assert!(commits.iter().all(|c| c.tag == 7), "untraced row must emit no events");
        for (i, c) in commits.iter().enumerate() {
            assert_eq!(c.seq, i as u64, "event numbers must be gapless from 0");
            assert!(!c.writes.is_empty());
        }

        let mut canvas = vec![mask; 64];
        for c in &commits {
            for &(off, tok, _conf) in &c.writes {
                canvas[off] = tok;
            }
        }
        assert_eq!(canvas, finals[&7], "replayed commits must rebuild the canvas");
        assert!(canvas.iter().all(|&t| t != mask), "finished canvas has no masks left");
    }

    #[test]
    fn evict_frees_slot_and_returns_partial_state() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::PrefixCache, 64);
        let mut engine = BatchEngine::new(&be, cfg, 2).unwrap();
        assert!(engine.admit(1, &prompt(0), 64));
        assert!(engine.admit(2, &prompt(1), 64));
        engine.step_block().unwrap();
        assert_eq!(engine.live_tags().len(), 2);

        let seq = engine.evict(1).expect("live row must evict");
        assert!(!seq.finished, "evicted mid-decode");
        assert!(seq.steps > 0, "evicted row had made progress");
        assert_eq!(engine.active(), 1);
        assert_eq!(engine.live_tags(), vec![2]);
        assert!(engine.evict(1).is_none(), "double-evict is a no-op");
        assert!(engine.admit(3, &prompt(2), 64), "freed slot is reusable");

        // the survivor must still converge to its solo text
        let mut texts = drain(&mut engine);
        let be2 = ReferenceBackend::toy(REFERENCE_SEED);
        let mut generator = Generator::new(&be2, GenConfig::preset(Method::PrefixCache, 64)).unwrap();
        let mut seqs = vec![SeqState::new(&prompt(1), 64, &be2.special)];
        generator.generate(&mut seqs, None).unwrap();
        assert_eq!(texts.remove(&2).unwrap(), be2.detokenize(seqs[0].generated()));
    }

    #[test]
    fn mid_flight_join_preserves_row_output() {
        // rows join the running batch at block boundaries (each decoding
        // alone for at least one round first); every row's text must
        // still equal its solo decode. PrefixCache decodes one token per
        // step with no early exit, so rows reliably overlap mid-flight.
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let cfg = GenConfig::preset(Method::PrefixCache, 64);
        let mut engine = BatchEngine::new(&be, cfg.clone(), 4).unwrap();
        let mut texts = HashMap::new();
        assert!(engine.admit(0, &prompt(0), 64));
        for f in engine.step_block().unwrap() {
            texts.insert(f.tag, engine_text(&f.seq));
        }
        assert!(engine.admit(1, &prompt(1), 64));
        for f in engine.step_block().unwrap() {
            texts.insert(f.tag, engine_text(&f.seq));
        }
        assert!(engine.admit(2, &prompt(2), 64));
        assert_eq!(engine.active(), 3, "joined rows should overlap mid-flight");
        texts.extend(drain(&mut engine));
        assert_eq!(texts.len(), 3);

        let be2 = ReferenceBackend::toy(REFERENCE_SEED);
        let mut generator = Generator::new(&be2, cfg).unwrap();
        for i in 0..3 {
            let mut seqs = vec![SeqState::new(&prompt(i), 64, &be2.special)];
            generator.generate(&mut seqs, None).unwrap();
            assert_eq!(texts[&(i as u64)], be2.detokenize(seqs[0].generated()), "row {i}");
        }
    }
}
