//! Generation configuration: method presets (the paper's baselines and
//! Streaming-dLLM itself) plus every ablation toggle Tables 3–6 and
//! Figures 5/6 sweep.
//!
//! Since the decode-policy redesign the spatial/temporal knobs live in
//! one composable [`DecodePolicy`] (see `engine::policy`); `GenConfig`
//! carries it alongside the scheduling knobs that are not policy
//! (block size, dKV refresh, early exit, remasking). The legacy
//! booleans (`suffix_pruning`, `dynamic_threshold`, …) survive as
//! variant-preserving setters so ablation sweeps read the same.

use super::policy::{DecodePolicy, SpatialPolicy, TemporalPolicy, PRESET_ALPHA};

/// The five methods every main table compares (paper Tables 1/2/8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full forward over the whole sequence every step, no cache,
    /// one token committed per step (LLaDA default schedule).
    Vanilla,
    /// dKV-Cache emulation: prefix cache with *delayed* refresh — the
    /// prefix KV is recomputed every `dkv_refresh` steps inside a block,
    /// so it keeps part of the recompute cost (paper reports 1.0–1.9×).
    DkvCache,
    /// Fast-dLLM-style prefix cache: prefix KV computed once per block,
    /// queries = current block + full suffix; one token per step.
    PrefixCache,
    /// Fast-dLLM: prefix cache + static-threshold parallel decoding.
    FastDllm,
    /// Streaming-dLLM (ours): prefix cache + attenuation-guided suffix
    /// pruning + dynamic confidence-aware decoding + early exit.
    Streaming,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::DkvCache => "dkv-cache",
            Method::PrefixCache => "prefix-cache",
            Method::FastDllm => "fast-dllm",
            Method::Streaming => "streaming",
        }
    }

    pub fn all() -> [Method; 5] {
        [
            Method::Vanilla,
            Method::DkvCache,
            Method::PrefixCache,
            Method::FastDllm,
            Method::Streaming,
        ]
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::all().into_iter().find(|m| m.name() == s)
    }
}

/// Full generation configuration (paper Table 12 row, scaled ÷4 per
/// DESIGN.md).
#[derive(Debug, Clone)]
pub struct GenConfig {
    pub method: Method,
    /// target generation length L
    pub gen_len: usize,
    /// block size K (paper: 32; scaled: 8)
    pub block_size: usize,
    /// composable spatial × temporal decode policy (Eq. 7–10)
    pub policy: DecodePolicy,
    /// EOS early exit (Table 3 "Exit.")
    pub early_exit: bool,
    /// dKV-Cache refresh interval (steps between prefix recomputes)
    pub dkv_refresh: usize,
    /// ReMDM-style inference-time remasking (extension; Wang et al.
    /// 2025, cited in paper §2.2): a committed token whose confidence
    /// was below `remask_tau` may be re-masked once for revision in a
    /// later step — trades extra steps for output quality.
    pub remask: bool,
    pub remask_tau: f32,
    /// Host-side row parallelism within one decode step: per-row
    /// candidate gather / selection / commit fans out across this many
    /// scoped threads, merged back in row order so output is
    /// bit-identical to the single-threaded schedule. 1 = off.
    pub decode_threads: usize,
}

impl GenConfig {
    /// Paper-faithful preset per method. `gen_len` in *scaled* tokens
    /// (64 ↔ paper 256, 128 ↔ paper 512).
    pub fn preset(method: Method, gen_len: usize) -> GenConfig {
        GenConfig {
            method,
            gen_len,
            block_size: 8,
            policy: DecodePolicy::for_method(method),
            early_exit: matches!(method, Method::Streaming),
            dkv_refresh: 2,
            remask: false,
            remask_tau: 0.5,
            decode_threads: 1,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.gen_len.div_ceil(self.block_size)
    }

    /// Whether this method reuses a prefix KV cache (everything but
    /// vanilla does).
    pub fn uses_cache(&self) -> bool {
        !matches!(self.method, Method::Vanilla)
    }

    /// Whether decoding commits multiple tokens per step by confidence
    /// threshold (any temporal policy beyond one-per-step).
    pub fn parallel_decoding(&self) -> bool {
        self.policy.temporal.is_parallel()
    }

    /// The spatial window size, reading the full suffix as a window
    /// spanning the whole generation (display/sweep convenience).
    pub fn window(&self) -> usize {
        match self.policy.spatial {
            SpatialPolicy::FullSuffix => self.gen_len,
            SpatialPolicy::Window { window, .. }
            | SpatialPolicy::Attenuating { window, .. }
            | SpatialPolicy::Dropout { window, .. } => window,
        }
    }

    /// Base confidence threshold τ0 of the temporal policy (1.0 for
    /// one-per-step: only fully-determined predictions clear it).
    pub fn tau0(&self) -> f32 {
        match self.policy.temporal {
            TemporalPolicy::OnePerStep => 1.0,
            TemporalPolicy::FixedTau { tau } => tau,
            TemporalPolicy::DynamicTau { tau0, .. }
            | TemporalPolicy::Extrapolating { tau0, .. } => tau0,
        }
    }

    /// Adaptation strength α (0.0 when the temporal policy is static).
    pub fn alpha(&self) -> f32 {
        match self.policy.temporal {
            TemporalPolicy::DynamicTau { alpha, .. }
            | TemporalPolicy::Extrapolating { alpha, .. } => alpha,
            _ => 0.0,
        }
    }

    /// Set the spatial window, preserving the policy variant (no-op on
    /// the unpruned full suffix). Attenuating floors clamp to the new
    /// window so the config stays valid.
    pub fn set_window(&mut self, w: usize) {
        match &mut self.policy.spatial {
            SpatialPolicy::FullSuffix => {}
            SpatialPolicy::Window { window, .. } | SpatialPolicy::Dropout { window, .. } => {
                *window = w;
            }
            SpatialPolicy::Attenuating { window, min_window, .. } => {
                *window = w;
                *min_window = (*min_window).min(w);
            }
        }
    }

    /// Toggle the trailing position id (Table 6); no-op on full suffix.
    pub fn set_trailing(&mut self, on: bool) {
        match &mut self.policy.spatial {
            SpatialPolicy::FullSuffix => {}
            SpatialPolicy::Window { trailing, .. }
            | SpatialPolicy::Attenuating { trailing, .. }
            | SpatialPolicy::Dropout { trailing, .. } => *trailing = on,
        }
    }

    /// Set τ0, preserving the temporal variant (no-op on one-per-step,
    /// matching the legacy field's dead-knob behaviour there).
    pub fn set_tau0(&mut self, t: f32) {
        match &mut self.policy.temporal {
            TemporalPolicy::OnePerStep => {}
            TemporalPolicy::FixedTau { tau } => *tau = t,
            TemporalPolicy::DynamicTau { tau0, .. }
            | TemporalPolicy::Extrapolating { tau0, .. } => *tau0 = t,
        }
    }

    /// Set α, preserving the temporal variant (no-op when static).
    pub fn set_alpha(&mut self, a: f32) {
        match &mut self.policy.temporal {
            TemporalPolicy::DynamicTau { alpha, .. }
            | TemporalPolicy::Extrapolating { alpha, .. } => *alpha = a,
            _ => {}
        }
    }

    /// Table 3 "Suf.": toggle suffix pruning. Off replaces the spatial
    /// policy with the full suffix; on restores the preset window when
    /// coming from the full suffix (windowed variants are kept as-is).
    pub fn set_suffix_pruning(&mut self, on: bool) {
        if !on {
            self.policy.spatial = SpatialPolicy::FullSuffix;
        } else if self.policy.spatial == SpatialPolicy::FullSuffix {
            self.policy.spatial = SpatialPolicy::preset_window();
        }
    }

    /// Table 3 "Dyn.": toggle the dynamic threshold. Off freezes the
    /// current τ0 as a static threshold; on lifts a static τ into the
    /// Eq. 10 schedule with the preset α.
    pub fn set_dynamic_threshold(&mut self, on: bool) {
        match (on, self.policy.temporal) {
            (false, TemporalPolicy::DynamicTau { tau0, .. })
            | (false, TemporalPolicy::Extrapolating { tau0, .. }) => {
                self.policy.temporal = TemporalPolicy::FixedTau { tau: tau0 };
            }
            (true, TemporalPolicy::FixedTau { tau }) => {
                self.policy.temporal =
                    TemporalPolicy::DynamicTau { tau0: tau, alpha: PRESET_ALPHA };
            }
            _ => {}
        }
    }

    /// Sanity checks; returns an error message on invalid combos.
    pub fn validate(&self) -> Result<(), String> {
        if self.block_size == 0 {
            return Err("block_size must be > 0".into());
        }
        if self.gen_len == 0 {
            return Err("gen_len must be > 0".into());
        }
        if self.gen_len % self.block_size != 0 {
            return Err(format!(
                "gen_len {} not a multiple of block_size {}",
                self.gen_len, self.block_size
            ));
        }
        self.policy.validate()?;
        if self.dkv_refresh == 0 && self.method == Method::DkvCache {
            return Err("dkv_refresh must be > 0".into());
        }
        if self.remask && !(0.0..=1.0).contains(&self.remask_tau) {
            return Err(format!("remask_tau {} outside [0,1]", self.remask_tau));
        }
        if self.decode_threads == 0 {
            return Err("decode_threads must be >= 1".into());
        }
        Ok(())
    }
}

/// The per-(model, suite, gen-length) hyperparameters of paper Table 12,
/// scaled ÷4. Window values follow the paper's per-benchmark tuning.
pub fn table12_config(model: &str, suite: &str, gen_len: usize) -> GenConfig {
    let mut c = GenConfig::preset(Method::Streaming, gen_len);
    // paper windows (tokens, original scale) — divide by 4.
    let w_paper: usize = match (model, suite, gen_len) {
        ("dream-mini", "humaneval-mini", 64) => 192,
        ("dream-mini", "humaneval-mini", _) => 128,
        ("dream-mini", "mbpp-mini", _) => 192,
        ("dream-mini", _, _) => 32,
        ("llada-mini", "humaneval-mini", 64) => 192,
        ("llada-mini", "humaneval-mini", _) => 256,
        ("llada-mini", "gsm-mini", _) => 96,
        ("llada-mini", "mbpp-mini", _) => 32,
        ("llada-mini", "math-mini", 64) => 128,
        ("llada-mini", "math-mini", _) => 256,
        ("llada15-mini", "gsm-mini", 128) => 128,
        ("llada15-mini", "math-mini", 128) => 192,
        _ => 96,
    };
    let a_paper = match (model, suite, gen_len) {
        ("dream-mini", "humaneval-mini", 64) => 0.7,
        ("dream-mini", "humaneval-mini", _) => 0.4,
        ("dream-mini", "mbpp-mini", 128) => 0.6,
        ("dream-mini", "math-mini", 64) => 0.1,
        ("llada-mini", "humaneval-mini", 128) => 0.4,
        ("llada-mini", "math-mini", 128) => 0.2,
        ("llada15-mini", "humaneval-mini", 128) => 0.4,
        ("llada15-mini", "gsm-mini", 64) => 0.4,
        ("llada15-mini", "gsm-mini", 128) => 0.6,
        ("llada15-mini", "math-mini", 64) => 0.4,
        _ => 0.3,
    };
    let w = (w_paper / 4).max(c.block_size);
    // windows can't exceed the suffix itself
    c.set_window(w.min(gen_len.saturating_sub(c.block_size)));
    c.set_alpha(a_paper);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for m in Method::all() {
            for len in [64, 128, 256, 512] {
                GenConfig::preset(m, len).validate().unwrap();
            }
        }
    }

    #[test]
    fn streaming_enables_all_modules() {
        let c = GenConfig::preset(Method::Streaming, 64);
        assert!(c.policy.spatial.is_pruning() && c.parallel_decoding() && c.early_exit);
        assert_eq!(c.policy, DecodePolicy::parse("streaming").unwrap());
        let f = GenConfig::preset(Method::FastDllm, 64);
        assert!(!f.policy.spatial.is_pruning() && !f.early_exit);
        assert_eq!(f.policy.temporal, TemporalPolicy::FixedTau { tau: 0.9 });
    }

    #[test]
    fn method_presets_resolve_to_policies() {
        for m in Method::all() {
            let c = GenConfig::preset(m, 64);
            assert_eq!(c.policy, DecodePolicy::for_method(m), "{}", m.name());
            assert_eq!(c.policy, DecodePolicy::parse(m.name()).unwrap(), "{}", m.name());
        }
    }

    #[test]
    fn setters_preserve_policy_variants() {
        let mut s = GenConfig::preset(Method::Streaming, 64);
        s.set_tau0(0.7);
        s.set_alpha(0.5);
        s.set_window(16);
        assert_eq!(s.policy.temporal, TemporalPolicy::DynamicTau { tau0: 0.7, alpha: 0.5 });
        assert_eq!(s.window(), 16);
        s.set_dynamic_threshold(false);
        assert_eq!(s.policy.temporal, TemporalPolicy::FixedTau { tau: 0.7 });
        s.set_dynamic_threshold(true);
        assert_eq!(s.policy.temporal, TemporalPolicy::DynamicTau { tau0: 0.7, alpha: 0.3 });
        s.set_suffix_pruning(false);
        assert_eq!(s.policy.spatial, SpatialPolicy::FullSuffix);
        s.set_suffix_pruning(true);
        assert_eq!(s.policy.spatial, SpatialPolicy::preset_window());

        // legacy dead-knob behaviour: τ0 is a no-op on one-per-step
        let mut v = GenConfig::preset(Method::PrefixCache, 64);
        v.set_tau0(0.5);
        assert_eq!(v.policy.temporal, TemporalPolicy::OnePerStep);
        assert_eq!(v.tau0(), 1.0);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = GenConfig::preset(Method::Streaming, 64);
        c.gen_len = 63;
        assert!(c.validate().is_err());
        let mut c2 = GenConfig::preset(Method::Streaming, 64);
        c2.set_tau0(1.5);
        assert!(c2.validate().is_err());
        let mut c3 = GenConfig::preset(Method::Streaming, 64);
        c3.decode_threads = 0;
        assert!(c3.validate().is_err());
        c3.decode_threads = 4;
        c3.validate().unwrap();
    }

    #[test]
    fn table12_window_bounded_by_suffix() {
        let c = table12_config("llada15-mini", "gsm-mini", 64);
        assert!(c.window() <= 64 - c.block_size);
        c.validate().unwrap();
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }
}
