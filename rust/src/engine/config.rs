//! Generation configuration: method presets (the paper's baselines and
//! Streaming-dLLM itself) plus every ablation toggle Tables 3–6 and
//! Figures 5/6 sweep.

/// The five methods every main table compares (paper Tables 1/2/8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full forward over the whole sequence every step, no cache,
    /// one token committed per step (LLaDA default schedule).
    Vanilla,
    /// dKV-Cache emulation: prefix cache with *delayed* refresh — the
    /// prefix KV is recomputed every `dkv_refresh` steps inside a block,
    /// so it keeps part of the recompute cost (paper reports 1.0–1.9×).
    DkvCache,
    /// Fast-dLLM-style prefix cache: prefix KV computed once per block,
    /// queries = current block + full suffix; one token per step.
    PrefixCache,
    /// Fast-dLLM: prefix cache + static-threshold parallel decoding.
    FastDllm,
    /// Streaming-dLLM (ours): prefix cache + attenuation-guided suffix
    /// pruning + dynamic confidence-aware decoding + early exit.
    Streaming,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::DkvCache => "dkv-cache",
            Method::PrefixCache => "prefix-cache",
            Method::FastDllm => "fast-dllm",
            Method::Streaming => "streaming",
        }
    }

    pub fn all() -> [Method; 5] {
        [
            Method::Vanilla,
            Method::DkvCache,
            Method::PrefixCache,
            Method::FastDllm,
            Method::Streaming,
        ]
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::all().into_iter().find(|m| m.name() == s)
    }
}

/// Full generation configuration (paper Table 12 row, scaled ÷4 per
/// DESIGN.md).
#[derive(Debug, Clone)]
pub struct GenConfig {
    pub method: Method,
    /// target generation length L
    pub gen_len: usize,
    /// block size K (paper: 32; scaled: 8)
    pub block_size: usize,
    /// sliding-window size w in tokens (suffix pruning)
    pub window: usize,
    /// base confidence threshold τ0 (Eq. 10)
    pub tau0: f32,
    /// adaptation strength α (Eq. 10)
    pub alpha: f32,
    /// keep the trailing position id in the pruned suffix (Table 6)
    pub trailing_position: bool,
    /// EOS early exit (Table 3 "Exit.")
    pub early_exit: bool,
    /// Table 3 "Suf.": suffix pruning on/off within Streaming
    pub suffix_pruning: bool,
    /// Table 3 "Dyn.": dynamic threshold on/off within Streaming
    pub dynamic_threshold: bool,
    /// dKV-Cache refresh interval (steps between prefix recomputes)
    pub dkv_refresh: usize,
    /// ReMDM-style inference-time remasking (extension; Wang et al.
    /// 2025, cited in paper §2.2): a committed token whose confidence
    /// was below `remask_tau` may be re-masked once for revision in a
    /// later step — trades extra steps for output quality.
    pub remask: bool,
    pub remask_tau: f32,
}

impl GenConfig {
    /// Paper-faithful preset per method. `gen_len` in *scaled* tokens
    /// (64 ↔ paper 256, 128 ↔ paper 512).
    pub fn preset(method: Method, gen_len: usize) -> GenConfig {
        let base = GenConfig {
            method,
            gen_len,
            block_size: 8,
            window: 24, // paper w=96 scaled ÷4
            tau0: 0.9,
            alpha: 0.3,
            trailing_position: true,
            early_exit: false,
            suffix_pruning: false,
            dynamic_threshold: false,
            dkv_refresh: 2,
            remask: false,
            remask_tau: 0.5,
        };
        match method {
            Method::Vanilla | Method::DkvCache | Method::PrefixCache | Method::FastDllm => base,
            Method::Streaming => GenConfig {
                early_exit: true,
                suffix_pruning: true,
                dynamic_threshold: true,
                ..base
            },
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.gen_len.div_ceil(self.block_size)
    }

    /// Whether this method reuses a prefix KV cache (everything but
    /// vanilla does).
    pub fn uses_cache(&self) -> bool {
        !matches!(self.method, Method::Vanilla)
    }

    /// Whether decoding commits multiple tokens per step by confidence
    /// threshold (Fast-dLLM and Streaming).
    pub fn parallel_decoding(&self) -> bool {
        matches!(self.method, Method::FastDllm | Method::Streaming)
    }

    /// Effective threshold at a step (Eq. 10):
    /// τ(t) = τ0 · (1 − α · (1 − r_mask)).
    pub fn threshold(&self, r_mask: f32) -> f32 {
        if self.method == Method::Streaming && self.dynamic_threshold {
            self.tau0 * (1.0 - self.alpha * (1.0 - r_mask))
        } else {
            self.tau0
        }
    }

    /// Sanity checks; returns an error message on invalid combos.
    pub fn validate(&self) -> Result<(), String> {
        if self.block_size == 0 {
            return Err("block_size must be > 0".into());
        }
        if self.gen_len == 0 {
            return Err("gen_len must be > 0".into());
        }
        if self.gen_len % self.block_size != 0 {
            return Err(format!(
                "gen_len {} not a multiple of block_size {}",
                self.gen_len, self.block_size
            ));
        }
        if !(0.0..=1.0).contains(&self.tau0) {
            return Err(format!("tau0 {} outside [0,1]", self.tau0));
        }
        if !(0.0..=1.0).contains(&self.alpha) {
            return Err(format!("alpha {} outside [0,1]", self.alpha));
        }
        if self.dkv_refresh == 0 && self.method == Method::DkvCache {
            return Err("dkv_refresh must be > 0".into());
        }
        if self.remask && !(0.0..=1.0).contains(&self.remask_tau) {
            return Err(format!("remask_tau {} outside [0,1]", self.remask_tau));
        }
        Ok(())
    }
}

/// The per-(model, suite, gen-length) hyperparameters of paper Table 12,
/// scaled ÷4. Window values follow the paper's per-benchmark tuning.
pub fn table12_config(model: &str, suite: &str, gen_len: usize) -> GenConfig {
    let mut c = GenConfig::preset(Method::Streaming, gen_len);
    // paper windows (tokens, original scale) — divide by 4.
    let w_paper: usize = match (model, suite, gen_len) {
        ("dream-mini", "humaneval-mini", 64) => 192,
        ("dream-mini", "humaneval-mini", _) => 128,
        ("dream-mini", "mbpp-mini", _) => 192,
        ("dream-mini", _, _) => 32,
        ("llada-mini", "humaneval-mini", 64) => 192,
        ("llada-mini", "humaneval-mini", _) => 256,
        ("llada-mini", "gsm-mini", _) => 96,
        ("llada-mini", "mbpp-mini", _) => 32,
        ("llada-mini", "math-mini", 64) => 128,
        ("llada-mini", "math-mini", _) => 256,
        ("llada15-mini", "gsm-mini", 128) => 128,
        ("llada15-mini", "math-mini", 128) => 192,
        _ => 96,
    };
    let a_paper = match (model, suite, gen_len) {
        ("dream-mini", "humaneval-mini", 64) => 0.7,
        ("dream-mini", "humaneval-mini", _) => 0.4,
        ("dream-mini", "mbpp-mini", 128) => 0.6,
        ("dream-mini", "math-mini", 64) => 0.1,
        ("llada-mini", "humaneval-mini", 128) => 0.4,
        ("llada-mini", "math-mini", 128) => 0.2,
        ("llada15-mini", "humaneval-mini", 128) => 0.4,
        ("llada15-mini", "gsm-mini", 64) => 0.4,
        ("llada15-mini", "gsm-mini", 128) => 0.6,
        ("llada15-mini", "math-mini", 64) => 0.4,
        _ => 0.3,
    };
    c.window = (w_paper / 4).max(c.block_size);
    // windows can't exceed the suffix itself
    c.window = c.window.min(gen_len.saturating_sub(c.block_size));
    c.alpha = a_paper;
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for m in Method::all() {
            for len in [64, 128, 256, 512] {
                GenConfig::preset(m, len).validate().unwrap();
            }
        }
    }

    #[test]
    fn streaming_enables_all_modules() {
        let c = GenConfig::preset(Method::Streaming, 64);
        assert!(c.suffix_pruning && c.dynamic_threshold && c.early_exit);
        let f = GenConfig::preset(Method::FastDllm, 64);
        assert!(!f.suffix_pruning && !f.dynamic_threshold && !f.early_exit);
    }

    #[test]
    fn dynamic_threshold_decays_with_commits() {
        let c = GenConfig::preset(Method::Streaming, 64);
        // fully masked block → τ = τ0
        assert!((c.threshold(1.0) - c.tau0).abs() < 1e-6);
        // mostly committed block → lower threshold
        assert!(c.threshold(0.25) < c.tau0);
        // monotone in r_mask
        assert!(c.threshold(0.5) <= c.threshold(0.9));
    }

    #[test]
    fn fixed_threshold_for_fast_dllm() {
        let c = GenConfig::preset(Method::FastDllm, 64);
        assert_eq!(c.threshold(1.0), c.threshold(0.1));
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = GenConfig::preset(Method::Streaming, 64);
        c.gen_len = 63;
        assert!(c.validate().is_err());
        let mut c2 = GenConfig::preset(Method::Streaming, 64);
        c2.tau0 = 1.5;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn table12_window_bounded_by_suffix() {
        let c = table12_config("llada15-mini", "gsm-mini", 64);
        assert!(c.window <= 64 - c.block_size);
        c.validate().unwrap();
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }
}
