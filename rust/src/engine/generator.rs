//! The block-wise diffusion driver: runs a batch of sequences through
//! prefill/decode (cached methods) or full forwards (vanilla), applying
//! the configured suffix modeling, selection policy and early exit.
//!
//! This is the rust half of the paper's contribution: §3.3's three
//! mechanisms are pure scheduling decisions made here, over packed
//! (token, confidence) tensors returned by the AOT executables.

use anyhow::{bail, Result};

use super::backend::Backend;
use super::config::{GenConfig, Method};
use super::policy::{select, Candidate, Selection};
use super::sequence::SeqState;
use super::suffix::{build_bundle, bundle_tokens};

/// Per-step observation for the confidence figures (Fig. 3 / 7–14):
/// confidences of the still-masked positions of row 0's current block.
#[derive(Debug, Clone)]
pub struct StepEvent {
    pub block: usize,
    pub step_in_block: usize,
    pub masked_confs: Vec<f32>,
    pub threshold: f32,
    pub committed: usize,
}

/// Outcome of one `generate` call.
#[derive(Debug, Clone, Default)]
pub struct GenReport {
    pub wall_secs: f64,
    /// model forward passes (decode or logits), the NFE count
    pub steps: u64,
    pub prefills: u64,
    pub non_eos_tokens: u64,
    /// blocks skipped by early exit, across the batch
    pub blocks_skipped: u64,
}

impl GenReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.non_eos_tokens as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

pub struct Generator<'a, B: Backend> {
    rt: &'a B,
    cfg: GenConfig,
}

impl<'a, B: Backend> Generator<'a, B> {
    pub fn new(rt: &'a B, cfg: GenConfig) -> Result<Generator<'a, B>> {
        if let Err(e) = cfg.validate() {
            bail!("invalid GenConfig: {e}");
        }
        Ok(Generator { rt, cfg })
    }

    pub fn config(&self) -> &GenConfig {
        &self.cfg
    }

    /// Decode a batch of sequences in place. All sequences share the
    /// config; prompts may differ in length. `on_step` observes row 0
    /// (used by the confidence-figure benches).
    pub fn generate(
        &self,
        seqs: &mut [SeqState],
        mut on_step: Option<&mut dyn FnMut(StepEvent)>,
    ) -> Result<GenReport> {
        let t0 = std::time::Instant::now();
        let mut report = GenReport::default();
        if seqs.is_empty() {
            return Ok(report);
        }
        let batch = self
            .rt
            .pick_batch(seqs.len())
            .ok_or_else(|| anyhow::anyhow!("batch {} exceeds buckets", seqs.len()))?;

        // pad the batch with tiny dummy rows (1-token prompt, same L)
        let special = self.rt.special();
        let gen_len = self.cfg.gen_len;
        let mut all: Vec<SeqState> = Vec::with_capacity(batch);
        let n_real = seqs.len();
        for s in seqs.iter() {
            all.push(s.clone());
        }
        for _ in n_real..batch {
            all.push(SeqState::new(&[special.bos], gen_len, &special));
        }

        match self.cfg.method {
            Method::Vanilla => self.run_vanilla(&mut all, &mut report, &mut on_step)?,
            _ => self.run_cached(&mut all, &mut report, &mut on_step)?,
        }

        for (dst, src) in seqs.iter_mut().zip(all.iter()) {
            *dst = src.clone();
        }
        report.non_eos_tokens = seqs.iter().map(|s| s.non_eos_tokens() as u64).sum();
        report.wall_secs = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    // -----------------------------------------------------------------
    // Vanilla: full forward every step, no cache.
    // -----------------------------------------------------------------
    fn run_vanilla(
        &self,
        seqs: &mut [SeqState],
        report: &mut GenReport,
        on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
    ) -> Result<()> {
        let batch = seqs.len();
        let k = self.cfg.block_size;
        let s_need = seqs.iter().map(|s| s.total_len()).max().unwrap();
        let s_bucket = self
            .rt
            .pick_seq(s_need)
            .ok_or_else(|| anyhow::anyhow!("seq {s_need} exceeds buckets"))?;
        let special = self.rt.special();

        let mut tokens = vec![special.pad; batch * s_bucket];
        let mut pos = vec![0i32; batch * s_bucket];
        let mut valid = vec![0i32; batch];
        let mut p0s = vec![0i32; batch];
        for (b, s) in seqs.iter().enumerate() {
            valid[b] = s.total_len() as i32;
            p0s[b] = s.p0 as i32;
            for j in 0..s_bucket {
                pos[b * s_bucket + j] = j as i32;
            }
        }

        let n_blocks = self.cfg.n_blocks();
        let max_steps = (n_blocks * k * 4) as u64 + 8;
        let mut guard = 0u64;
        while seqs.iter().any(|s| !s.finished) {
            guard += 1;
            if guard > max_steps {
                bail!("vanilla decode failed to terminate");
            }
            for (b, s) in seqs.iter().enumerate() {
                for (j, &t) in s.tokens.iter().enumerate() {
                    tokens[b * s_bucket + j] = t;
                }
                for j in s.tokens.len()..s_bucket {
                    tokens[b * s_bucket + j] = special.pad;
                }
            }
            let out = self.rt.logits(
                batch,
                s_bucket,
                &tokens,
                &pos,
                &valid,
                if self.rt.wants_p0() { Some(&p0s) } else { None },
            )?;
            report.steps += 1;

            for (b, s) in seqs.iter_mut().enumerate() {
                if s.finished {
                    continue;
                }
                let masked = s.masked_in_block(k);
                if masked.is_empty() {
                    // advance block cursor
                    s.block += 1;
                    if s.block >= n_blocks {
                        s.finished = true;
                    }
                    continue;
                }
                let cands: Vec<Candidate> = masked
                    .iter()
                    .map(|&p| Candidate {
                        pos: p,
                        token: sanitize(out.token(b, p), special.mask, special.pad, special.eos),
                        conf: out.conf(b, p),
                    })
                    .collect();
                if b == 0 {
                    if let Some(cb) = on_step.as_mut() {
                        cb(StepEvent {
                            block: s.block,
                            step_in_block: (k - masked.len().min(k)),
                            masked_confs: cands.iter().map(|c| c.conf).collect(),
                            threshold: 1.0,
                            committed: 1,
                        });
                    }
                }
                for i in select(Selection::OnePerStep, &cands) {
                    s.commit_with_conf(cands[i].pos, cands[i].token, cands[i].conf);
                }
                s.steps += 1;
                if s.block_done(k) {
                    s.block += 1;
                    if s.block >= n_blocks {
                        s.finished = true;
                    }
                }
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Cached methods: per-block prefill + bundle decode steps.
    // -----------------------------------------------------------------
    fn run_cached(
        &self,
        seqs: &mut [SeqState],
        report: &mut GenReport,
        on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
    ) -> Result<()> {
        let batch = seqs.len();
        let k = self.cfg.block_size;
        let n_blocks = self.cfg.n_blocks();
        let early_exit = self.cfg.method == Method::Streaming && self.cfg.early_exit;

        for blk in 0..n_blocks {
            if seqs.iter().all(|s| s.finished) {
                report.blocks_skipped += ((n_blocks - blk) * batch) as u64;
                break;
            }
            for s in seqs.iter_mut() {
                if !s.finished {
                    debug_assert_eq!(s.block, blk);
                }
            }

            let mut kv = self.prefill_block(seqs, blk)?;
            report.prefills += 1;

            let mut step_in_block = 0usize;
            let guard_max = k * 4 + 8 + if self.cfg.remask { k } else { 0 };
            loop {
                let any_masked = seqs
                    .iter()
                    .any(|s| !s.finished && !s.block_done(k));
                if !any_masked {
                    break;
                }
                if step_in_block > guard_max {
                    bail!("block decode failed to terminate");
                }
                // dKV-Cache emulation: delayed refresh pays periodic
                // prefix recompute inside the block.
                if self.cfg.method == Method::DkvCache
                    && step_in_block > 0
                    && step_in_block % self.cfg.dkv_refresh == 0
                {
                    kv = self.prefill_block(seqs, blk)?;
                    report.prefills += 1;
                }

                self.decode_step(seqs, &kv, blk, step_in_block, early_exit, report, on_step)?;
                step_in_block += 1;
            }

            // block complete: early-exit check + cursor advance
            for s in seqs.iter_mut() {
                if s.finished {
                    continue;
                }
                if early_exit && s.block_all_eos(k) {
                    let remaining = n_blocks - (s.block + 1);
                    report.blocks_skipped += remaining as u64;
                    s.finish_with_eos();
                    continue;
                }
                s.block += 1;
                if s.block >= n_blocks {
                    s.finished = true;
                }
            }
        }
        Ok(())
    }

    fn prefill_block(&self, seqs: &[SeqState], blk: usize) -> Result<B::Kv> {
        let batch = seqs.len();
        let k = self.cfg.block_size;
        let special = self.rt.special();
        let p_need = seqs
            .iter()
            .map(|s| if s.finished { 1 } else { s.p0 + blk * k })
            .max()
            .unwrap()
            .max(1);
        let p_bucket = self
            .rt
            .pick_prefix(p_need)
            .ok_or_else(|| anyhow::anyhow!("prefix {p_need} exceeds buckets"))?;

        let mut tokens = vec![special.pad; batch * p_bucket];
        let mut pos = vec![0i32; batch * p_bucket];
        let mut valid = vec![1i32; batch];
        let mut p0s = vec![0i32; batch];
        for (b, s) in seqs.iter().enumerate() {
            let plen = if s.finished { 1 } else { s.p0 + blk * k };
            valid[b] = plen as i32;
            p0s[b] = s.p0 as i32;
            for j in 0..p_bucket {
                pos[b * p_bucket + j] = j as i32;
            }
            for j in 0..plen.min(s.tokens.len()) {
                tokens[b * p_bucket + j] = s.tokens[j];
            }
        }
        self.rt.prefill(
            batch,
            p_bucket,
            &tokens,
            &pos,
            &valid,
            if self.rt.wants_p0() { Some(&p0s) } else { None },
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn decode_step(
        &self,
        seqs: &mut [SeqState],
        kv: &B::Kv,
        blk: usize,
        step_in_block: usize,
        early_exit: bool,
        report: &mut GenReport,
        on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
    ) -> Result<()> {
        let batch = seqs.len();
        let k = self.cfg.block_size;
        let special = self.rt.special();

        // build bundles
        let bundles: Vec<_> = seqs.iter().map(|s| build_bundle(s, &self.cfg)).collect();
        let q_need = bundles.iter().map(|b| b.positions.len()).max().unwrap().max(1);
        let q_bucket = self
            .rt
            .pick_query(q_need)
            .ok_or_else(|| anyhow::anyhow!("query {q_need} exceeds buckets"))?;

        let mut q_tok = vec![special.mask; batch * q_bucket];
        let mut q_pos = vec![0i32; batch * q_bucket];
        let mut q_valid = vec![0i32; batch];
        for (b, s) in seqs.iter().enumerate() {
            let bun = &bundles[b];
            q_valid[b] = bun.positions.len() as i32;
            let toks = bundle_tokens(s, bun);
            for (j, (&p, &t)) in bun.positions.iter().zip(toks.iter()).enumerate() {
                q_tok[b * q_bucket + j] = t;
                q_pos[b * q_bucket + j] = p as i32;
            }
        }

        let out = self.rt.decode(kv, q_bucket, &q_tok, &q_pos, &q_valid)?;
        report.steps += 1;

        for (b, s) in seqs.iter_mut().enumerate() {
            if s.finished || s.block_done(k) {
                continue;
            }
            let bun = &bundles[b];
            let r_mask = s.mask_ratio(k);
            // candidates: masked positions within the current block,
            // which occupy the first `block_len` bundle slots.
            let mut cands = Vec::with_capacity(bun.block_len);
            for j in 0..bun.block_len {
                let abs = bun.positions[j];
                if s.is_masked(abs) {
                    cands.push(Candidate {
                        pos: abs,
                        token: sanitize(out.token(b, j), special.mask, special.pad, special.eos),
                        conf: out.conf(b, j),
                    });
                }
            }
            if cands.is_empty() {
                continue;
            }
            let policy = if self.cfg.parallel_decoding() {
                Selection::Threshold(self.cfg.threshold(r_mask))
            } else {
                Selection::OnePerStep
            };
            let picked = select(policy, &cands);
            if b == 0 {
                if let Some(cb) = on_step.as_mut() {
                    cb(StepEvent {
                        block: blk,
                        step_in_block,
                        masked_confs: cands.iter().map(|c| c.conf).collect(),
                        threshold: match policy {
                            Selection::Threshold(t) => t,
                            Selection::OnePerStep => 1.0,
                        },
                        committed: picked.len(),
                    });
                }
            }
            for &i in &picked {
                s.commit_with_conf(cands[i].pos, cands[i].token, cands[i].conf);
            }
            // ReMDM extension: revise low-confidence commits (once per
            // position) while the block is still open.
            if self.cfg.remask && !s.block_done(k) {
                s.remask_low_confidence(k, self.cfg.remask_tau);
            }
            s.steps += 1;
            if early_exit && s.early_exit_scan(k) {
                // rest of the block was EOS-filled; final decision at
                // block completion (block_all_eos / finish_with_eos).
                let n_blocks = self.cfg.n_blocks();
                let remaining = n_blocks - (s.block + 1);
                report.blocks_skipped += remaining as u64;
                s.finish_with_eos();
            }
        }
        Ok(())
    }
}

/// The head can in principle emit special tokens that would corrupt the
/// canvas (committing MASK would livelock the loop). Map them to EOS —
/// never a legal content token, and harmless to answer extraction.
fn sanitize(tok: i32, mask: i32, pad: i32, eos: i32) -> i32 {
    if tok == mask || tok == pad {
        eos
    } else {
        tok
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_maps_specials_to_eos() {
        assert_eq!(sanitize(1, 1, 0, 3), 3);
        assert_eq!(sanitize(0, 1, 0, 3), 3);
        assert_eq!(sanitize(42, 1, 0, 3), 42);
        assert_eq!(sanitize(3, 1, 0, 3), 3);
    }

    #[test]
    fn report_tps_zero_safe() {
        let r = GenReport::default();
        assert_eq!(r.tokens_per_sec(), 0.0);
    }
}
