//! The block-wise diffusion driver: runs a batch of sequences through
//! prefill/decode (cached methods) or full forwards (vanilla), applying
//! the configured suffix modeling, selection policy and early exit.
//!
//! This is the rust half of the paper's contribution: §3.3's three
//! mechanisms are pure scheduling decisions made here, over packed
//! (token, confidence) tensors returned by the AOT executables.
//!
//! The step machinery itself lives in [`super::workspace`]: the
//! generator owns a [`StepWorkspace`] (so host buffers, bundles and
//! candidate lists are reused across steps *and* across `generate`
//! calls) plus a recycled pool of padding rows, and drives the shared
//! block-round core batch-at-a-time. For slot-based streaming admission
//! over the same core, see [`super::batch::BatchEngine`].

use anyhow::{bail, Result};

use super::backend::Backend;
use super::config::{GenConfig, Method};
use super::prefix_cache::PrefixHandle;
use super::sequence::SeqState;
use super::workspace::{run_block_round, run_vanilla, RowsMut, StepWorkspace};

/// Per-step observation for the confidence figures (Fig. 3 / 7–14):
/// confidences of the still-masked positions of row 0's current block.
#[derive(Debug, Clone)]
pub struct StepEvent {
    pub block: usize,
    pub step_in_block: usize,
    pub masked_confs: Vec<f32>,
    pub threshold: f32,
    pub committed: usize,
}

/// Outcome of one `generate` call (or one `BatchEngine` lifetime).
#[derive(Debug, Clone, Default)]
pub struct GenReport {
    pub wall_secs: f64,
    /// model forward passes (decode or logits), the NFE count
    pub steps: u64,
    pub prefills: u64,
    pub non_eos_tokens: u64,
    /// blocks skipped by early exit — counted exactly once per real
    /// row (padding rows and double counts excluded)
    pub blocks_skipped: u64,
    /// seconds inside backend prefill calls
    pub prefill_secs: f64,
    /// prefill seconds attributable to calls that included at least one
    /// fresh row (first prefill of a request's life)
    pub init_prefill_secs: f64,
    /// prefill seconds for pure re-prefills (dKV-Cache refresh and
    /// later-block prefix recompute — no fresh row in the call)
    pub reprefill_secs: f64,
    /// prefill calls counted into `init_prefill_secs`
    pub init_prefills: u64,
    /// prefill calls counted into `reprefill_secs`
    pub reprefills: u64,
    /// seconds inside backend decode/logits calls
    pub decode_secs: f64,
    /// *measured* seconds in the candidate-gather / selection / commit
    /// inner loops — the host work this attribution used to bury in the
    /// derived remainder. A sub-bucket of `host_secs`, timed directly
    /// so vectorization wins show up in the thing they change.
    pub select_secs: f64,
    /// seconds in the host scheduling layer (wall − prefill − decode):
    /// bundle building, buffer gather/scatter, selection and commits
    pub host_secs: f64,
}

impl GenReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.non_eos_tokens as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Fill in the derived host share once wall time is known.
    pub(crate) fn finish_phases(&mut self) {
        self.host_secs = (self.wall_secs - self.prefill_secs - self.decode_secs).max(0.0);
    }
}

/// Workspace counters exposed for the `host_overhead` bench: buffer
/// growth events vs steps driven (allocs-per-step proxy — near zero
/// after the first block of a steady-state workload).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkspaceStats {
    pub grows: u64,
    pub steps: u64,
}

pub struct Generator<'a, B: Backend> {
    rt: &'a B,
    cfg: GenConfig,
    ws: StepWorkspace,
    /// recycled dummy rows used to pad real batches up to the bucket
    pads: Vec<SeqState>,
    /// cross-request prefix cache handle (None = caching off)
    prefix: Option<PrefixHandle>,
}

impl<'a, B: Backend> Generator<'a, B> {
    pub fn new(rt: &'a B, cfg: GenConfig) -> Result<Generator<'a, B>> {
        if let Err(e) = cfg.validate() {
            bail!("invalid GenConfig: {e}");
        }
        Ok(Generator { rt, cfg, ws: StepWorkspace::new(), pads: Vec::new(), prefix: None })
    }

    pub fn config(&self) -> &GenConfig {
        &self.cfg
    }

    /// Attach a cross-request prefix-cache handle. Cached decode is
    /// bit-identical to cold decode (pinned by the parity tests), so
    /// this only changes where prefill time goes, never the output.
    pub fn set_prefix_cache(&mut self, handle: PrefixHandle) {
        self.prefix = Some(handle);
    }

    pub fn workspace_stats(&self) -> WorkspaceStats {
        WorkspaceStats { grows: self.ws.grows, steps: self.ws.steps }
    }

    /// Decode a batch of sequences in place. All sequences share the
    /// config; prompts may differ in length. `on_step` observes row 0
    /// (used by the confidence-figure benches). Takes `&mut self`
    /// because the scratch workspace (and the padding-row pool) is
    /// reused across calls — that reuse is the zero-allocation core.
    pub fn generate(
        &mut self,
        seqs: &mut [SeqState],
        mut on_step: Option<&mut dyn FnMut(StepEvent)>,
    ) -> Result<GenReport> {
        let t0 = std::time::Instant::now();
        let mut report = GenReport::default();
        if seqs.is_empty() {
            return Ok(report);
        }
        let batch = self
            .rt
            .pick_batch(seqs.len())
            .ok_or_else(|| anyhow::anyhow!("batch {} exceeds buckets", seqs.len()))?;
        let special = self.rt.special();
        let k = self.cfg.block_size;
        let gen_len = self.cfg.gen_len;
        for s in seqs.iter_mut() {
            s.init_block_counts(k);
        }

        // Recycle the padding pool: tiny dummy rows (1-token prompt,
        // same L) brought back to their initial state in place.
        let n_pad = batch - seqs.len();
        self.pads.truncate(n_pad);
        for p in self.pads.iter_mut() {
            p.reset(&[special.bos], gen_len, &special);
            p.init_block_counts(k);
        }
        while self.pads.len() < n_pad {
            let mut p = SeqState::new(&[special.bos], gen_len, &special);
            p.init_block_counts(k);
            self.pads.push(p);
        }

        {
            let this = &mut *self;
            let mut rows = RowsMut { real: &mut *seqs, pad: &mut this.pads };
            let batch_rows = rows.len();
            match this.cfg.method {
                Method::Vanilla => run_vanilla(
                    this.rt,
                    &this.cfg,
                    &mut this.ws,
                    &mut rows,
                    batch_rows,
                    &mut report,
                    &mut on_step,
                    u64::MAX, // batch-at-a-time: classic run to completion
                )?,
                _ => run_cached(
                    this.rt,
                    &this.cfg,
                    &mut this.ws,
                    &mut rows,
                    batch_rows,
                    this.prefix.as_ref(),
                    &mut report,
                    &mut on_step,
                )?,
            }
        }

        report.non_eos_tokens = seqs.iter().map(|s| s.non_eos_tokens() as u64).sum();
        report.wall_secs = t0.elapsed().as_secs_f64();
        report.finish_phases();
        Ok(report)
    }
}

/// Batch-at-a-time cached decode: every row marches its own cursor, but
/// admission is fixed at call time, so rows stay in block lockstep (the
/// seed-compatible schedule the golden parity tests pin).
#[allow(clippy::too_many_arguments)]
fn run_cached<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    ws: &mut StepWorkspace,
    rows: &mut RowsMut,
    batch: usize,
    prefix: Option<&PrefixHandle>,
    report: &mut GenReport,
    on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
) -> Result<()> {
    let n_blocks = cfg.n_blocks();
    for blk in 0..n_blocks {
        if rows.iter().all(|s| s.finished) {
            break;
        }
        for s in rows.iter() {
            if !s.finished {
                debug_assert_eq!(s.block, blk);
            }
        }
        run_block_round(rt, cfg, ws, rows, batch, prefix, report, on_step)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_tps_zero_safe() {
        let r = GenReport::default();
        assert_eq!(r.tokens_per_sec(), 0.0);
    }

    #[test]
    fn phase_split_never_negative() {
        let mut r = GenReport {
            wall_secs: 1.0,
            prefill_secs: 0.7,
            decode_secs: 0.5, // timer skew: phases can exceed wall
            ..Default::default()
        };
        r.finish_phases();
        assert_eq!(r.host_secs, 0.0);
        let mut r2 = GenReport {
            wall_secs: 1.0,
            prefill_secs: 0.2,
            decode_secs: 0.3,
            ..Default::default()
        };
        r2.finish_phases();
        assert!((r2.host_secs - 0.5).abs() < 1e-9);
    }
}
