//! The decoding engine: Streaming-dLLM's three mechanisms (suffix
//! pruning, dynamic confidence-aware parallel decoding, early exit) and
//! every baseline, implemented as scheduling policies over an abstract
//! model [`Backend`].
//!
//! Backends: the always-available pure-Rust [`ReferenceBackend`] and —
//! behind the `pjrt` cargo feature — `runtime::ModelRuntime` (AOT
//! executables). [`AnyBackend`] selects between them at runtime.

pub mod any;
pub mod backend;
pub mod batch;
pub mod config;
pub mod generator;
pub mod policy;
pub mod prefix_cache;
pub mod reference;
pub mod sequence;
pub mod suffix;
pub mod types;
pub mod workspace;

pub use any::{AnyBackend, AnyKv};
pub use backend::{Backend, CachedSpan, PrefixCapture};
pub use batch::{clamp_batch, BatchEngine, Finished, RowCommit};
pub use config::{table12_config, GenConfig, Method};
pub use generator::{GenReport, Generator, StepEvent, WorkspaceStats};
pub use policy::{
    argmax_conf, select, select_into, select_soa, Candidate, DecodePolicy, SpatialPolicy,
    TemporalPolicy, Trend,
};
pub use prefix_cache::{
    prefix_scope_for, PrefixCache, PrefixCacheStats, PrefixHandle, PrefixHit, SharedPrefixCache,
};
pub use reference::{RefKv, RefMode, RefPrefix, RefStats, ReferenceBackend, REFERENCE_SEED};
pub use sequence::SeqState;
pub use suffix::{build_bundle, build_bundle_into, bundle_tokens, Bundle};
pub use types::{detokenize_until_eos, pick_bucket, Buckets, DecodeOut, SpecialTokens};
pub use workspace::StepWorkspace;
