//! The decoding engine: Streaming-dLLM's three mechanisms (suffix
//! pruning, dynamic confidence-aware parallel decoding, early exit) and
//! every baseline, implemented as scheduling policies over the AOT
//! executables.

pub mod backend;
pub mod config;
pub mod generator;
pub mod policy;
pub mod sequence;
pub mod suffix;

pub use backend::{Backend, MockBackend};
pub use config::{table12_config, GenConfig, Method};
pub use generator::{GenReport, Generator, StepEvent};
pub use policy::{select, Candidate, Selection};
pub use sequence::SeqState;
pub use suffix::{build_bundle, bundle_tokens, Bundle};
