//! Token-selection policies — the S(·) of paper Eq. 5/9.
//!
//! Given the (token, confidence) predictions at the masked positions of
//! the current block, decide which to commit this step:
//!
//! - `OnePerStep`: vanilla LLaDA remasking schedule — commit exactly the
//!   highest-confidence prediction (K steps per block).
//! - `Threshold`: Fast-dLLM — commit everything ≥ τ; if nothing clears
//!   the bar, fall back to the single best (Eq. 9 second case), which
//!   guarantees progress/termination.
//!
//! The *dynamic* part of "dynamic confidence-aware parallel decoding"
//! lives in `GenConfig::threshold(r_mask)` (Eq. 10); this module is pure
//! selection and is what the property tests hammer.

/// One masked position's prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// absolute position in the sequence canvas
    pub pos: usize,
    pub token: i32,
    pub conf: f32,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Selection {
    OnePerStep,
    Threshold(f32),
}

/// Writes the indices (into `cands`) to commit into `out`, reusing its
/// allocation — the zero-allocation form the decode hot path uses.
/// Invariants (pinned by property tests):
/// - never empty when `cands` is non-empty (progress guarantee)
/// - threshold mode: every candidate with conf ≥ τ is selected
/// - one-per-step: exactly one, the argmax by confidence
pub fn select_into(policy: Selection, cands: &[Candidate], out: &mut Vec<usize>) {
    out.clear();
    if cands.is_empty() {
        return;
    }
    match policy {
        Selection::OnePerStep => out.push(argmax(cands)),
        Selection::Threshold(tau) => {
            for (i, c) in cands.iter().enumerate() {
                if c.conf >= tau {
                    out.push(i);
                }
            }
            if out.is_empty() {
                out.push(argmax(cands));
            }
        }
    }
}

/// Allocating convenience wrapper over [`select_into`].
pub fn select(policy: Selection, cands: &[Candidate]) -> Vec<usize> {
    let mut out = Vec::new();
    select_into(policy, cands, &mut out);
    out
}

fn argmax(cands: &[Candidate]) -> usize {
    let mut best = 0;
    for (i, c) in cands.iter().enumerate().skip(1) {
        if c.conf > cands[best].conf {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cand(pos: usize, conf: f32) -> Candidate {
        Candidate { pos, token: 7, conf }
    }

    #[test]
    fn one_per_step_picks_argmax() {
        let cands = [cand(0, 0.2), cand(1, 0.9), cand(2, 0.5)];
        assert_eq!(select(Selection::OnePerStep, &cands), vec![1]);
    }

    #[test]
    fn threshold_takes_all_above() {
        let cands = [cand(0, 0.95), cand(1, 0.5), cand(2, 0.92)];
        assert_eq!(select(Selection::Threshold(0.9), &cands), vec![0, 2]);
    }

    #[test]
    fn threshold_fallback_to_best() {
        let cands = [cand(0, 0.1), cand(1, 0.4), cand(2, 0.3)];
        assert_eq!(select(Selection::Threshold(0.9), &cands), vec![1]);
    }

    #[test]
    fn select_into_clears_previous_contents() {
        let mut out = vec![99, 98, 97];
        let cands = [cand(0, 0.95), cand(1, 0.5)];
        select_into(Selection::Threshold(0.9), &cands, &mut out);
        assert_eq!(out, vec![0]);
        select_into(Selection::OnePerStep, &[], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn empty_input_empty_output() {
        assert!(select(Selection::Threshold(0.5), &[]).is_empty());
        assert!(select(Selection::OnePerStep, &[]).is_empty());
    }

    #[test]
    fn prop_progress_guarantee() {
        prop::check(300, |g| {
            let n = g.usize(1, 20);
            let confs: Vec<f32> = (0..n).map(|_| g.f32(0.0, 1.0)).collect();
            let cands: Vec<Candidate> =
                confs.iter().enumerate().map(|(i, &c)| cand(i, c)).collect();
            let tau = g.f32(0.0, 1.0);
            let sel = select(Selection::Threshold(tau), &cands);
            if sel.is_empty() {
                return Err("no progress".into());
            }
            // all above-threshold candidates must be selected
            for (i, c) in cands.iter().enumerate() {
                if c.conf >= tau && !sel.contains(&i) {
                    return Err(format!("candidate {i} above tau but unselected"));
                }
            }
            // selection indices must be unique and in-range
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != sel.len() || sel.iter().any(|&i| i >= n) {
                return Err("bad indices".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_one_per_step_always_single_max() {
        prop::check(300, |g| {
            let n = g.usize(1, 32);
            let cands: Vec<Candidate> =
                (0..n).map(|i| cand(i, g.f32(0.0, 1.0))).collect();
            let sel = select(Selection::OnePerStep, &cands);
            if sel.len() != 1 {
                return Err(format!("expected 1, got {}", sel.len()));
            }
            let max = cands.iter().map(|c| c.conf).fold(f32::MIN, f32::max);
            if (cands[sel[0]].conf - max).abs() > 1e-9 {
                return Err("not the argmax".into());
            }
            Ok(())
        });
    }
}
