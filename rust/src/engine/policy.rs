//! First-class decode policies — the composable spatial × temporal
//! strategy space the paper's two mechanisms live in.
//!
//! A [`DecodePolicy`] is a pair of independent axes:
//!
//! - [`SpatialPolicy`] — *which masked positions ride in the query
//!   bundle* (paper §3.3, Eq. 7–8). Full suffix, a fixed sliding window
//!   plus trailing position id, an attenuating window that shrinks as
//!   decoding converges, or DPad-style seeded suffix dropout.
//! - [`TemporalPolicy`] — *which predictions commit each step*, the
//!   S(·) of Eq. 5/9/10. One-per-step (LLaDA), a static threshold τ
//!   (Fast-dLLM), the dynamic τ(r_mask) of Eq. 10, or an extrapolating
//!   rule that also commits tokens whose confidence trend predicts
//!   convergence.
//!
//! The three legacy [`Method`]s resolve to named presets
//! ([`DecodePolicy::for_method`]) with bit-identical schedules, so the
//! golden/parity/trade-off oracles are unchanged. Policies implement
//! `Eq + Hash` (confidence params compared/hashed by bit pattern) so
//! the batcher and router can key engine compatibility on them.

use super::config::Method;
use std::hash::{Hash, Hasher};

/// One masked position's prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// absolute position in the sequence canvas
    pub pos: usize,
    pub token: i32,
    pub conf: f32,
}

/// Confidence-trend observation for one candidate, fed to the
/// extrapolating temporal policy (ignored by every other variant). The
/// decode loop tracks this per masked position across steps.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Trend {
    /// confidence this position's prediction carried last step
    pub prev_conf: f32,
    /// consecutive *prior* steps that predicted the same token as now
    pub streak: u32,
}

/// Spatial axis: what the query bundle contains besides the current
/// block. Integer/bool parameters only, so `Eq`/`Hash` derive cleanly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpatialPolicy {
    /// The entire remaining suffix rides along (vanilla / Fast-dLLM).
    FullSuffix,
    /// Fixed sliding window of `window` suffix tokens after the block,
    /// plus (optionally) the trailing position id (Eq. 7).
    Window { window: usize, trailing: bool },
    /// Window that attenuates from `window` down to `min_window` as
    /// decoding progresses through the blocks — the suffix has converged
    /// by the time the tail blocks decode, so less of it is kept.
    Attenuating { window: usize, min_window: usize, trailing: bool },
    /// DPad-style seeded suffix dropout: the near `window` tokens are
    /// kept densely, and the far suffix is thinned to one deterministic
    /// survivor per `stride`-sized chunk (seeded, schedule-independent).
    Dropout { window: usize, stride: usize, seed: u64, trailing: bool },
}

/// Temporal axis: the commit rule S(·). Confidence parameters are
/// `f32`; equality/hashing use the bit pattern (policies are validated
/// finite, see [`DecodePolicy::validate`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TemporalPolicy {
    /// Commit exactly the highest-confidence prediction (K steps/block).
    OnePerStep,
    /// Fast-dLLM: commit everything ≥ τ; argmax fallback (Eq. 9).
    FixedTau { tau: f32 },
    /// Eq. 10: τ(r_mask) = τ0 · (1 − α · (1 − r_mask)); argmax fallback.
    DynamicTau { tau0: f32, alpha: f32 },
    /// DynamicTau plus an extrapolating early-commit: a prediction that
    /// has been stable for `min_streak` prior steps, sits at or above
    /// `floor`, and whose linear confidence trend reaches 1.0 within one
    /// more step (conf + gain·Δconf ≥ 1) commits even below τ.
    Extrapolating { tau0: f32, alpha: f32, gain: f32, floor: f32, min_streak: u32 },
}

// `PartialEq` on the f32 payloads is total over the validated parameter
// space (no NaN survives `validate`), so the `Eq` marker is sound.
impl Eq for TemporalPolicy {}

impl Hash for TemporalPolicy {
    fn hash<H: Hasher>(&self, state: &mut H) {
        fn f(x: f32, state: &mut impl Hasher) {
            // +0.0 collapses -0.0 onto +0.0 so a == b ⇒ hash(a) == hash(b)
            (x + 0.0).to_bits().hash(state);
        }
        std::mem::discriminant(self).hash(state);
        match *self {
            TemporalPolicy::OnePerStep => {}
            TemporalPolicy::FixedTau { tau } => f(tau, state),
            TemporalPolicy::DynamicTau { tau0, alpha } => {
                f(tau0, state);
                f(alpha, state);
            }
            TemporalPolicy::Extrapolating { tau0, alpha, gain, floor, min_streak } => {
                f(tau0, state);
                f(alpha, state);
                f(gain, state);
                f(floor, state);
                min_streak.hash(state);
            }
        }
    }
}

/// The composable decode policy: one spatial choice × one temporal
/// choice. This is what `GenConfig` carries, what the batcher keys
/// engine compatibility on, and what a v1 wire request may select.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DecodePolicy {
    pub spatial: SpatialPolicy,
    pub temporal: TemporalPolicy,
}

/// Preset window (paper w = 96 scaled ÷4) shared by every named preset.
pub const PRESET_WINDOW: usize = 24;
/// Preset base threshold τ0 (Eq. 10).
pub const PRESET_TAU0: f32 = 0.9;
/// Preset adaptation strength α (Eq. 10).
pub const PRESET_ALPHA: f32 = 0.3;

impl SpatialPolicy {
    /// Streaming-dLLM's fixed window + trailing position id.
    pub fn preset_window() -> SpatialPolicy {
        SpatialPolicy::Window { window: PRESET_WINDOW, trailing: true }
    }

    /// Whether this policy prunes the suffix at all (anything but
    /// [`SpatialPolicy::FullSuffix`]).
    pub fn is_pruning(&self) -> bool {
        !matches!(self, SpatialPolicy::FullSuffix)
    }

    /// The window in effect while decoding block `block` of `n_blocks`
    /// (`None` for the unpruned full suffix).
    pub fn window_at(&self, block: usize, n_blocks: usize) -> Option<usize> {
        match *self {
            SpatialPolicy::FullSuffix => None,
            SpatialPolicy::Window { window, .. } | SpatialPolicy::Dropout { window, .. } => {
                Some(window)
            }
            SpatialPolicy::Attenuating { window, min_window, .. } => {
                Some(attenuated_window(window, min_window, block, n_blocks))
            }
        }
    }

    /// Whether the trailing position id rides along when the window
    /// falls short of the suffix end.
    pub fn trailing(&self) -> bool {
        match *self {
            SpatialPolicy::FullSuffix => false,
            SpatialPolicy::Window { trailing, .. }
            | SpatialPolicy::Attenuating { trailing, .. }
            | SpatialPolicy::Dropout { trailing, .. } => trailing,
        }
    }

    /// Worst-case bundle length over every block of a generation — the
    /// admission/warm-up bound (`block + window + trailing`, clipped to
    /// the generation length; dropout adds its far-suffix survivors).
    pub fn max_bundle_len(&self, block_size: usize, gen_len: usize) -> usize {
        match *self {
            SpatialPolicy::FullSuffix => gen_len,
            SpatialPolicy::Window { window, .. }
            | SpatialPolicy::Attenuating { window, .. } => {
                (block_size + window + 1).min(gen_len)
            }
            SpatialPolicy::Dropout { window, stride, .. } => {
                let far = gen_len.saturating_sub(block_size + window);
                (block_size + window + far.div_ceil(stride.max(1)) + 1).min(gen_len)
            }
        }
    }

    /// Exact bundle length for block `block` when `suffix_len` masked
    /// tokens remain after it. Mirrors `suffix::build_bundle_into`
    /// (pinned against it by a property test there); the warm-up planner
    /// uses this to pre-compile exactly the query buckets a generation
    /// will touch.
    pub fn bundle_len_at(
        &self,
        block: usize,
        n_blocks: usize,
        block_size: usize,
        suffix_len: usize,
    ) -> usize {
        fn windowed(k: usize, suffix_len: usize, window: usize, trailing: bool) -> usize {
            let win = window.min(suffix_len);
            k + win + usize::from(trailing && win < suffix_len)
        }
        match *self {
            SpatialPolicy::FullSuffix => block_size + suffix_len,
            SpatialPolicy::Window { window, trailing } => {
                windowed(block_size, suffix_len, window, trailing)
            }
            SpatialPolicy::Attenuating { window, min_window, trailing } => {
                let w = attenuated_window(window, min_window, block, n_blocks);
                windowed(block_size, suffix_len, w, trailing)
            }
            SpatialPolicy::Dropout { window, stride, trailing, .. } => {
                let near = window.min(suffix_len);
                let far = suffix_len.saturating_sub(usize::from(trailing)).saturating_sub(near);
                let trail = usize::from(trailing && near < suffix_len);
                block_size + near + far.div_ceil(stride.max(1)) + trail
            }
        }
    }
}

/// Linear attenuation from `window` (first block) down to `min_window`
/// (last block), in integer arithmetic.
pub fn attenuated_window(window: usize, min_window: usize, block: usize, n_blocks: usize) -> usize {
    let lo = min_window.min(window);
    let span = window - lo;
    let denom = n_blocks.saturating_sub(1).max(1);
    window - span * block.min(denom) / denom
}

/// Deterministic survivor offset for one far-suffix chunk of the
/// dropout policy: chunk `chunk` keeps exactly one position, chosen by
/// the seed (independent of decode schedule or prompt placement).
pub fn dropout_survivor(seed: u64, chunk: usize, chunk_len: usize) -> usize {
    debug_assert!(chunk_len > 0);
    mix64(seed ^ (chunk as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) as usize % chunk_len
}

fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl TemporalPolicy {
    /// Effective threshold at a step (Eq. 10 for the dynamic variants):
    /// τ(t) = τ0 · (1 − α · (1 − r_mask)). One-per-step reports 1.0 —
    /// only fully-determined predictions would clear it.
    pub fn threshold(&self, r_mask: f32) -> f32 {
        match *self {
            TemporalPolicy::OnePerStep => 1.0,
            TemporalPolicy::FixedTau { tau } => tau,
            TemporalPolicy::DynamicTau { tau0, alpha }
            | TemporalPolicy::Extrapolating { tau0, alpha, .. } => {
                tau0 * (1.0 - alpha * (1.0 - r_mask))
            }
        }
    }

    /// Whether multiple tokens may commit per step.
    pub fn is_parallel(&self) -> bool {
        !matches!(self, TemporalPolicy::OnePerStep)
    }

    /// Whether the decode loop must track confidence trends for this
    /// policy (only the extrapolating rule reads them).
    pub fn uses_trend(&self) -> bool {
        matches!(self, TemporalPolicy::Extrapolating { .. })
    }
}

impl DecodePolicy {
    /// The preset a legacy [`Method`] resolves to — bit-identical to the
    /// pre-policy hard-wired schedules (pinned by golden/parity tests).
    pub fn for_method(method: Method) -> DecodePolicy {
        match method {
            Method::Vanilla | Method::DkvCache | Method::PrefixCache => DecodePolicy {
                spatial: SpatialPolicy::FullSuffix,
                temporal: TemporalPolicy::OnePerStep,
            },
            Method::FastDllm => DecodePolicy {
                spatial: SpatialPolicy::FullSuffix,
                temporal: TemporalPolicy::FixedTau { tau: PRESET_TAU0 },
            },
            Method::Streaming => DecodePolicy {
                spatial: SpatialPolicy::preset_window(),
                temporal: TemporalPolicy::DynamicTau { tau0: PRESET_TAU0, alpha: PRESET_ALPHA },
            },
        }
    }

    /// Every named preset, in canonical order: the five method presets
    /// followed by the new composable strategies.
    pub fn presets() -> [(&'static str, DecodePolicy); 8] {
        let dynamic = TemporalPolicy::DynamicTau { tau0: PRESET_TAU0, alpha: PRESET_ALPHA };
        [
            ("vanilla", DecodePolicy::for_method(Method::Vanilla)),
            ("dkv-cache", DecodePolicy::for_method(Method::DkvCache)),
            ("prefix-cache", DecodePolicy::for_method(Method::PrefixCache)),
            ("fast-dllm", DecodePolicy::for_method(Method::FastDllm)),
            ("streaming", DecodePolicy::for_method(Method::Streaming)),
            (
                "attenuating",
                DecodePolicy {
                    spatial: SpatialPolicy::Attenuating {
                        window: PRESET_WINDOW,
                        min_window: 8,
                        trailing: true,
                    },
                    temporal: dynamic,
                },
            ),
            (
                "extrapolating",
                DecodePolicy {
                    spatial: SpatialPolicy::preset_window(),
                    temporal: TemporalPolicy::Extrapolating {
                        tau0: PRESET_TAU0,
                        alpha: PRESET_ALPHA,
                        gain: 1.0,
                        floor: 1.0,
                        min_streak: 2,
                    },
                },
            ),
            (
                "dropout",
                DecodePolicy {
                    spatial: SpatialPolicy::Dropout {
                        window: PRESET_WINDOW,
                        stride: 4,
                        seed: 0xD9AD,
                        trailing: true,
                    },
                    temporal: dynamic,
                },
            ),
        ]
    }

    /// The canonical preset names, parseable by [`DecodePolicy::parse`].
    pub fn preset_names() -> [&'static str; 8] {
        DecodePolicy::presets().map(|(name, _)| name)
    }

    /// Look up a named preset.
    pub fn parse(name: &str) -> Option<DecodePolicy> {
        DecodePolicy::presets().into_iter().find(|(n, _)| *n == name).map(|(_, p)| p)
    }

    /// The first preset name this policy is structurally equal to, if
    /// any (several methods share the one-per-step full-suffix policy,
    /// so the mapping is canonical, not injective).
    pub fn name(&self) -> Option<&'static str> {
        DecodePolicy::presets().into_iter().find(|(_, p)| p == self).map(|(n, _)| n)
    }

    /// Parameter sanity — every confidence knob finite and in range, so
    /// the `Eq`/`Hash` impls are total over accepted policies.
    pub fn validate(&self) -> Result<(), String> {
        match self.spatial {
            SpatialPolicy::FullSuffix | SpatialPolicy::Window { .. } => {}
            SpatialPolicy::Attenuating { window, min_window, .. } => {
                if min_window > window {
                    return Err(format!(
                        "attenuating min_window {min_window} exceeds window {window}"
                    ));
                }
            }
            SpatialPolicy::Dropout { stride, .. } => {
                if stride == 0 {
                    return Err("dropout stride must be > 0".into());
                }
            }
        }
        let unit = |name: &str, v: f32| -> Result<(), String> {
            if !(0.0..=1.0).contains(&v) || v.is_nan() {
                return Err(format!("{name} {v} outside [0,1]"));
            }
            Ok(())
        };
        match self.temporal {
            TemporalPolicy::OnePerStep => {}
            TemporalPolicy::FixedTau { tau } => unit("tau0", tau)?,
            TemporalPolicy::DynamicTau { tau0, alpha } => {
                unit("tau0", tau0)?;
                unit("alpha", alpha)?;
            }
            TemporalPolicy::Extrapolating { tau0, alpha, gain, floor, .. } => {
                unit("tau0", tau0)?;
                unit("alpha", alpha)?;
                unit("floor", floor)?;
                if !gain.is_finite() || gain < 0.0 {
                    return Err(format!("gain {gain} must be finite and >= 0"));
                }
            }
        }
        Ok(())
    }
}

/// Writes the indices (into `cands`) to commit into `out`, reusing its
/// allocation — the zero-allocation form the decode hot path uses.
/// `trends` is a parallel slice of per-candidate confidence trends; it
/// may be empty (or short) when the policy does not read trends.
/// Invariants (pinned by property tests):
/// - never empty when `cands` is non-empty (progress guarantee)
/// - threshold family: every candidate with conf ≥ τ(r_mask) is selected
/// - one-per-step: exactly one, the argmax by confidence
pub fn select_into(
    policy: &TemporalPolicy,
    r_mask: f32,
    cands: &[Candidate],
    trends: &[Trend],
    out: &mut Vec<usize>,
) {
    out.clear();
    if cands.is_empty() {
        return;
    }
    match *policy {
        TemporalPolicy::OnePerStep => out.push(argmax(cands)),
        TemporalPolicy::FixedTau { .. } | TemporalPolicy::DynamicTau { .. } => {
            let tau = policy.threshold(r_mask);
            for (i, c) in cands.iter().enumerate() {
                if c.conf >= tau {
                    out.push(i);
                }
            }
            if out.is_empty() {
                out.push(argmax(cands));
            }
        }
        TemporalPolicy::Extrapolating { gain, floor, min_streak, .. } => {
            let tau = policy.threshold(r_mask);
            for (i, c) in cands.iter().enumerate() {
                let extrapolates = trends.get(i).is_some_and(|t| {
                    t.streak >= min_streak
                        && c.conf >= floor
                        && c.conf + gain * (c.conf - t.prev_conf) >= 1.0
                });
                if c.conf >= tau || extrapolates {
                    out.push(i);
                }
            }
            if out.is_empty() {
                out.push(argmax(cands));
            }
        }
    }
}

/// Allocating convenience wrapper over [`select_into`].
pub fn select(
    policy: &TemporalPolicy,
    r_mask: f32,
    cands: &[Candidate],
    trends: &[Trend],
) -> Vec<usize> {
    let mut out = Vec::new();
    select_into(policy, r_mask, cands, trends, &mut out);
    out
}

fn argmax(cands: &[Candidate]) -> usize {
    let mut best = 0;
    for (i, c) in cands.iter().enumerate().skip(1) {
        if c.conf.total_cmp(&cands[best].conf).is_gt() {
            best = i;
        }
    }
    best
}

/// Chunk width of the SoA kernels. 8 f32 lanes fit one AVX2 register;
/// the compare/reduce bodies below are written so the per-chunk work is
/// branch-free and autovectorizes.
const LANES: usize = 8;

/// Argmax over a contiguous confidence slice using the IEEE total order
/// (`f32::total_cmp`): first max wins, identical to the scalar
/// [`argmax`] for all inputs including NaN (which sorts above +inf
/// instead of silently losing every comparison). Chunked: each 8-lane
/// block reduces locally, then one compare folds it into the running
/// best — the inner reduction is branchless (conditional moves).
pub fn argmax_conf(conf: &[f32]) -> usize {
    debug_assert!(!conf.is_empty());
    let mut best = 0usize;
    let mut base = 0usize;
    let mut chunks = conf.chunks_exact(LANES);
    for chunk in &mut chunks {
        let mut local = 0usize;
        for j in 1..LANES {
            local = if chunk[j].total_cmp(&chunk[local]).is_gt() { j } else { local };
        }
        let cand = base + local;
        best = if conf[cand].total_cmp(&conf[best]).is_gt() { cand } else { best };
        base += LANES;
    }
    for (j, &c) in chunks.remainder().iter().enumerate() {
        let cand = base + j;
        best = if c.total_cmp(&conf[best]).is_gt() { cand } else { best };
    }
    best
}

/// Chunked threshold scan: per 8-lane chunk build a compare bitmask
/// (no branches in the compare loop), then pop set bits in index order.
/// NaN compares false against every τ — same as the scalar loop.
fn threshold_scan(conf: &[f32], tau: f32, out: &mut Vec<usize>) {
    let mut base = 0usize;
    let mut chunks = conf.chunks_exact(LANES);
    for chunk in &mut chunks {
        let mut mask = 0u32;
        for (j, &c) in chunk.iter().enumerate() {
            mask |= u32::from(c >= tau) << j;
        }
        while mask != 0 {
            out.push(base + mask.trailing_zeros() as usize);
            mask &= mask - 1;
        }
        base += LANES;
    }
    for (j, &c) in chunks.remainder().iter().enumerate() {
        if c >= tau {
            out.push(base + j);
        }
    }
}

/// Structure-of-arrays form of [`select_into`]: the decode hot path
/// keeps confidences in one contiguous `f32` slice (parallel to its
/// position/token slices), so the threshold compare and argmax run as
/// chunked kernels instead of walking `Candidate` structs. Selection is
/// bit-identical to [`select_into`] over the same confidences (pinned
/// by the `vector_parity` property test).
pub fn select_soa(
    policy: &TemporalPolicy,
    r_mask: f32,
    conf: &[f32],
    trends: &[Trend],
    out: &mut Vec<usize>,
) {
    out.clear();
    if conf.is_empty() {
        return;
    }
    match *policy {
        TemporalPolicy::OnePerStep => out.push(argmax_conf(conf)),
        TemporalPolicy::FixedTau { .. } | TemporalPolicy::DynamicTau { .. } => {
            threshold_scan(conf, policy.threshold(r_mask), out);
            if out.is_empty() {
                out.push(argmax_conf(conf));
            }
        }
        TemporalPolicy::Extrapolating { gain, floor, min_streak, .. } => {
            let tau = policy.threshold(r_mask);
            for (i, &c) in conf.iter().enumerate() {
                let extrapolates = trends.get(i).is_some_and(|t| {
                    t.streak >= min_streak && c >= floor && c + gain * (c - t.prev_conf) >= 1.0
                });
                if c >= tau || extrapolates {
                    out.push(i);
                }
            }
            if out.is_empty() {
                out.push(argmax_conf(conf));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::hash_map::DefaultHasher;

    fn cand(pos: usize, conf: f32) -> Candidate {
        Candidate { pos, token: 7, conf }
    }

    fn fixed(tau: f32) -> TemporalPolicy {
        TemporalPolicy::FixedTau { tau }
    }

    #[test]
    fn one_per_step_picks_argmax() {
        let cands = [cand(0, 0.2), cand(1, 0.9), cand(2, 0.5)];
        assert_eq!(select(&TemporalPolicy::OnePerStep, 1.0, &cands, &[]), vec![1]);
    }

    #[test]
    fn fixed_tau_takes_all_above() {
        let cands = [cand(0, 0.95), cand(1, 0.5), cand(2, 0.92)];
        assert_eq!(select(&fixed(0.9), 1.0, &cands, &[]), vec![0, 2]);
    }

    #[test]
    fn fixed_tau_fallback_to_best() {
        let cands = [cand(0, 0.1), cand(1, 0.4), cand(2, 0.3)];
        assert_eq!(select(&fixed(0.9), 1.0, &cands, &[]), vec![1]);
    }

    #[test]
    fn select_into_clears_previous_contents() {
        let mut out = vec![99, 98, 97];
        let cands = [cand(0, 0.95), cand(1, 0.5)];
        select_into(&fixed(0.9), 1.0, &cands, &[], &mut out);
        assert_eq!(out, vec![0]);
        select_into(&TemporalPolicy::OnePerStep, 1.0, &[], &[], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn empty_input_empty_output() {
        assert!(select(&fixed(0.5), 1.0, &[], &[]).is_empty());
        assert!(select(&TemporalPolicy::OnePerStep, 1.0, &[], &[]).is_empty());
    }

    #[test]
    fn dynamic_tau_decays_with_commits() {
        let p = TemporalPolicy::DynamicTau { tau0: 0.9, alpha: 0.3 };
        // fully masked block → τ = τ0
        assert!((p.threshold(1.0) - 0.9).abs() < 1e-6);
        // mostly committed block → lower threshold
        assert!(p.threshold(0.25) < 0.9);
        // monotone in r_mask
        assert!(p.threshold(0.5) <= p.threshold(0.9));
    }

    #[test]
    fn fixed_tau_threshold_constant() {
        let p = fixed(0.9);
        assert_eq!(p.threshold(1.0), p.threshold(0.1));
        assert_eq!(TemporalPolicy::OnePerStep.threshold(0.3), 1.0);
    }

    #[test]
    fn extrapolating_commits_on_converging_trend() {
        let p = TemporalPolicy::Extrapolating {
            tau0: 0.9,
            alpha: 0.0,
            gain: 1.0,
            floor: 0.7,
            min_streak: 2,
        };
        // the decoy clears τ = 0.9 so the argmax fallback never masks a
        // negative case below
        let decoy = cand(0, 0.95);

        // rising, stable, above floor: 0.8 + 1.0·(0.8 − 0.5) ≥ 1.0 → commits
        let rising = [decoy, cand(1, 0.8)];
        assert_eq!(select(&p, 1.0, &rising, &[Trend::default(), trend(0.5, 2)]), vec![0, 1]);
        // streak too short → no extrapolation
        assert_eq!(select(&p, 1.0, &rising, &[Trend::default(), trend(0.5, 1)]), vec![0]);
        // falling confidence → trend never reaches 1.0
        assert_eq!(select(&p, 1.0, &rising, &[Trend::default(), trend(0.9, 5)]), vec![0]);
        // below the floor → rejected even with a steep trend
        let low = [decoy, cand(1, 0.6)];
        assert_eq!(select(&p, 1.0, &low, &[Trend::default(), trend(0.1, 5)]), vec![0]);
        // no trend info at all → base threshold rule only
        assert_eq!(select(&p, 1.0, &rising, &[]), vec![0]);
    }

    fn trend(prev_conf: f32, streak: u32) -> Trend {
        Trend { prev_conf, streak }
    }

    #[test]
    fn prop_extrapolating_floor_one_matches_dynamic_tau() {
        // the "extrapolating" preset sets floor = 1.0: the extra clause
        // needs conf ≥ 1.0, which the base rule already commits (τ ≤ τ0
        // < 1 when τ0 < 1) — so the commit set equals DynamicTau's for
        // every input. This is what makes the preset a provable tie.
        prop::check(300, |g| {
            let tau0 = g.f32(0.3, 0.99);
            let alpha = g.f32(0.0, 0.9);
            let ext = TemporalPolicy::Extrapolating {
                tau0,
                alpha,
                gain: g.f32(0.0, 4.0),
                floor: 1.0,
                min_streak: g.usize(0, 3) as u32,
            };
            let dyn_tau = TemporalPolicy::DynamicTau { tau0, alpha };
            let n = g.usize(1, 16);
            let cands: Vec<Candidate> = (0..n).map(|i| cand(i, g.f32(0.0, 1.0))).collect();
            let trends: Vec<Trend> =
                (0..n).map(|_| trend(g.f32(0.0, 1.0), g.usize(0, 5) as u32)).collect();
            let r = g.f32(0.0, 1.0);
            if select(&ext, r, &cands, &trends) != select(&dyn_tau, r, &cands, &[]) {
                return Err("floor=1.0 extrapolation diverged from dynamic τ".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_progress_guarantee_every_temporal_policy() {
        prop::check(400, |g| {
            let tau0 = g.f32(0.0, 1.0);
            let policy = match g.usize(0, 3) {
                0 => TemporalPolicy::OnePerStep,
                1 => TemporalPolicy::FixedTau { tau: tau0 },
                2 => TemporalPolicy::DynamicTau { tau0, alpha: g.f32(0.0, 1.0) },
                _ => TemporalPolicy::Extrapolating {
                    tau0,
                    alpha: g.f32(0.0, 1.0),
                    gain: g.f32(0.0, 4.0),
                    floor: g.f32(0.0, 1.0),
                    min_streak: g.usize(0, 4) as u32,
                },
            };
            let n = g.usize(1, 20);
            let cands: Vec<Candidate> = (0..n).map(|i| cand(i, g.f32(0.0, 1.0))).collect();
            let trends: Vec<Trend> =
                (0..n).map(|_| trend(g.f32(0.0, 1.0), g.usize(0, 5) as u32)).collect();
            let r = g.f32(0.0, 1.0);
            let sel = select(&policy, r, &cands, &trends);
            if sel.is_empty() {
                return Err("no progress".into());
            }
            // threshold family: everything ≥ τ(r) must be selected
            if policy.is_parallel() {
                let tau = policy.threshold(r);
                for (i, c) in cands.iter().enumerate() {
                    if c.conf >= tau && !sel.contains(&i) {
                        return Err(format!("candidate {i} above tau but unselected"));
                    }
                }
            }
            // selection indices must be unique and in-range
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != sel.len() || sel.iter().any(|&i| i >= n) {
                return Err("bad indices".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_one_per_step_always_single_max() {
        prop::check(300, |g| {
            let n = g.usize(1, 32);
            let cands: Vec<Candidate> = (0..n).map(|i| cand(i, g.f32(0.0, 1.0))).collect();
            let sel = select(&TemporalPolicy::OnePerStep, g.f32(0.0, 1.0), &cands, &[]);
            if sel.len() != 1 {
                return Err(format!("expected 1, got {}", sel.len()));
            }
            // max under the IEEE total order (NaN-safe, unlike a
            // fold(f32::MIN, f32::max) which a stray NaN poisons)
            let max = cands.iter().map(|c| c.conf).max_by(f32::total_cmp).unwrap();
            if (cands[sel[0]].conf - max).abs() > 1e-9 {
                return Err("not the argmax".into());
            }
            Ok(())
        });
    }

    #[test]
    fn nan_confidence_never_panics_or_escapes_bounds() {
        // a backend bug emitting NaN must not panic selection or return
        // out-of-range indices, for every temporal policy. Under
        // total_cmp NaN sorts above +inf, so the argmax paths pick it
        // deterministically instead of degenerating to index 0.
        let policies = [
            TemporalPolicy::OnePerStep,
            fixed(0.9),
            TemporalPolicy::DynamicTau { tau0: 0.9, alpha: 0.3 },
            TemporalPolicy::Extrapolating {
                tau0: 0.9,
                alpha: 0.3,
                gain: 1.0,
                floor: 0.5,
                min_streak: 1,
            },
        ];
        let cands = [cand(0, 0.2), cand(1, f32::NAN), cand(2, 0.4)];
        let conf: Vec<f32> = cands.iter().map(|c| c.conf).collect();
        let trends = [trend(0.1, 3), trend(0.1, 3), trend(0.1, 3)];
        for p in policies {
            let mut out = Vec::new();
            select_into(&p, 1.0, &cands, &trends, &mut out);
            assert!(!out.is_empty(), "{p:?}: progress guarantee broken by NaN");
            assert!(out.iter().all(|&i| i < cands.len()), "{p:?}: bad index");
            // NaN is below every threshold (>= compares false) but wins
            // any argmax fallback under the total order
            let mut soa = Vec::new();
            select_soa(&p, 1.0, &conf, &trends, &mut soa);
            assert_eq!(out, soa, "{p:?}: SoA diverged from scalar on NaN input");
        }
        // pure-NaN input: argmax fallback must still make progress
        let all_nan = [cand(0, f32::NAN), cand(1, f32::NAN)];
        assert_eq!(select(&fixed(0.5), 1.0, &all_nan, &[]), vec![0]);
    }

    #[test]
    fn prop_select_soa_matches_select_into() {
        // the chunked SoA kernels must be bit-identical to the scalar
        // AoS reference across the whole policy space, including sizes
        // around the 8-lane chunk boundary
        prop::check(600, |g| {
            let tau0 = g.f32(0.0, 1.0);
            let policy = match g.usize(0, 3) {
                0 => TemporalPolicy::OnePerStep,
                1 => TemporalPolicy::FixedTau { tau: tau0 },
                2 => TemporalPolicy::DynamicTau { tau0, alpha: g.f32(0.0, 1.0) },
                _ => TemporalPolicy::Extrapolating {
                    tau0,
                    alpha: g.f32(0.0, 1.0),
                    gain: g.f32(0.0, 4.0),
                    floor: g.f32(0.0, 1.0),
                    min_streak: g.usize(0, 4) as u32,
                },
            };
            let n = g.usize(1, 40);
            let cands: Vec<Candidate> = (0..n).map(|i| cand(i, g.f32(0.0, 1.0))).collect();
            let conf: Vec<f32> = cands.iter().map(|c| c.conf).collect();
            let trends: Vec<Trend> =
                (0..n).map(|_| trend(g.f32(0.0, 1.0), g.usize(0, 5) as u32)).collect();
            let r = g.f32(0.0, 1.0);
            let scalar = select(&policy, r, &cands, &trends);
            let mut soa = Vec::new();
            select_soa(&policy, r, &conf, &trends, &mut soa);
            if scalar != soa {
                return Err(format!("SoA {soa:?} != scalar {scalar:?} for {policy:?}"));
            }
            if argmax_conf(&conf) != argmax(&cands) {
                return Err("argmax_conf diverged from scalar argmax".into());
            }
            Ok(())
        });
    }

    #[test]
    fn argmax_conf_first_max_wins_across_chunks() {
        // ties resolve to the earliest index, even when the tie spans
        // the 8-lane chunk boundary
        let mut conf = vec![0.25f32; 20];
        conf[3] = 0.9;
        conf[11] = 0.9;
        conf[19] = 0.9;
        assert_eq!(argmax_conf(&conf), 3);
        assert_eq!(argmax_conf(&[0.5]), 0);
        assert_eq!(argmax_conf(&vec![0.5f32; 8]), 0);
    }

    #[test]
    fn method_presets_match_legacy_schedules() {
        for m in [Method::Vanilla, Method::DkvCache, Method::PrefixCache] {
            let p = DecodePolicy::for_method(m);
            assert_eq!(p.spatial, SpatialPolicy::FullSuffix);
            assert_eq!(p.temporal, TemporalPolicy::OnePerStep);
        }
        let fast = DecodePolicy::for_method(Method::FastDllm);
        assert_eq!(fast.temporal, TemporalPolicy::FixedTau { tau: 0.9 });
        assert!(!fast.spatial.is_pruning());
        let s = DecodePolicy::for_method(Method::Streaming);
        assert_eq!(s.spatial, SpatialPolicy::Window { window: 24, trailing: true });
        assert_eq!(s.temporal, TemporalPolicy::DynamicTau { tau0: 0.9, alpha: 0.3 });
    }

    #[test]
    fn preset_parse_name_roundtrip() {
        for name in DecodePolicy::preset_names() {
            let p = DecodePolicy::parse(name).expect(name);
            p.validate().unwrap();
            let canon = p.name().expect("preset must resolve to a name");
            assert_eq!(DecodePolicy::parse(canon), Some(p), "{name} → {canon}");
        }
        assert_eq!(DecodePolicy::parse("nope"), None);
    }

    #[test]
    fn equal_policies_hash_equal() {
        fn h(p: &DecodePolicy) -> u64 {
            let mut s = DefaultHasher::new();
            p.hash(&mut s);
            s.finish()
        }
        for (_, p) in DecodePolicy::presets() {
            let copy = p;
            assert_eq!(h(&p), h(&copy));
        }
        let a = DecodePolicy::parse("streaming").unwrap();
        let b = DecodePolicy::parse("attenuating").unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn attenuated_window_shrinks_monotonically() {
        let n_blocks = 8;
        let mut prev = attenuated_window(24, 8, 0, n_blocks);
        assert_eq!(prev, 24);
        for b in 1..n_blocks {
            let w = attenuated_window(24, 8, b, n_blocks);
            assert!(w <= prev, "block {b}: {w} > {prev}");
            assert!(w >= 8);
            prev = w;
        }
        assert_eq!(prev, 8);
        // degenerate shapes stay sane
        assert_eq!(attenuated_window(24, 8, 0, 1), 24);
        assert_eq!(attenuated_window(8, 8, 3, 8), 8);
        assert_eq!(attenuated_window(8, 24, 7, 8), 8); // min > window clamps
    }

    #[test]
    fn dropout_survivor_is_deterministic_and_bounded() {
        for chunk in 0..32 {
            let a = dropout_survivor(0xD9AD, chunk, 4);
            assert_eq!(a, dropout_survivor(0xD9AD, chunk, 4));
            assert!(a < 4);
        }
        assert_eq!(dropout_survivor(1, 0, 1), 0);
    }

    #[test]
    fn max_bundle_len_bounds() {
        assert_eq!(SpatialPolicy::FullSuffix.max_bundle_len(8, 64), 64);
        assert_eq!(SpatialPolicy::preset_window().max_bundle_len(8, 64), 33);
        assert_eq!(SpatialPolicy::preset_window().max_bundle_len(8, 16), 16);
        let att = SpatialPolicy::Attenuating { window: 24, min_window: 8, trailing: true };
        assert_eq!(att.max_bundle_len(8, 64), 33);
        let drop = SpatialPolicy::Dropout { window: 8, stride: 4, seed: 1, trailing: true };
        // 8 + 8 + ceil(48/4) + 1 = 29
        assert_eq!(drop.max_bundle_len(8, 64), 29);
    }

    #[test]
    fn invalid_policies_rejected() {
        let bad_tau = DecodePolicy {
            spatial: SpatialPolicy::FullSuffix,
            temporal: TemporalPolicy::FixedTau { tau: 1.5 },
        };
        assert!(bad_tau.validate().is_err());
        let bad_att = DecodePolicy {
            spatial: SpatialPolicy::Attenuating { window: 4, min_window: 9, trailing: true },
            temporal: TemporalPolicy::OnePerStep,
        };
        assert!(bad_att.validate().is_err());
        let bad_stride = DecodePolicy {
            spatial: SpatialPolicy::Dropout { window: 4, stride: 0, seed: 1, trailing: false },
            temporal: TemporalPolicy::OnePerStep,
        };
        assert!(bad_stride.validate().is_err());
        let bad_gain = DecodePolicy {
            spatial: SpatialPolicy::FullSuffix,
            temporal: TemporalPolicy::Extrapolating {
                tau0: 0.9,
                alpha: 0.3,
                gain: -1.0,
                floor: 0.5,
                min_streak: 1,
            },
        };
        assert!(bad_gain.validate().is_err());
    }
}
