//! Cross-request radix prefix cache: reusable prefill state keyed on
//! prompt token prefixes.
//!
//! At fleet scale the same system prompts and few-shot templates arrive
//! over and over; prefill recomputes them per request. This cache stores
//! backend-opaque [`PrefixCapture`]s (see `Backend::capture_prefix`) in
//! a radix tree over prompt tokens, so a warm request restores the
//! shared-prefix state instead of recomputing it, and requests whose
//! prompts diverge only in the tail still share the template part
//! (partial hits walk to the divergence point and borrow a descendant
//! capture that covers it).
//!
//! Structure: an arena of nodes, each holding a compressed token edge,
//! child indices, an optional entry (capture + byte cost + LRU stamp),
//! and a subtree entry count used as the refcount for pruning. Roots
//! are per-*scope*: captures are only reusable within the same decode
//! configuration and backend identity (method × policy ×
//! `Backend::prefix_scope`), so a causal capture never leaks into a toy
//! decode and policy groups stay isolated — the same compatibility rule
//! as the batcher's `GroupKey`.
//!
//! Eviction is LRU over entries under a byte budget (and a derived node
//! budget); an entry whose capture is still held by a live row
//! (`Arc::strong_count > 1`) is pinned and skipped. Subtrees that lose
//! their last entry are pruned via parent links.
//!
//! **Bit-identity is the backend's contract, not the cache's**: a
//! capture only shortens how a backend computes prefill, never what it
//! computes. The parity suite pins warm == cold output bytes for both
//! reference modes.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};

use super::backend::{Backend, PrefixCapture};
use super::config::GenConfig;

/// Shortest prefix worth caching: captures below this carry less state
/// than the bookkeeping around them.
pub const MIN_CACHE_PREFIX: usize = 4;

/// Estimated bytes per cached token (capture payload + node overhead) —
/// the unit the byte budget is accounted in.
const BYTES_PER_TOKEN: usize = 64;

const NO_NODE: u32 = u32::MAX;

/// Hit/miss/eviction accounting plus the savings estimate, snapshotted
/// into the router's metrics.
#[derive(Debug, Default, Clone)]
pub struct PrefixCacheStats {
    pub lookups: u64,
    /// full hit: the cached prefix covers the whole prompt
    pub hits: u64,
    /// partial hit: a shorter (but usable) prefix was found
    pub partial_hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// current accounted bytes / arena nodes / live entries
    pub bytes: u64,
    pub nodes: u64,
    pub entries: u64,
    /// prompt tokens served from cache instead of recomputed
    pub reused_tokens: u64,
    /// estimated prefill seconds avoided (reused tokens × the EWMA
    /// observed secs-per-prefilled-token)
    pub saved_prefill_secs: f64,
}

/// A successful lookup: how many leading prompt tokens the capture
/// covers, and the capture itself.
#[derive(Clone)]
pub struct PrefixHit {
    pub len: usize,
    pub capture: PrefixCapture,
}

struct Entry {
    capture: PrefixCapture,
    /// full key length (root-to-here token count) this entry covers
    key_len: usize,
    /// accounted byte cost
    bytes: usize,
    /// logical LRU clock stamp (monotonic per cache)
    last_use: u64,
}

impl std::fmt::Debug for Entry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Entry")
            .field("key_len", &self.key_len)
            .field("bytes", &self.bytes)
            .field("last_use", &self.last_use)
            .finish()
    }
}

impl std::fmt::Debug for PrefixHit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefixHit").field("len", &self.len).finish()
    }
}

#[derive(Debug, Default)]
struct Node {
    /// compressed token run on the edge from the parent to this node
    edge: Vec<i32>,
    /// child node indices (linear scan; fanout is tiny in practice)
    children: Vec<u32>,
    entry: Option<Entry>,
    /// entries in this node's subtree (including its own) — the
    /// refcount that keeps a chain of internal nodes alive
    refs: u32,
    parent: u32,
}

/// The radix tree plus budget/stats. Not shared directly — wrap it in
/// [`SharedPrefixCache`] for cross-thread use.
#[derive(Debug)]
pub struct PrefixCache {
    nodes: Vec<Node>,
    free: Vec<u32>,
    /// per-scope root nodes (scope = method × policy × backend scope)
    roots: Vec<(u64, u32)>,
    max_bytes: usize,
    clock: u64,
    stats: PrefixCacheStats,
    /// EWMA of observed prefill secs per computed token — converts
    /// reused tokens into an honest "seconds saved" estimate
    secs_per_token: f64,
}

impl PrefixCache {
    pub fn new(max_bytes: usize) -> PrefixCache {
        PrefixCache {
            nodes: vec![],
            free: vec![],
            roots: vec![],
            max_bytes,
            clock: 0,
            stats: PrefixCacheStats::default(),
            secs_per_token: 0.0,
        }
    }

    fn alloc(&mut self, node: Node) -> u32 {
        match self.free.pop() {
            Some(ix) => {
                self.nodes[ix as usize] = node;
                ix
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        }
    }

    fn root_for(&mut self, scope: u64) -> u32 {
        if let Some(&(_, ix)) = self.roots.iter().find(|&&(s, _)| s == scope) {
            return ix;
        }
        let ix = self.alloc(Node { parent: NO_NODE, ..Node::default() });
        self.roots.push((scope, ix));
        ix
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest usable cached prefix of `prompt` under `scope`. Walks the
    /// tree tracking the deepest entry on the matched path; if the walk
    /// ends mid-tree with no on-path entry, any entry in the reached
    /// subtree still covers the matched part (entries live at
    /// full-prompt depths; shared templates are internal nodes), so a
    /// descendant representative is returned clamped to the matched
    /// length. Returns `None` on a cold miss or when the best match is
    /// shorter than [`MIN_CACHE_PREFIX`].
    pub fn lookup(&mut self, scope: u64, prompt: &[i32]) -> Option<PrefixHit> {
        self.stats.lookups += 1;
        let found = self.lookup_inner(scope, prompt);
        match &found {
            Some(hit) if hit.len >= prompt.len() => self.stats.hits += 1,
            Some(_) => self.stats.partial_hits += 1,
            None => self.stats.misses += 1,
        }
        found
    }

    fn lookup_inner(&mut self, scope: u64, prompt: &[i32]) -> Option<PrefixHit> {
        let root = self.roots.iter().find(|&&(s, _)| s == scope).map(|&(_, ix)| ix)?;
        let mut at = root;
        let mut matched = 0usize;
        // deepest entry whose key is a full prefix of `prompt`
        let mut best: Option<(u32, usize)> = None;
        loop {
            if let Some(e) = &self.nodes[at as usize].entry {
                debug_assert_eq!(e.key_len, matched);
                best = Some((at, matched));
            }
            if matched == prompt.len() {
                break;
            }
            let next = self.nodes[at as usize]
                .children
                .iter()
                .copied()
                .find(|&c| self.nodes[c as usize].edge.first() == Some(&prompt[matched]));
            let Some(child) = next else { break };
            let edge_len = self.nodes[child as usize].edge.len();
            let common = self.nodes[child as usize]
                .edge
                .iter()
                .zip(&prompt[matched..])
                .take_while(|(a, b)| a == b)
                .count();
            matched += common;
            if common < edge_len {
                // diverged mid-edge: the subtree below still shares the
                // matched part — borrow a descendant entry for it
                if best.map(|(_, l)| l).unwrap_or(0) < matched {
                    if let Some(d) = self.subtree_entry(child) {
                        best = Some((d, matched));
                    }
                }
                break;
            }
            at = child;
        }
        // ran out of tree with prompt left over: entries below `at`
        // (if any) cover everything matched so far
        if matched < prompt.len() && best.map(|(_, l)| l).unwrap_or(0) < matched {
            if let Some(d) = self.subtree_entry(at) {
                best = Some((d, matched));
            }
        }
        let (node, len) = best?;
        if len < MIN_CACHE_PREFIX {
            return None;
        }
        let stamp = self.tick();
        let e = self.nodes[node as usize].entry.as_mut().expect("best node carries an entry");
        e.last_use = stamp;
        self.stats.reused_tokens += len as u64;
        self.stats.saved_prefill_secs += len as f64 * self.secs_per_token;
        Some(PrefixHit { len, capture: e.capture.clone() })
    }

    /// Any entry-bearing node in `node`'s subtree (itself included).
    fn subtree_entry(&self, node: u32) -> Option<u32> {
        if self.nodes[node as usize].refs == 0 {
            return None;
        }
        let mut stack = vec![node];
        while let Some(at) = stack.pop() {
            if self.nodes[at as usize].entry.is_some() {
                return Some(at);
            }
            stack.extend(self.nodes[at as usize].children.iter().copied());
        }
        None
    }

    /// Insert a capture for the full `key` under `scope`, splitting
    /// edges as needed. Replaces an existing entry at the same key.
    /// No-op for keys shorter than [`MIN_CACHE_PREFIX`] or when the
    /// cache is disabled (`max_bytes == 0`).
    pub fn insert(&mut self, scope: u64, key: &[i32], capture: PrefixCapture) {
        if self.max_bytes == 0 || key.len() < MIN_CACHE_PREFIX {
            return;
        }
        let root = self.root_for(scope);
        let mut at = root;
        let mut depth = 0usize;
        while depth < key.len() {
            let next = self.nodes[at as usize]
                .children
                .iter()
                .copied()
                .find(|&c| self.nodes[c as usize].edge.first() == Some(&key[depth]));
            match next {
                None => {
                    // new leaf carries the whole remaining run
                    let leaf = self.alloc(Node {
                        edge: key[depth..].to_vec(),
                        parent: at,
                        ..Node::default()
                    });
                    self.nodes[at as usize].children.push(leaf);
                    at = leaf;
                    depth = key.len();
                }
                Some(child) => {
                    let common = self.nodes[child as usize]
                        .edge
                        .iter()
                        .zip(&key[depth..])
                        .take_while(|(a, b)| a == b)
                        .count();
                    if common < self.nodes[child as usize].edge.len() {
                        // classic radix split: child keeps the tail,
                        // a new internal node takes the shared head
                        let tail = self.nodes[child as usize].edge.split_off(common);
                        let head = std::mem::take(&mut self.nodes[child as usize].edge);
                        let refs = self.nodes[child as usize].refs;
                        let mid = self.alloc(Node {
                            edge: head,
                            children: vec![child],
                            refs,
                            parent: at,
                            ..Node::default()
                        });
                        self.nodes[child as usize].edge = tail;
                        self.nodes[child as usize].parent = mid;
                        let slot = self.nodes[at as usize]
                            .children
                            .iter()
                            .position(|&c| c == child)
                            .expect("child listed under parent");
                        self.nodes[at as usize].children[slot] = mid;
                        at = mid;
                    } else {
                        at = child;
                    }
                    depth += common;
                }
            }
        }
        let bytes = key.len() * BYTES_PER_TOKEN;
        let stamp = self.tick();
        let old = self.nodes[at as usize].entry.replace(Entry {
            capture,
            key_len: key.len(),
            bytes,
            last_use: stamp,
        });
        self.stats.inserts += 1;
        self.stats.bytes += bytes as u64;
        match old {
            Some(e) => self.stats.bytes -= e.bytes as u64,
            None => {
                self.stats.entries += 1;
                self.bump_refs(at, 1);
            }
        }
        self.evict_to_budget();
        self.stats.nodes = self.live_nodes() as u64;
    }

    fn bump_refs(&mut self, mut at: u32, delta: i64) {
        loop {
            let r = &mut self.nodes[at as usize].refs;
            *r = (*r as i64 + delta) as u32;
            let parent = self.nodes[at as usize].parent;
            if parent == NO_NODE {
                break;
            }
            at = parent;
        }
    }

    /// LRU-evict unpinned entries until accounted bytes fit the budget.
    /// A pinned entry (its capture `Arc` is held outside the cache —
    /// i.e. some live row is decoding on it) is skipped.
    fn evict_to_budget(&mut self) {
        while self.stats.bytes > self.max_bytes as u64 {
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(ix, n)| {
                    let e = n.entry.as_ref()?;
                    (Arc::strong_count(&e.capture) == 1).then_some((ix as u32, e.last_use))
                })
                .min_by_key(|&(_, stamp)| stamp);
            let Some((ix, _)) = victim else { break };
            self.remove_entry(ix);
            self.stats.evictions += 1;
        }
    }

    fn remove_entry(&mut self, ix: u32) {
        let e = self.nodes[ix as usize].entry.take().expect("victim carries an entry");
        self.stats.bytes -= e.bytes as u64;
        self.stats.entries -= 1;
        self.bump_refs(ix, -1);
        self.prune(ix);
    }

    /// Free `ix` and its now-entryless ancestors while their subtrees
    /// hold no entries (refs == 0). Roots stay allocated.
    fn prune(&mut self, mut ix: u32) {
        loop {
            let n = &self.nodes[ix as usize];
            if n.refs > 0 || n.entry.is_some() || n.parent == NO_NODE || !n.children.is_empty() {
                break;
            }
            let parent = n.parent;
            let p = &mut self.nodes[parent as usize];
            p.children.retain(|&c| c != ix);
            self.nodes[ix as usize] = Node::default();
            self.free.push(ix);
            ix = parent;
        }
    }

    fn live_nodes(&self) -> usize {
        self.nodes.len() - self.free.len()
    }

    /// Fold one observed prefill timing into the EWMA secs-per-token
    /// model behind `saved_prefill_secs`.
    pub fn note_prefill(&mut self, secs: f64, computed_tokens: usize) {
        if computed_tokens == 0 || secs <= 0.0 {
            return;
        }
        let per = secs / computed_tokens as f64;
        self.secs_per_token =
            if self.secs_per_token == 0.0 { per } else { 0.9 * self.secs_per_token + 0.1 * per };
    }

    pub fn stats(&self) -> PrefixCacheStats {
        self.stats.clone()
    }

    /// Structural invariants, exercised by the unit/stress suites:
    /// subtree refcounts equal live entry counts, every child's parent
    /// link points back, entry key lengths equal their root distance,
    /// and accounted bytes match the live entries.
    pub fn check_invariants(&self) {
        let mut bytes = 0u64;
        let mut entries = 0u64;
        for &(_, root) in &self.roots {
            self.check_node(root, 0, &mut bytes, &mut entries);
        }
        assert_eq!(bytes, self.stats.bytes, "accounted bytes diverged from live entries");
        assert_eq!(entries, self.stats.entries, "entry count diverged");
    }

    fn check_node(&self, ix: u32, depth: usize, bytes: &mut u64, entries: &mut u64) -> u32 {
        let n = &self.nodes[ix as usize];
        let depth = depth + n.edge.len();
        let mut refs = 0u32;
        if let Some(e) = &n.entry {
            assert_eq!(e.key_len, depth, "entry key length != root distance");
            *bytes += e.bytes as u64;
            *entries += 1;
            refs += 1;
        }
        for &c in &n.children {
            assert_eq!(self.nodes[c as usize].parent, ix, "child parent link broken");
            assert!(!self.nodes[c as usize].edge.is_empty(), "empty child edge");
            refs += self.check_node(c, depth, bytes, entries);
        }
        assert_eq!(n.refs, refs, "subtree refcount diverged at node {ix}");
        refs
    }
}

/// Thread-safe handle: the router owns one cache shared by every worker
/// thread (captures outlive engine retirements, so a re-spawned engine
/// still serves warm).
#[derive(Debug, Clone)]
pub struct SharedPrefixCache {
    inner: Arc<Mutex<PrefixCache>>,
}

impl SharedPrefixCache {
    pub fn new(max_bytes: usize) -> SharedPrefixCache {
        SharedPrefixCache { inner: Arc::new(Mutex::new(PrefixCache::new(max_bytes))) }
    }

    pub fn lookup(&self, scope: u64, prompt: &[i32]) -> Option<PrefixHit> {
        self.inner.lock().unwrap().lookup(scope, prompt)
    }

    pub fn insert(&self, scope: u64, key: &[i32], capture: PrefixCapture) {
        self.inner.lock().unwrap().insert(scope, key, capture)
    }

    pub fn note_prefill(&self, secs: f64, computed_tokens: usize) {
        self.inner.lock().unwrap().note_prefill(secs, computed_tokens)
    }

    pub fn stats(&self) -> PrefixCacheStats {
        self.inner.lock().unwrap().stats()
    }

    pub fn check_invariants(&self) {
        self.inner.lock().unwrap().check_invariants()
    }
}

/// An engine's view of the shared cache: the cache handle plus the
/// pre-computed scope for this engine's (method, policy, backend)
/// configuration — computed once at `set_prefix_cache` so the per-row
/// hot path only hashes prompts, not configs.
#[derive(Debug, Clone)]
pub struct PrefixHandle {
    pub cache: SharedPrefixCache,
    pub scope: u64,
}

/// Cache scope for a decode configuration on a backend: method × policy
/// (the batcher's `GroupKey` axes) × the backend's own identity
/// discriminant (`Backend::prefix_scope`: mode + seed for the reference
/// model). Two engines share captures iff their scopes are equal.
pub fn prefix_scope_for<B: Backend>(rt: &B, cfg: &GenConfig) -> u64 {
    let mut h = DefaultHasher::new();
    cfg.method.hash(&mut h);
    cfg.policy.hash(&mut h);
    h.finish() ^ rt.prefix_scope()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cap(tag: u64) -> PrefixCapture {
        Arc::new(tag)
    }

    fn hit_len(c: &mut PrefixCache, scope: u64, prompt: &[i32]) -> Option<usize> {
        c.lookup(scope, prompt).map(|h| h.len)
    }

    #[test]
    fn insert_then_full_hit_roundtrip() {
        let mut c = PrefixCache::new(1 << 20);
        let key = [2, 10, 11, 12, 13, 14];
        c.insert(7, &key, cap(1));
        c.check_invariants();
        let hit = c.lookup(7, &key).expect("full hit");
        assert_eq!(hit.len, key.len());
        assert_eq!(*hit.capture.downcast_ref::<u64>().unwrap(), 1);
        let s = c.stats();
        assert_eq!((s.hits, s.partial_hits, s.misses), (1, 0, 0));
    }

    #[test]
    fn scopes_are_isolated() {
        let mut c = PrefixCache::new(1 << 20);
        let key = [2, 10, 11, 12, 13];
        c.insert(1, &key, cap(1));
        assert!(c.lookup(2, &key).is_none(), "wrong scope must miss");
        assert!(c.lookup(1, &key).is_some());
        c.check_invariants();
    }

    #[test]
    fn shared_template_splits_and_partial_hits() {
        let mut c = PrefixCache::new(1 << 20);
        // two prompts sharing an 8-token template, diverging in the tail
        let a = [2, 5, 6, 7, 8, 9, 10, 11, 30, 31];
        let b = [2, 5, 6, 7, 8, 9, 10, 11, 40, 41, 42];
        c.insert(0, &a, cap(1));
        c.insert(0, &b, cap(2));
        c.check_invariants();
        // a third prompt with the same template but a fresh tail:
        // partial hit covering exactly the shared 8 tokens
        let q = [2, 5, 6, 7, 8, 9, 10, 11, 50];
        let hit = c.lookup(0, &q).expect("template part must hit");
        assert_eq!(hit.len, 8);
        let s = c.stats();
        assert_eq!(s.partial_hits, 1);
        // both originals still full-hit
        assert_eq!(hit_len(&mut c, 0, &a), Some(a.len()));
        assert_eq!(hit_len(&mut c, 0, &b), Some(b.len()));
    }

    #[test]
    fn prefix_of_an_entry_partial_hits_via_descendant() {
        let mut c = PrefixCache::new(1 << 20);
        let long = [2, 5, 6, 7, 8, 9, 10, 11];
        c.insert(0, &long, cap(1));
        // a query that is a strict prefix of the stored key: the stored
        // (longer) capture covers the whole query prefix
        let hit = c.lookup(0, &long[..6]).expect("prefix query must hit");
        assert_eq!(hit.len, 6);
        assert_eq!(c.stats().hits, 1, "covers the whole prompt → full hit");
    }

    #[test]
    fn short_prefixes_are_not_cached_or_served() {
        let mut c = PrefixCache::new(1 << 20);
        c.insert(0, &[2, 5], cap(1)); // below MIN_CACHE_PREFIX → dropped
        assert_eq!(c.stats().inserts, 0);
        let key = [2, 5, 6, 7, 8];
        c.insert(0, &key, cap(2));
        // matched part shorter than the floor → miss, not a 2-token hit
        assert!(c.lookup(0, &[2, 5, 9, 9, 9]).is_none());
        c.check_invariants();
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        // budget for ~2 entries of 8 tokens each
        let mut c = PrefixCache::new(2 * 8 * BYTES_PER_TOKEN);
        let k1 = [1, 5, 6, 7, 8, 9, 10, 11];
        let k2 = [2, 5, 6, 7, 8, 9, 10, 11];
        let k3 = [3, 5, 6, 7, 8, 9, 10, 11];
        c.insert(0, &k1, cap(1));
        c.insert(0, &k2, cap(2));
        assert_eq!(c.stats().evictions, 0);
        // touch k1 so k2 becomes the LRU victim
        assert!(c.lookup(0, &k1).is_some());
        c.insert(0, &k3, cap(3));
        c.check_invariants();
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 2 * 8 * BYTES_PER_TOKEN as u64);
        assert!(c.lookup(0, &k1).is_some(), "recently-used entry must survive");
        assert!(c.lookup(0, &k2).is_none(), "LRU entry must be evicted");
        assert!(c.lookup(0, &k3).is_some());
    }

    #[test]
    fn pinned_entries_survive_eviction() {
        let mut c = PrefixCache::new(8 * BYTES_PER_TOKEN);
        let k1 = [1, 5, 6, 7, 8, 9, 10, 11];
        let k2 = [2, 5, 6, 7, 8, 9, 10, 11];
        c.insert(0, &k1, cap(1));
        // hold the capture like a live row would
        let pinned = c.lookup(0, &k1).unwrap().capture;
        c.insert(0, &k2, cap(2));
        c.check_invariants();
        assert!(c.lookup(0, &k1).is_some(), "pinned entry must not be evicted");
        drop(pinned);
        // now k1 is evictable; the next insert pushes it out
        let k3 = [3, 5, 6, 7, 8, 9, 10, 11];
        c.insert(0, &k3, cap(3));
        assert!(c.stats().bytes <= 8 * BYTES_PER_TOKEN as u64);
        c.check_invariants();
    }

    #[test]
    fn eviction_prunes_split_chains() {
        let mut c = PrefixCache::new(1 << 20);
        let a = [2, 5, 6, 7, 8, 9];
        let b = [2, 5, 6, 7, 20, 21];
        c.insert(0, &a, cap(1));
        c.insert(0, &b, cap(2)); // splits the edge → internal node
        let full = c.live_nodes();
        // remove both entries via the internal API and check the tree
        // collapses back to just the root
        let victims: Vec<u32> = c
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.entry.is_some())
            .map(|(ix, _)| ix as u32)
            .collect();
        for v in victims {
            c.remove_entry(v);
        }
        c.check_invariants();
        assert!(c.live_nodes() < full);
        assert_eq!(c.live_nodes(), 1, "only the scope root survives");
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn zero_budget_disables_inserts() {
        let mut c = PrefixCache::new(0);
        c.insert(0, &[2, 5, 6, 7, 8, 9], cap(1));
        assert_eq!(c.stats().inserts, 0);
        assert!(c.lookup(0, &[2, 5, 6, 7, 8, 9]).is_none());
    }

    #[test]
    fn saved_seconds_track_reuse() {
        let mut c = PrefixCache::new(1 << 20);
        c.note_prefill(0.010, 100); // 100µs/token
        let key = [2, 5, 6, 7, 8, 9, 10, 11, 12, 13];
        c.insert(0, &key, cap(1));
        assert!(c.lookup(0, &key).is_some());
        let s = c.stats();
        assert_eq!(s.reused_tokens, key.len() as u64);
        assert!(s.saved_prefill_secs > 0.0);
        assert!((s.saved_prefill_secs - key.len() as f64 * 1e-4).abs() < 1e-9);
    }

    #[test]
    fn shared_handle_and_scope_separation() {
        let shared = SharedPrefixCache::new(1 << 20);
        let be = crate::engine::ReferenceBackend::toy(crate::engine::REFERENCE_SEED);
        let cfg_a = GenConfig::preset(crate::engine::Method::Streaming, 64);
        let cfg_b = GenConfig::preset(crate::engine::Method::Vanilla, 64);
        let sa = prefix_scope_for(&be, &cfg_a);
        let sb = prefix_scope_for(&be, &cfg_b);
        assert_ne!(sa, sb, "different methods must not share captures");
        let causal = crate::engine::ReferenceBackend::causal(crate::engine::REFERENCE_SEED);
        assert_ne!(
            prefix_scope_for(&be, &cfg_a),
            prefix_scope_for(&causal, &cfg_a),
            "different backend modes must not share captures"
        );
        shared.insert(sa, &[2, 5, 6, 7, 8], cap(9));
        assert!(shared.lookup(sa, &[2, 5, 6, 7, 8]).is_some());
        assert!(shared.lookup(sb, &[2, 5, 6, 7, 8]).is_none());
        shared.check_invariants();
    }
}
