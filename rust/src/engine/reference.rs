//! `ReferenceBackend`: a deterministic, pure-Rust model backend.
//!
//! Promoted from the test-only `MockBackend`: it honors the same
//! bucket/manifest contract as the PJRT runtime (bucket grids, packed
//! (token, confidence) outputs, KV handles, p0 plumbing) but computes
//! everything on the CPU from a seeded RNG — no artifacts, no xla, no
//! network. Three modes:
//!
//! - [`RefMode::Scripted`] — the original test script: content below an
//!   absolute position boundary, EOS at and after it. Scheduler tests
//!   use this to pin early-exit/termination behavior precisely.
//! - [`RefMode::Toy`] — a tiny "language model": each row's prompt
//!   hashes to a signature that deterministically fixes the answer
//!   length and every content token, so *all* decode schedules converge
//!   to the same text. `eval::synthetic_suite` derives matching
//!   expected answers from the same function, which gives CI benches a
//!   meaningful accuracy axis on a bare checkout.
//! - [`RefMode::Causal`] — the confidence-coupled model: each token is
//!   a hash chain over the *committed* prefix, and confidence reflects
//!   how many predecessors are still masked. Committing a low-confidence
//!   guess early corrupts every dependent downstream token — exactly
//!   the failure mode the paper's dynamic threshold (Eq. 10) avoids —
//!   so the accuracy/NFE trade-off benches actually bend on a bare
//!   checkout. Suites score against the fully-sequential chain (the
//!   analogue of the AR teacher).

use std::cell::RefCell;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::util::rng::Rng;

use super::backend::{Backend, CachedSpan, PrefixCapture};
use super::types::{detokenize_until_eos, reference_vocab, Buckets, DecodeOut, SpecialTokens};

/// Default seed for the toy model: serving, eval and benches must all
/// agree on it so synthesized suites score against the right oracle.
pub const REFERENCE_SEED: u64 = 0x5d11_a5ee_d001;

/// Prompt tokens hashed into the row signature (toy/causal modes).
const SIG_WINDOW: usize = 16;

/// Domain-separation salts for the causal hash chain.
const CHAIN_SALT: u64 = 0xC4A5_A11C_4A15_0001;
const WRONG_SALT: u64 = 0x00BA_DD1E_0000_0001;
const GUESS_SALT: u64 = 0x6E55_0000_0000_0001;
const CONF_SALT: u64 = 0xC0FF_1D3A_0000_0001;

/// Probability that the causal model's imagined value for a still-masked
/// predecessor matches its own chain prediction (per offset, per call) —
/// the knob that sets how often an early parallel commit happens to be
/// right anyway.
const GUESS_P: f32 = 0.75;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefMode {
    /// Emit `content_token` below absolute position `boundary`, EOS at
    /// and after it.
    Scripted { boundary: usize, content_token: i32 },
    /// Prompt-signature toy model: schedule-independent (every decode
    /// path converges to the oracle text).
    Toy,
    /// Committed-prefix hash chain with prefix-coupled confidences:
    /// schedule-*dependent*, reproduces the accuracy/speed trade-off.
    Causal,
}

impl RefMode {
    pub fn name(&self) -> &'static str {
        match self {
            RefMode::Scripted { .. } => "scripted",
            RefMode::Toy => "toy",
            RefMode::Causal => "causal",
        }
    }

    /// CLI/env selection (`--ref-mode`, `SDLLM_REF_MODE`). The scripted
    /// mode is test-only and not selectable.
    pub fn parse(s: &str) -> Option<RefMode> {
        match s {
            "toy" => Some(RefMode::Toy),
            "causal" => Some(RefMode::Causal),
            _ => None,
        }
    }
}

/// Per-kind call counters (the reference analogue of `RuntimeStats`).
#[derive(Debug, Default, Clone)]
pub struct RefStats {
    pub prefills: u64,
    pub decodes: u64,
    pub logits: u64,
    /// prompt tokens actually fed through the signature hash — the
    /// reference model's stand-in for prefill FLOPs. Cached-prefix rows
    /// hash 0; intra-batch dedup hashes each distinct sig window once.
    /// The prefix-cache acceptance tests assert over deltas of this.
    pub prefix_tokens_hashed: u64,
}

/// The reference model's [`PrefixCapture`] payload: the row signature a
/// cold prefill would recompute, plus the prompt length it was captured
/// at. Reusing the signature for a matched prefix of `m` tokens is
/// sound iff `m >= SIG_WINDOW` (the hash never reads past the window)
/// or the hit covers the *exact* captured prompt — `usable_span` below
/// enforces that, so warm rows stay bit-identical to cold ones.
#[derive(Debug, Clone, Copy)]
pub struct RefPrefix {
    pub sig: u64,
    pub len: usize,
}

/// Per-row prefill capture: prompt signature, prompt length, and (causal
/// mode) the committed generation tokens the KV prefix carries, so
/// decode can replay the hash chain up to any queried offset.
#[derive(Debug, Clone)]
pub struct RefRow {
    pub sig: u64,
    pub p0: usize,
    pub gen_prefix: Vec<i32>,
}

/// Reference KV: remembers what prefill saw (enough for decode and for
/// test assertions).
pub struct RefKv {
    pub batch: usize,
    pub p_bucket: usize,
    pub valid: Vec<i32>,
    rows: Vec<RefRow>,
}

/// Reusable buffers for the causal rollout: the committed-offset map,
/// chain predictions and unknown-predecessor counts for one row. Kept
/// on the backend behind a `RefCell` so `emit_causal_row` performs no
/// heap allocation per call — all rows and calls share one arena that
/// grows to the high-water generation length.
#[derive(Debug, Default)]
struct CausalScratch {
    committed: Vec<Option<i32>>,
    pred: Vec<i32>,
    unknown: Vec<usize>,
}

pub struct ReferenceBackend {
    pub special: SpecialTokens,
    pub vocab: Vec<String>,
    pub buckets: Buckets,
    pub mode: RefMode,
    /// confidence floor (scripted/toy); draws land in
    /// [base_conf, base_conf + 0.5]
    pub base_conf: f32,
    pub conf_seed: u64,
    pub calls: RefCell<RefStats>,
    scratch: RefCell<CausalScratch>,
}

fn default_buckets() -> Buckets {
    Buckets {
        batch: vec![1, 4],
        prefix: vec![96, 160, 224, 352, 800, 1056],
        query: vec![13, 17, 25, 41, 73, 137, 264, 520],
        seq: vec![96, 160, 224, 352, 800, 1056],
    }
}

/// splitmix64 finalizer — the hash primitive behind signatures, chain
/// states and per-position token draws.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Map a mixed 64-bit state to a uniform f32 in [0, 1) (top 24 bits —
/// the same reduction `util::rng::Rng::f32` uses).
fn uniform01(h: u64) -> f32 {
    (h >> 40) as f32 / (1u64 << 24) as f32
}

/// Digits/letters content draw shared by the toy and causal models.
fn content_token(h: u64) -> i32 {
    let mut r = Rng::new(h);
    if r.f32() < 0.75 {
        5 + r.below(10) as i32 // digit
    } else {
        15 + r.below(26) as i32 // lowercase letter
    }
}

/// Confidence of a causal prediction with `u` still-masked predecessors:
/// certain when fully determined, else a band that decays with `u` —
/// tuned so τ sweeps bend: τ=1.0 only ever commits determined tokens,
/// τ≈0.9 occasionally admits single-gap guesses, lower τ admits deeper
/// (and likelier-wrong) guesses.
fn causal_conf(u: usize, jit: f32) -> f32 {
    if u == 0 {
        1.0
    } else {
        let center = 0.33 + 0.5 * 0.7f32.powi(u as i32 - 1);
        (center + (jit - 0.5) * 0.3).clamp(0.05, 0.99)
    }
}

impl ReferenceBackend {
    /// The scripted test backend (formerly `MockBackend::new`): content
    /// token 10 below absolute position `boundary`, EOS after.
    pub fn scripted(boundary: usize) -> ReferenceBackend {
        ReferenceBackend::with_mode(RefMode::Scripted { boundary, content_token: 10 }, 7)
    }

    /// The deterministic toy model (prompt-dependent, schedule-independent
    /// answers).
    pub fn toy(seed: u64) -> ReferenceBackend {
        ReferenceBackend::with_mode(RefMode::Toy, seed)
    }

    /// The confidence-coupled causal model (schedule-dependent answers;
    /// premature commits corrupt dependent tokens).
    pub fn causal(seed: u64) -> ReferenceBackend {
        ReferenceBackend::with_mode(RefMode::Causal, seed)
    }

    fn with_mode(mode: RefMode, conf_seed: u64) -> ReferenceBackend {
        ReferenceBackend {
            special: SpecialTokens::default(),
            vocab: reference_vocab(),
            buckets: default_buckets(),
            mode,
            base_conf: 0.5,
            conf_seed,
            calls: RefCell::default(),
            scratch: RefCell::default(),
        }
    }

    pub fn stats(&self) -> RefStats {
        self.calls.borrow().clone()
    }

    /// Row signature: hash of the first `SIG_WINDOW` prompt tokens.
    /// Depends only on the prompt, so every decode schedule sees the
    /// same model parameters (what differs in causal mode is the
    /// *conditioning*, not the model).
    fn row_sig(&self, prompt: &[i32]) -> u64 {
        self.calls.borrow_mut().prefix_tokens_hashed += prompt.len().min(SIG_WINDOW) as u64;
        let mut h = mix(self.conf_seed ^ 0xA076_1D64_78BD_642F);
        for &t in prompt.iter().take(SIG_WINDOW) {
            h = mix(h ^ t as u64);
        }
        h
    }

    /// Whether a cached span's capture can stand in for recomputing the
    /// signature of a prompt of length `p0b`. Sound in exactly two
    /// cases: the matched prefix reaches `SIG_WINDOW` (the hash never
    /// reads past it), or the hit covers this exact prompt end to end.
    fn usable_span(span: &CachedSpan, p0b: usize) -> Option<u64> {
        let cap = span.capture.as_ref()?.downcast_ref::<RefPrefix>()?;
        let m = span.len.min(p0b);
        (m >= SIG_WINDOW || (m == p0b && cap.len == m)).then_some(cap.sig)
    }

    /// Content tokens before EOS, fixed by the signature: 4..=16.
    fn answer_len(sig: u64) -> usize {
        4 + (sig % 13) as usize
    }

    /// Toy-mode token at generation offset `d` (0-based after the
    /// prompt): digits/letters with a ';' separator near the end, EOS
    /// from `answer_len` on. A pure function of (sig, d).
    fn toy_token(&self, sig: u64, d: usize, answer_len: usize) -> i32 {
        if d >= answer_len {
            return self.special.eos;
        }
        if d == answer_len - 3 {
            return 46; // ';' — gives extract_final a non-trivial split
        }
        content_token(mix(sig ^ (d as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)))
    }

    /// Causal-mode token emitted from chain state `h` at offset `d`.
    /// Length and the ';' separator position stay signature-fixed (so
    /// termination and answer extraction are schedule-independent); the
    /// content tokens are chain-dependent.
    fn chain_token(&self, h: u64, d: usize, answer_len: usize) -> i32 {
        if d >= answer_len {
            return self.special.eos;
        }
        if d == answer_len - 3 {
            return 46;
        }
        content_token(mix(h ^ CHAIN_SALT))
    }

    /// Fold a committed (or imagined) token into the chain state.
    fn chain_absorb(h: u64, tok: i32) -> u64 {
        mix(h ^ (tok as u64).wrapping_add(0x1_0000))
    }

    /// What the model deterministically generates for `prompt` under a
    /// fully-sequential schedule — the oracle `eval::synthetic_suite`
    /// scores against. In causal mode this walks the hash chain absorbing
    /// its own tokens (the AR-teacher analogue); aggressive schedules may
    /// diverge from it, which is the whole point.
    pub fn oracle_text(&self, prompt: &[i32]) -> String {
        let sig = self.row_sig(prompt);
        let answer_len = Self::answer_len(sig);
        let ids: Vec<i32> = match self.mode {
            RefMode::Causal => {
                let mut h = mix(sig ^ CHAIN_SALT);
                let mut ids = Vec::with_capacity(answer_len);
                for d in 0..answer_len {
                    let t = self.chain_token(h, d, answer_len);
                    h = Self::chain_absorb(h, t);
                    ids.push(t);
                }
                ids
            }
            _ => (0..answer_len).map(|d| self.toy_token(sig, d, answer_len)).collect(),
        };
        detokenize_until_eos(&self.vocab, &self.special, &ids)
    }

    /// Token emitted at absolute position `pos` for a scripted/toy row.
    fn token_at(&self, row: &RefRow, pos: usize) -> i32 {
        match self.mode {
            RefMode::Scripted { boundary, content_token } => {
                if pos >= boundary {
                    self.special.eos
                } else {
                    content_token
                }
            }
            _ => {
                let answer_len = Self::answer_len(row.sig);
                self.toy_token(row.sig, pos.saturating_sub(row.p0), answer_len)
            }
        }
    }

    /// Deterministic f32 in [0, 1), unique per (row, position, slot,
    /// call): the call counter keeps draws fresh across steps, and
    /// positions are mixed order-sensitively so permuted or partially
    /// overlapping bundles can't collide.
    fn jitter(&self, b: usize, pos: usize, slot: usize, call: u64) -> f32 {
        let mut h = mix(self.conf_seed ^ CONF_SALT ^ call);
        h = mix(h ^ b as u64);
        h = mix(h ^ ((pos as u64) << 20) ^ slot as u64);
        uniform01(h)
    }

    fn emit(
        &self,
        rows: &[RefRow],
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
        batch: usize,
        bucket: usize,
    ) -> DecodeOut {
        let call = {
            let c = self.calls.borrow();
            c.prefills + c.decodes + c.logits
        };
        let mut out = DecodeOut::filled(batch, bucket);
        for b in 0..batch {
            let live = q_valid.get(b).copied().unwrap_or(bucket as i32).max(0) as usize;
            if self.mode == RefMode::Causal {
                self.emit_causal_row(&rows[b], q_tok, q_pos, live, call, b, bucket, &mut out);
                continue;
            }
            for i in 0..bucket {
                if i >= live {
                    out.put(b, i, self.special.pad, 0.0);
                    continue;
                }
                let pos = q_pos[b * bucket + i].max(0) as usize;
                let tok = self.token_at(&rows[b], pos);
                let jit = self.jitter(b, pos, i, call);
                out.put(b, i, tok, (self.base_conf + jit * 0.5).min(1.0));
            }
        }
        out
    }

    /// The causal forward for one row: reconstruct which generation
    /// offsets are visibly committed (KV prefix + committed bundle
    /// slots), then run *one* batched rollout of the chain covering
    /// every queried offset — per-slot output reads are table lookups
    /// into that pass, never fresh chain evaluations. Committed offsets
    /// are absorbed as-is; masked offsets absorb the model's own
    /// prediction, which is only right with probability `GUESS_P` per
    /// offset — so every prediction past a masked gap is a guess, and a
    /// wrong guess that gets committed corrupts the chain for all
    /// downstream offsets. The rollout tables live in the shared
    /// [`CausalScratch`] arena, so the per-call cost is pure hash math
    /// (the hash sequence is byte-identical to the allocating form).
    #[allow(clippy::too_many_arguments)]
    fn emit_causal_row(
        &self,
        row: &RefRow,
        q_tok: &[i32],
        q_pos: &[i32],
        live: usize,
        call: u64,
        b: usize,
        bucket: usize,
        out: &mut DecodeOut,
    ) {
        let (sig, p0) = (row.sig, row.p0);
        let answer_len = Self::answer_len(sig);
        let max_d = (0..live)
            .map(|i| (q_pos[b * bucket + i].max(0) as usize).saturating_sub(p0))
            .max()
            .unwrap_or(0);
        let mut arena = self.scratch.borrow_mut();
        let CausalScratch { committed, pred, unknown } = &mut *arena;
        committed.clear();
        committed.resize(max_d + 1, None);
        pred.clear();
        pred.resize(max_d + 1, 0);
        unknown.clear();
        unknown.resize(max_d + 1, 0);
        for (j, &t) in row.gen_prefix.iter().enumerate() {
            if j <= max_d && t != self.special.mask && t != self.special.pad {
                committed[j] = Some(t);
            }
        }
        for i in 0..live {
            let pos = q_pos[b * bucket + i].max(0) as usize;
            let t = q_tok[b * bucket + i];
            if pos >= p0 && t != self.special.mask && t != self.special.pad {
                committed[pos - p0] = Some(t);
            }
        }
        let mut h = mix(sig ^ CHAIN_SALT);
        let mut u = 0usize;
        for d in 0..=max_d {
            pred[d] = self.chain_token(h, d, answer_len);
            unknown[d] = u;
            let absorbed = match committed[d] {
                Some(t) => t,
                None => {
                    u += 1;
                    let roll =
                        uniform01(mix(self.conf_seed ^ GUESS_SALT ^ call ^ mix(sig ^ d as u64)));
                    if roll < GUESS_P {
                        pred[d]
                    } else {
                        content_token(mix(h ^ WRONG_SALT))
                    }
                }
            };
            h = Self::chain_absorb(h, absorbed);
        }
        for i in 0..bucket {
            if i >= live {
                out.put(b, i, self.special.pad, 0.0);
                continue;
            }
            let pos = q_pos[b * bucket + i].max(0) as usize;
            let d = pos.saturating_sub(p0);
            out.put(b, i, pred[d], causal_conf(unknown[d], self.jitter(b, pos, i, call)));
        }
    }

    /// Per-row capture for a `[batch, width]` token block.
    fn sig_rows(
        &self,
        tokens: &[i32],
        width: usize,
        batch: usize,
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<Vec<RefRow>> {
        match self.mode {
            RefMode::Scripted { .. } => {
                Ok((0..batch).map(|_| RefRow { sig: 0, p0: 0, gen_prefix: vec![] }).collect())
            }
            RefMode::Toy | RefMode::Causal => {
                let p0 = p0
                    .ok_or_else(|| anyhow!("reference {} backend needs p0", self.mode.name()))?;
                let mut rows = Vec::with_capacity(batch);
                for b in 0..batch {
                    let p0b = p0[b].max(0) as usize;
                    let row = &tokens[b * width..(b + 1) * width];
                    let sig = self.row_sig(&row[..p0b.min(width)]);
                    let gen_prefix = if self.mode == RefMode::Causal {
                        let hi = (valid.get(b).copied().unwrap_or(0).max(0) as usize).min(width);
                        row[p0b.min(hi)..hi].to_vec()
                    } else {
                        vec![]
                    };
                    rows.push(RefRow { sig, p0: p0b, gen_prefix });
                }
                Ok(rows)
            }
        }
    }
}

impl Backend for ReferenceBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.special.clone()
    }

    fn wants_p0(&self) -> bool {
        matches!(self.mode, RefMode::Toy | RefMode::Causal)
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.buckets.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.buckets.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.buckets.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.buckets.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        _pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<RefKv> {
        self.calls.borrow_mut().prefills += 1;
        let rows = self.sig_rows(tokens, p_bucket, batch, valid, p0)?;
        Ok(RefKv { batch, p_bucket, valid: valid.to_vec(), rows })
    }

    /// Cache-aware prefill. **Bit-identical to `prefill`**: the call
    /// counter advances exactly the same way (causal confidence draws
    /// are keyed on it) and the resulting rows are equal — captures and
    /// intra-batch dedup only shorten signature hashing, which
    /// `prefix_tokens_hashed` accounts for.
    fn prefill_cached(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        _pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
        cached: &[CachedSpan],
    ) -> Result<RefKv> {
        self.calls.borrow_mut().prefills += 1;
        let rows = match self.mode {
            RefMode::Scripted { .. } => {
                (0..batch).map(|_| RefRow { sig: 0, p0: 0, gen_prefix: vec![] }).collect()
            }
            RefMode::Toy | RefMode::Causal => {
                let p0 = p0
                    .ok_or_else(|| anyhow!("reference {} backend needs p0", self.mode.name()))?;
                // shared-prefix dedup: cold rows arriving in the same
                // call whose sig windows coincide hash the window once
                let mut windows: Vec<(&[i32], u64)> = Vec::with_capacity(batch);
                let mut rows = Vec::with_capacity(batch);
                for b in 0..batch {
                    let p0b = p0[b].max(0) as usize;
                    let row = &tokens[b * p_bucket..(b + 1) * p_bucket];
                    let prompt = &row[..p0b.min(p_bucket)];
                    let sig = match cached.get(b).and_then(|s| Self::usable_span(s, prompt.len()))
                    {
                        Some(sig) => sig,
                        None => {
                            let win = &prompt[..prompt.len().min(SIG_WINDOW)];
                            match windows.iter().find(|&&(w, _)| w == win) {
                                Some(&(_, sig)) => sig,
                                None => {
                                    let sig = self.row_sig(prompt);
                                    windows.push((win, sig));
                                    sig
                                }
                            }
                        }
                    };
                    let gen_prefix = if self.mode == RefMode::Causal {
                        let hi =
                            (valid.get(b).copied().unwrap_or(0).max(0) as usize).min(p_bucket);
                        row[p0b.min(hi)..hi].to_vec()
                    } else {
                        vec![]
                    };
                    rows.push(RefRow { sig, p0: p0b, gen_prefix });
                }
                rows
            }
        };
        Ok(RefKv { batch, p_bucket, valid: valid.to_vec(), rows })
    }

    fn capture_prefix(&self, kv: &RefKv, row: usize, prefix_len: usize) -> Option<PrefixCapture> {
        match self.mode {
            // scripted rows carry no prompt-derived state worth sharing
            RefMode::Scripted { .. } => None,
            RefMode::Toy | RefMode::Causal => {
                let r = kv.rows.get(row)?;
                // the signature is only honest for the row's own prompt
                // length; `gen_prefix` is per-call decode state, not
                // prompt state, so it is never captured
                (prefix_len == r.p0)
                    .then(|| Arc::new(RefPrefix { sig: r.sig, len: prefix_len }) as PrefixCapture)
            }
        }
    }

    fn prefix_scope(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.mode.name().hash(&mut h);
        self.conf_seed.hash(&mut h);
        h.finish()
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        self.calls.borrow_mut().decodes += 1;
        Ok(self.emit(&kv.rows, q_tok, q_pos, q_valid, kv.batch, q_bucket))
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        self.calls.borrow_mut().logits += 1;
        let rows = self.sig_rows(tokens, s_bucket, batch, valid, p0)?;
        // the full canvas doubles as the query bundle: every committed
        // position is visible to the causal chain.
        Ok(self.emit(&rows, tokens, pos, valid, batch, s_bucket))
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        detokenize_until_eos(&self.vocab, &self.special, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_is_deterministic_and_prompt_dependent() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let a = be.oracle_text(&[2, 10, 11, 12]);
        let b = be.oracle_text(&[2, 10, 11, 12]);
        let c = be.oracle_text(&[2, 10, 11, 13]);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert_ne!(a, c, "different prompts should get different answers");
    }

    #[test]
    fn oracle_contains_separator() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let text = be.oracle_text(&[2, 20, 21, 22, 23]);
        assert!(text.contains(';'), "toy answers carry a ';' split: {text:?}");
        let tail = crate::eval::extract_final(&text);
        assert_eq!(tail.chars().count(), 2);
    }

    #[test]
    fn causal_oracle_shares_shape_with_toy_but_not_content() {
        let toy = ReferenceBackend::toy(REFERENCE_SEED);
        let causal = ReferenceBackend::causal(REFERENCE_SEED);
        let prompt = [2, 20, 21, 22, 23];
        let a = toy.oracle_text(&prompt);
        let b = causal.oracle_text(&prompt);
        // same signature → same length and ';' position …
        assert_eq!(a.len(), b.len());
        assert_eq!(a.find(';'), b.find(';'));
        // … but the chain produces different content
        assert_ne!(a, b);
    }

    #[test]
    fn ref_mode_parse_roundtrip() {
        assert_eq!(RefMode::parse("toy"), Some(RefMode::Toy));
        assert_eq!(RefMode::parse("causal"), Some(RefMode::Causal));
        assert_eq!(RefMode::parse("scripted"), None);
        assert_eq!(RefMode::Causal.name(), "causal");
    }

    #[test]
    fn scripted_boundary_emits_eos() {
        let be = ReferenceBackend::scripted(10);
        let tokens = vec![2i32; 96];
        let pos: Vec<i32> = (0..96).collect();
        let kv = be.prefill(1, 96, &tokens, &pos, &[8], None).unwrap();
        let q_tok = vec![1i32; 13];
        let q_pos: Vec<i32> = (8..21).collect();
        let out = be.decode(&kv, 13, &q_tok, &q_pos, &[13]).unwrap();
        for (i, &p) in q_pos.iter().enumerate() {
            let want = if p >= 10 { 3 } else { 10 };
            assert_eq!(out.token(0, i), want, "pos {p}");
        }
    }

    #[test]
    fn toy_decode_matches_oracle() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let prompt = vec![2i32, 15, 16, 17, 18, 19];
        let p0 = prompt.len();
        let mut tokens = vec![0i32; 96];
        tokens[..p0].copy_from_slice(&prompt);
        let pos: Vec<i32> = (0..96).collect();
        let kv = be.prefill(1, 96, &tokens, &pos, &[p0 as i32], Some(&[p0 as i32])).unwrap();
        // query the whole generation region in one bundle
        let q: usize = 41;
        let q_tok = vec![1i32; q];
        let q_pos: Vec<i32> = (p0 as i32..(p0 + q) as i32).collect();
        let out = be.decode(&kv, q, &q_tok, &q_pos, &[q as i32]).unwrap();
        let ids: Vec<i32> = (0..q).map(|i| out.token(0, i)).collect();
        assert_eq!(be.detokenize(&ids), be.oracle_text(&prompt));
    }

    #[test]
    fn causal_fully_visible_decode_matches_oracle() {
        // when every predecessor is committed to its chain value, the
        // prediction at each offset is the oracle token with conf 1.0
        let be = ReferenceBackend::causal(REFERENCE_SEED);
        let prompt = vec![2i32, 15, 16, 17, 18, 19];
        let p0 = prompt.len();
        let sig = be.row_sig(&prompt);
        let answer_len = ReferenceBackend::answer_len(sig);
        // commit the oracle chain into the canvas one position at a time
        let mut canvas = vec![be.special.mask; 32];
        for d in 0..answer_len {
            let mut tokens = vec![0i32; 96];
            tokens[..p0].copy_from_slice(&prompt);
            let kv = be.prefill(1, 96, &tokens, &[0; 96], &[p0 as i32], Some(&[p0 as i32]))
                .unwrap();
            let q: usize = 25;
            let mut q_tok = vec![be.special.mask; q];
            q_tok[..canvas.len().min(q)].copy_from_slice(&canvas[..canvas.len().min(q)]);
            let q_pos: Vec<i32> = (p0 as i32..(p0 + q) as i32).collect();
            let out = be.decode(&kv, q, &q_tok, &q_pos, &[q as i32]).unwrap();
            assert!(
                (out.conf(0, d) - 1.0).abs() < 1e-6,
                "fully-determined offset {d} must be certain"
            );
            canvas[d] = out.token(0, d);
        }
        let text = be.detokenize(&canvas);
        assert_eq!(text, be.oracle_text(&prompt));
    }

    #[test]
    fn causal_masked_predecessors_lower_confidence() {
        let be = ReferenceBackend::causal(REFERENCE_SEED);
        let prompt = vec![2i32, 15, 16, 17, 18, 19];
        let p0 = prompt.len();
        let mut tokens = vec![0i32; 96];
        tokens[..p0].copy_from_slice(&prompt);
        let kv = be.prefill(1, 96, &tokens, &[0; 96], &[p0 as i32], Some(&[p0 as i32])).unwrap();
        let q: usize = 13;
        let q_tok = vec![be.special.mask; q];
        let q_pos: Vec<i32> = (p0 as i32..(p0 + q) as i32).collect();
        let out = be.decode(&kv, q, &q_tok, &q_pos, &[q as i32]).unwrap();
        // offset 0 is fully determined; deeper offsets are guesses
        assert!((out.conf(0, 0) - 1.0).abs() < 1e-6);
        for i in 1..q {
            let c = out.conf(0, i);
            assert!(c < 1.0, "offset {i} has masked predecessors but conf {c}");
            assert!(c >= 0.05);
        }
    }

    #[test]
    fn confidences_in_range() {
        let be = ReferenceBackend::scripted(24);
        let tokens = vec![2i32; 96];
        let pos: Vec<i32> = (0..96).collect();
        let kv = be.prefill(1, 96, &tokens, &pos, &[8], None).unwrap();
        let q_tok = vec![1i32; 13];
        let q_pos: Vec<i32> = (8..21).collect();
        let out = be.decode(&kv, 13, &q_tok, &q_pos, &[13]).unwrap();
        for i in 0..13usize {
            let c = out.conf(0, i);
            assert!((0.0..=1.0).contains(&c), "conf {c}");
        }
    }

    #[test]
    fn prefill_cached_matches_cold_and_skips_hashing() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let prompt = vec![2i32, 15, 16, 17, 18, 19];
        let p0 = prompt.len();
        let mut tokens = vec![0i32; 96];
        tokens[..p0].copy_from_slice(&prompt);
        let cold = be.prefill(1, 96, &tokens, &[0; 96], &[p0 as i32], Some(&[p0 as i32])).unwrap();
        let cap = be.capture_prefix(&cold, 0, p0).expect("toy mode captures");
        let hashed_cold = be.stats().prefix_tokens_hashed;

        // warm: exact-prompt hit → same sig, zero tokens hashed
        let spans = vec![CachedSpan { len: p0, capture: Some(cap.clone()) }];
        let warm = be
            .prefill_cached(1, 96, &tokens, &[0; 96], &[p0 as i32], Some(&[p0 as i32]), &spans)
            .unwrap();
        assert_eq!(warm.rows[0].sig, cold.rows[0].sig);
        assert_eq!(warm.rows[0].p0, cold.rows[0].p0);
        assert_eq!(be.stats().prefix_tokens_hashed, hashed_cold, "warm row must hash nothing");
        assert_eq!(be.stats().prefills, 2, "cached prefill still counts as a prefill call");

        // a short partial span (below SIG_WINDOW, not the exact prompt)
        // must be rejected and recomputed, not trusted
        let bogus = vec![CachedSpan {
            len: 3,
            capture: Some(Arc::new(RefPrefix { sig: 0xDEAD, len: 3 }) as PrefixCapture),
        }];
        let re = be
            .prefill_cached(1, 96, &tokens, &[0; 96], &[p0 as i32], Some(&[p0 as i32]), &bogus)
            .unwrap();
        assert_eq!(re.rows[0].sig, cold.rows[0].sig, "unusable span must fall back to cold");
    }

    #[test]
    fn prefill_cached_dedups_shared_windows_in_one_call() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let prompt = vec![2i32, 15, 16, 17, 18, 19];
        let p0 = prompt.len() as i32;
        // batch of 4 identical prompts, no captures
        let mut tokens = vec![0i32; 4 * 96];
        for b in 0..4 {
            tokens[b * 96..b * 96 + prompt.len()].copy_from_slice(&prompt);
        }
        let before = be.stats().prefix_tokens_hashed;
        let spans = vec![CachedSpan::default(); 4];
        let kv = be
            .prefill_cached(4, 96, &tokens, &[0; 4 * 96], &[p0; 4], Some(&[p0; 4]), &spans)
            .unwrap();
        let batch_hashed = be.stats().prefix_tokens_hashed - before;

        // a solo cold prefill of the same prompt
        let before = be.stats().prefix_tokens_hashed;
        let solo =
            be.prefill(1, 96, &tokens[..96], &[0; 96], &[p0], Some(&[p0])).unwrap();
        let solo_hashed = be.stats().prefix_tokens_hashed - before;
        assert_eq!(
            batch_hashed, solo_hashed,
            "4 same-prefix rows must hash exactly what 1 row hashes (shared prefill)"
        );
        for b in 0..4 {
            assert_eq!(kv.rows[b].sig, solo.rows[0].sig);
        }
    }

    #[test]
    fn prefix_scope_separates_modes_and_seeds() {
        let toy = ReferenceBackend::toy(REFERENCE_SEED);
        let causal = ReferenceBackend::causal(REFERENCE_SEED);
        let other_seed = ReferenceBackend::toy(REFERENCE_SEED ^ 1);
        assert_ne!(toy.prefix_scope(), causal.prefix_scope());
        assert_ne!(toy.prefix_scope(), other_seed.prefix_scope());
        assert_eq!(toy.prefix_scope(), ReferenceBackend::toy(REFERENCE_SEED).prefix_scope());
    }

    #[test]
    fn confidence_draws_vary_per_row_and_step() {
        // satellite fix: the old RNG was seeded by q_pos.sum(), making
        // draws permutation-invariant and identical across rows/steps.
        let be = ReferenceBackend::scripted(90);
        let tokens = vec![2i32; 192];
        let pos: Vec<i32> = (0..96).chain(0..96).collect();
        let kv = be.prefill(2, 96, &tokens, &pos, &[8, 8], None).unwrap();
        let q_tok = vec![1i32; 2 * 13];
        let q_pos: Vec<i32> = (8..21).chain(8..21).collect();
        let a = be.decode(&kv, 13, &q_tok, &q_pos, &[13, 13]).unwrap();
        let b = be.decode(&kv, 13, &q_tok, &q_pos, &[13, 13]).unwrap();
        let row0: Vec<f32> = (0..13).map(|i| a.conf(0, i)).collect();
        let row1: Vec<f32> = (0..13).map(|i| a.conf(1, i)).collect();
        let step2: Vec<f32> = (0..13).map(|i| b.conf(0, i)).collect();
        assert_ne!(row0, row1, "rows must draw independent confidences");
        assert_ne!(row0, step2, "steps must draw fresh confidences");
    }
}
