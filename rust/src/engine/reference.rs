//! `ReferenceBackend`: a deterministic, pure-Rust model backend.
//!
//! Promoted from the test-only `MockBackend`: it honors the same
//! bucket/manifest contract as the PJRT runtime (bucket grids, packed
//! (token, confidence) outputs, KV handles, p0 plumbing) but computes
//! everything on the CPU from a seeded RNG — no artifacts, no xla, no
//! network. Two modes:
//!
//! - [`RefMode::Scripted`] — the original test script: content below an
//!   absolute position boundary, EOS at and after it. Scheduler tests
//!   use this to pin early-exit/termination behavior precisely.
//! - [`RefMode::Toy`] — a tiny "language model": each row's prompt
//!   hashes to a signature that deterministically fixes the answer
//!   length and every content token, so *all* decode schedules converge
//!   to the same text. `eval::synthetic_suite` derives matching
//!   expected answers from the same function, which gives CI benches a
//!   meaningful accuracy axis on a bare checkout.

use std::cell::RefCell;

use anyhow::{anyhow, Result};

use crate::util::rng::Rng;

use super::backend::Backend;
use super::types::{detokenize_until_eos, reference_vocab, Buckets, DecodeOut, SpecialTokens};

/// Default seed for the toy model: serving, eval and benches must all
/// agree on it so synthesized suites score against the right oracle.
pub const REFERENCE_SEED: u64 = 0x5d11_a5ee_d001;

/// Prompt tokens hashed into the row signature (toy mode).
const SIG_WINDOW: usize = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefMode {
    /// Emit `content_token` below absolute position `boundary`, EOS at
    /// and after it.
    Scripted { boundary: usize, content_token: i32 },
    /// Prompt-signature toy model (block-causal style: wants p0).
    Toy,
}

/// Per-kind call counters (the reference analogue of `RuntimeStats`).
#[derive(Debug, Default, Clone)]
pub struct RefStats {
    pub prefills: u64,
    pub decodes: u64,
    pub logits: u64,
}

/// Reference KV: remembers what prefill saw (enough for decode and for
/// test assertions).
pub struct RefKv {
    pub batch: usize,
    pub p_bucket: usize,
    pub valid: Vec<i32>,
    /// per-row (signature, p0) captured at prefill time
    rows: Vec<(u64, usize)>,
}

pub struct ReferenceBackend {
    pub special: SpecialTokens,
    pub vocab: Vec<String>,
    pub buckets: Buckets,
    pub mode: RefMode,
    /// confidence floor; draws land in [base_conf, base_conf + 0.5]
    pub base_conf: f32,
    pub conf_seed: u64,
    pub calls: RefCell<RefStats>,
}

fn default_buckets() -> Buckets {
    Buckets {
        batch: vec![1, 4],
        prefix: vec![96, 160, 224, 352, 800, 1056],
        query: vec![13, 17, 25, 41, 73, 137, 264, 520],
        seq: vec![96, 160, 224, 352, 800, 1056],
    }
}

/// splitmix64 finalizer — the hash primitive behind signatures and
/// per-position token draws.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl ReferenceBackend {
    /// The scripted test backend (formerly `MockBackend::new`): content
    /// token 10 below absolute position `boundary`, EOS after.
    pub fn scripted(boundary: usize) -> ReferenceBackend {
        ReferenceBackend::with_mode(RefMode::Scripted { boundary, content_token: 10 }, 7)
    }

    /// The deterministic toy model (prompt-dependent answers).
    pub fn toy(seed: u64) -> ReferenceBackend {
        ReferenceBackend::with_mode(RefMode::Toy, seed)
    }

    fn with_mode(mode: RefMode, conf_seed: u64) -> ReferenceBackend {
        ReferenceBackend {
            special: SpecialTokens::default(),
            vocab: reference_vocab(),
            buckets: default_buckets(),
            mode,
            base_conf: 0.5,
            conf_seed,
            calls: RefCell::default(),
        }
    }

    pub fn stats(&self) -> RefStats {
        self.calls.borrow().clone()
    }

    /// Row signature: hash of the first `SIG_WINDOW` prompt tokens.
    /// Depends only on the prompt (never on committed tokens), so every
    /// decode schedule sees the same toy model.
    fn row_sig(&self, prompt: &[i32]) -> u64 {
        let mut h = mix(self.conf_seed ^ 0xA076_1D64_78BD_642F);
        for &t in prompt.iter().take(SIG_WINDOW) {
            h = mix(h ^ t as u64);
        }
        h
    }

    /// Content tokens before EOS, fixed by the signature: 4..=16.
    fn answer_len(sig: u64) -> usize {
        4 + (sig % 13) as usize
    }

    /// Deterministic token at generation offset `d` (0-based after the
    /// prompt): digits/letters with a ';' separator near the end, EOS
    /// from `answer_len` on.
    fn toy_token(&self, sig: u64, d: usize, answer_len: usize) -> i32 {
        if d >= answer_len {
            return self.special.eos;
        }
        if d == answer_len - 3 {
            return 46; // ';' — gives extract_final a non-trivial split
        }
        let mut r = Rng::new(mix(sig ^ (d as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)));
        if r.f32() < 0.75 {
            5 + r.below(10) as i32 // digit
        } else {
            15 + r.below(26) as i32 // lowercase letter
        }
    }

    /// What the toy model deterministically generates for `prompt` —
    /// the oracle `eval::synthetic_suite` scores against.
    pub fn oracle_text(&self, prompt: &[i32]) -> String {
        let sig = self.row_sig(prompt);
        let answer_len = Self::answer_len(sig);
        let ids: Vec<i32> = (0..answer_len).map(|d| self.toy_token(sig, d, answer_len)).collect();
        detokenize_until_eos(&self.vocab, &self.special, &ids)
    }

    /// Token emitted at absolute position `pos` for a row with
    /// signature/p0 `row`.
    fn token_at(&self, row: (u64, usize), pos: usize) -> i32 {
        match self.mode {
            RefMode::Scripted { boundary, content_token } => {
                if pos >= boundary {
                    self.special.eos
                } else {
                    content_token
                }
            }
            RefMode::Toy => {
                let (sig, p0) = row;
                let answer_len = Self::answer_len(sig);
                self.toy_token(sig, pos.saturating_sub(p0), answer_len)
            }
        }
    }

    fn emit(
        &self,
        rows: &[(u64, usize)],
        q_pos: &[i32],
        q_valid: &[i32],
        batch: usize,
        bucket: usize,
    ) -> DecodeOut {
        let mut rng =
            Rng::new(self.conf_seed ^ q_pos.iter().map(|&p| p as u64).sum::<u64>());
        let mut data = vec![0f32; batch * bucket * 2];
        for b in 0..batch {
            for i in 0..bucket {
                let idx = (b * bucket + i) * 2;
                let pos = q_pos[b * bucket + i].max(0) as usize;
                let live = q_valid.get(b).copied().unwrap_or(bucket as i32) as usize;
                let tok = if i < live { self.token_at(rows[b], pos) } else { self.special.pad };
                data[idx] = tok as f32;
                data[idx + 1] = (self.base_conf + rng.f32() * 0.5).min(1.0);
            }
        }
        DecodeOut { data, batch, q: bucket }
    }

    /// Per-row (signature, p0) for a `[batch, width]` token block.
    fn sig_rows(
        &self,
        tokens: &[i32],
        width: usize,
        batch: usize,
        p0: Option<&[i32]>,
    ) -> Result<Vec<(u64, usize)>> {
        match self.mode {
            RefMode::Scripted { .. } => Ok(vec![(0, 0); batch]),
            RefMode::Toy => {
                let p0 = p0.ok_or_else(|| anyhow!("reference toy backend needs p0"))?;
                let mut rows = Vec::with_capacity(batch);
                for b in 0..batch {
                    let p0b = p0[b].max(0) as usize;
                    let row = &tokens[b * width..(b + 1) * width];
                    rows.push((self.row_sig(&row[..p0b.min(width)]), p0b));
                }
                Ok(rows)
            }
        }
    }
}

impl Backend for ReferenceBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.special.clone()
    }

    fn wants_p0(&self) -> bool {
        matches!(self.mode, RefMode::Toy)
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.buckets.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.buckets.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.buckets.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.buckets.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        _pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<RefKv> {
        self.calls.borrow_mut().prefills += 1;
        let rows = self.sig_rows(tokens, p_bucket, batch, p0)?;
        Ok(RefKv { batch, p_bucket, valid: valid.to_vec(), rows })
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        _q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        self.calls.borrow_mut().decodes += 1;
        Ok(self.emit(&kv.rows, q_pos, q_valid, kv.batch, q_bucket))
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        self.calls.borrow_mut().logits += 1;
        let rows = self.sig_rows(tokens, s_bucket, batch, p0)?;
        Ok(self.emit(&rows, pos, valid, batch, s_bucket))
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        detokenize_until_eos(&self.vocab, &self.special, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_is_deterministic_and_prompt_dependent() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let a = be.oracle_text(&[2, 10, 11, 12]);
        let b = be.oracle_text(&[2, 10, 11, 12]);
        let c = be.oracle_text(&[2, 10, 11, 13]);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert_ne!(a, c, "different prompts should get different answers");
    }

    #[test]
    fn oracle_contains_separator() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let text = be.oracle_text(&[2, 20, 21, 22, 23]);
        assert!(text.contains(';'), "toy answers carry a ';' split: {text:?}");
        let tail = crate::eval::extract_final(&text);
        assert_eq!(tail.chars().count(), 2);
    }

    #[test]
    fn scripted_boundary_emits_eos() {
        let be = ReferenceBackend::scripted(10);
        let tokens = vec![2i32; 96];
        let pos: Vec<i32> = (0..96).collect();
        let kv = be.prefill(1, 96, &tokens, &pos, &[8], None).unwrap();
        let q_tok = vec![1i32; 13];
        let q_pos: Vec<i32> = (8..21).collect();
        let out = be.decode(&kv, 13, &q_tok, &q_pos, &[13]).unwrap();
        for (i, &p) in q_pos.iter().enumerate() {
            let want = if p >= 10 { 3 } else { 10 };
            assert_eq!(out.token(0, i), want, "pos {p}");
        }
    }

    #[test]
    fn toy_decode_matches_oracle() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let prompt = vec![2i32, 15, 16, 17, 18, 19];
        let p0 = prompt.len();
        let mut tokens = vec![0i32; 96];
        tokens[..p0].copy_from_slice(&prompt);
        let pos: Vec<i32> = (0..96).collect();
        let kv = be.prefill(1, 96, &tokens, &pos, &[p0 as i32], Some(&[p0 as i32])).unwrap();
        // query the whole generation region in one bundle
        let q: usize = 41;
        let q_tok = vec![1i32; q];
        let q_pos: Vec<i32> = (p0 as i32..(p0 + q) as i32).collect();
        let out = be.decode(&kv, q, &q_tok, &q_pos, &[q as i32]).unwrap();
        let ids: Vec<i32> = (0..q).map(|i| out.token(0, i)).collect();
        assert_eq!(be.detokenize(&ids), be.oracle_text(&prompt));
    }

    #[test]
    fn confidences_in_range() {
        let be = ReferenceBackend::scripted(24);
        let tokens = vec![2i32; 96];
        let pos: Vec<i32> = (0..96).collect();
        let kv = be.prefill(1, 96, &tokens, &pos, &[8], None).unwrap();
        let q_tok = vec![1i32; 13];
        let q_pos: Vec<i32> = (8..21).collect();
        let out = be.decode(&kv, 13, &q_tok, &q_pos, &[13]).unwrap();
        for i in 0..13usize {
            let c = out.conf(0, i);
            assert!((0.0..=1.0).contains(&c), "conf {c}");
        }
    }
}
