//! Per-request decode state: the token canvas (prompt + masked
//! generation region), block cursor and commit bookkeeping — the x^(t)
//! of paper Eq. 1, partitioned into blocks per Eq. 2.

use super::backend::CachedSpan;
use super::policy::Trend;
use super::types::SpecialTokens;

#[derive(Debug, Clone)]
pub struct SeqState {
    /// prompt + generation region; generation region starts as MASK
    pub tokens: Vec<i32>,
    /// prompt length (p_L in the paper)
    pub p0: usize,
    /// generation length L
    pub gen_len: usize,
    /// current block index (c in Eq. 6)
    pub block: usize,
    /// early-exited or ran out of blocks
    pub finished: bool,
    /// diffusion steps this sequence participated in (NFE proxy)
    pub steps: u64,
    /// cross-request prefix-cache attachment: how much of the prompt a
    /// cached capture covers. Set once (at the row's first prefill) by
    /// the cache lookup/insert path; holding the capture here pins its
    /// cache entry against eviction while the row decodes.
    pub cached_prefix: Option<CachedSpan>,
    /// commit-time confidence per generation position (for remasking)
    pub commit_conf: Vec<f32>,
    /// generation positions already remasked once (budget: 1 per pos)
    pub remasked: Vec<bool>,
    mask_id: i32,
    eos_id: i32,
    /// block size the masked-count cache is keyed to (0 = uninitialized)
    counts_block: usize,
    /// per-block count of still-masked generation positions, maintained
    /// incrementally by commit/remask/EOS-fill once initialized — the
    /// O(1) backing for `block_done` / `mask_ratio` on the decode hot
    /// path (the scan fallback still covers ad-hoc block sizes)
    masked_counts: Vec<u32>,
    /// confidence-trend tracking for the extrapolating temporal policy,
    /// sized lazily on first observation (empty — zero cost — unless
    /// the active policy reads trends): last predicted token, its
    /// confidence, and the consecutive-same-prediction run length per
    /// generation position
    trend_token: Vec<i32>,
    trend_conf: Vec<f32>,
    trend_streak: Vec<u32>,
}

impl SeqState {
    pub fn new(prompt: &[i32], gen_len: usize, special: &SpecialTokens) -> SeqState {
        let mut tokens = Vec::with_capacity(prompt.len() + gen_len);
        tokens.extend_from_slice(prompt);
        tokens.resize(prompt.len() + gen_len, special.mask);
        SeqState {
            tokens,
            p0: prompt.len(),
            gen_len,
            block: 0,
            finished: false,
            steps: 0,
            cached_prefix: None,
            commit_conf: vec![1.0; gen_len],
            remasked: vec![false; gen_len],
            mask_id: special.mask,
            eos_id: special.eos,
            counts_block: 0,
            masked_counts: Vec::new(),
            trend_token: Vec::new(),
            trend_conf: Vec::new(),
            trend_streak: Vec::new(),
        }
    }

    /// Re-initialize in place to the state `SeqState::new(prompt,
    /// gen_len, special)` would produce, reusing the existing
    /// allocations — the generator recycles its padding rows through
    /// this instead of constructing fresh ones every call.
    pub fn reset(&mut self, prompt: &[i32], gen_len: usize, special: &SpecialTokens) {
        self.tokens.clear();
        self.tokens.extend_from_slice(prompt);
        self.tokens.resize(prompt.len() + gen_len, special.mask);
        self.p0 = prompt.len();
        self.gen_len = gen_len;
        self.block = 0;
        self.finished = false;
        self.steps = 0;
        self.cached_prefix = None;
        self.commit_conf.clear();
        self.commit_conf.resize(gen_len, 1.0);
        self.remasked.clear();
        self.remasked.resize(gen_len, false);
        self.mask_id = special.mask;
        self.eos_id = special.eos;
        self.counts_block = 0;
        self.masked_counts.clear();
        self.trend_token.clear();
        self.trend_conf.clear();
        self.trend_streak.clear();
    }

    /// Record this step's prediction `(token, conf)` at masked position
    /// `abs`, returning the trend the extrapolating temporal policy
    /// should see: the *previous* step's confidence and how many
    /// consecutive prior steps predicted the same token. First
    /// observations report a flat trend (prev_conf = conf, streak 0).
    pub fn observe_trend(&mut self, abs: usize, token: i32, conf: f32) -> Trend {
        if self.trend_token.is_empty() {
            // mask_id marks "never observed": sanitized predictions are
            // never MASK, so the sentinel cannot collide
            self.trend_token.resize(self.gen_len, self.mask_id);
            self.trend_conf.resize(self.gen_len, 0.0);
            self.trend_streak.resize(self.gen_len, 0);
        }
        let g = abs - self.p0;
        let first = self.trend_token[g] == self.mask_id;
        let streak = if !first && self.trend_token[g] == token { self.trend_streak[g] } else { 0 };
        let out = Trend { prev_conf: if first { conf } else { self.trend_conf[g] }, streak };
        self.trend_token[g] = token;
        self.trend_conf[g] = conf;
        self.trend_streak[g] = streak + 1;
        out
    }

    /// Initialize (or re-key) the per-block masked-count cache for
    /// `block_size`: one scan now, O(1) `block_done`/`mask_ratio`
    /// afterwards. Idempotent for the same block size.
    pub fn init_block_counts(&mut self, block_size: usize) {
        debug_assert!(block_size > 0);
        if self.counts_block == block_size {
            return;
        }
        let n_blocks = self.gen_len.div_ceil(block_size).max(1);
        self.masked_counts.clear();
        self.masked_counts.resize(n_blocks, 0);
        for i in self.p0..self.total_len() {
            if self.tokens[i] == self.mask_id {
                self.masked_counts[(i - self.p0) / block_size] += 1;
            }
        }
        self.counts_block = block_size;
    }

    /// Cache slot for an absolute position, when the cache is live.
    fn count_block_of(&self, abs: usize) -> Option<usize> {
        if self.counts_block == 0 || abs < self.p0 {
            return None;
        }
        let b = (abs - self.p0) / self.counts_block;
        (b < self.masked_counts.len()).then_some(b)
    }

    /// Still-masked positions in block `b` — O(1) when the count cache
    /// is keyed to `block_size`, a span scan otherwise.
    pub fn masked_count_in(&self, b: usize, block_size: usize) -> usize {
        if self.counts_block == block_size {
            self.masked_counts.get(b).copied().unwrap_or(0) as usize
        } else {
            let (s, e) = self.block_span(b, block_size);
            if e <= s {
                return 0;
            }
            (s..e).filter(|&i| self.is_masked(i)).count()
        }
    }

    pub fn total_len(&self) -> usize {
        self.p0 + self.gen_len
    }

    /// This row's own block budget: how many blocks its generation
    /// region spans. Rows with different `gen_len` can share a batch —
    /// each retires when its *own* cursor runs out, not the config's.
    pub fn n_blocks(&self, block_size: usize) -> usize {
        self.gen_len.div_ceil(block_size).max(1)
    }

    /// Absolute start/end of block `b`.
    pub fn block_span(&self, b: usize, block_size: usize) -> (usize, usize) {
        let start = self.p0 + b * block_size;
        let end = (start + block_size).min(self.total_len());
        (start, end)
    }

    /// Prefix length visible to the current block: prompt + decoded blocks.
    pub fn prefix_len(&self, block_size: usize) -> usize {
        self.p0 + self.block * block_size
    }

    pub fn is_masked(&self, abs: usize) -> bool {
        self.tokens[abs] == self.mask_id
    }

    /// Masked absolute positions within the current block.
    pub fn masked_in_block(&self, block_size: usize) -> Vec<usize> {
        let (s, e) = self.block_span(self.block, block_size);
        (s..e).filter(|&i| self.is_masked(i)).collect()
    }

    /// Fraction of the current block still masked (r_mask of Eq. 10).
    pub fn mask_ratio(&self, block_size: usize) -> f32 {
        let (s, e) = self.block_span(self.block, block_size);
        if e <= s {
            return 0.0;
        }
        self.masked_count_in(self.block, block_size) as f32 / (e - s) as f32
    }

    pub fn block_done(&self, block_size: usize) -> bool {
        self.masked_count_in(self.block, block_size) == 0
    }

    pub fn commit(&mut self, abs: usize, token: i32) {
        self.commit_with_conf(abs, token, 1.0)
    }

    pub fn commit_with_conf(&mut self, abs: usize, token: i32, conf: f32) {
        debug_assert!(self.is_masked(abs), "double commit at {abs}");
        debug_assert!(abs >= self.p0, "commit into prompt at {abs}");
        let was_masked = self.tokens[abs] == self.mask_id;
        self.tokens[abs] = token;
        self.commit_conf[abs - self.p0] = conf;
        if was_masked && token != self.mask_id {
            if let Some(b) = self.count_block_of(abs) {
                self.masked_counts[b] -= 1;
            }
        }
    }

    /// ReMDM-style revision: re-mask committed low-confidence tokens in
    /// the current block (at most once per position — the budget that
    /// guarantees termination). Returns how many were re-masked.
    pub fn remask_low_confidence(&mut self, block_size: usize, tau: f32) -> usize {
        let (s, e) = self.block_span(self.block, block_size);
        let mut n = 0;
        for i in s..e {
            let g = i - self.p0;
            if !self.is_masked(i)
                && self.tokens[i] != self.eos_id
                && self.commit_conf[g] < tau
                && !self.remasked[g]
            {
                self.tokens[i] = self.mask_id;
                self.remasked[g] = true;
                if let Some(b) = self.count_block_of(i) {
                    self.masked_counts[b] += 1;
                }
                n += 1;
            }
        }
        n
    }

    /// Early-exit scan (paper §3.3 "Early Exit For Block Diffusion"):
    /// if the current block contains a committed EOS whose preceding
    /// block positions are all committed, everything after it is
    /// semantically EOS — commit the rest of the block and report true.
    /// The caller then marks the sequence finished (skipping all
    /// subsequent blocks).
    pub fn early_exit_scan(&mut self, block_size: usize) -> bool {
        let (s, e) = self.block_span(self.block, block_size);
        for i in s..e {
            if self.is_masked(i) {
                return false; // hit an uncommitted position before any EOS
            }
            if self.tokens[i] == self.eos_id {
                for j in i + 1..e {
                    if self.is_masked(j) {
                        self.tokens[j] = self.eos_id;
                        if let Some(b) = self.count_block_of(j) {
                            self.masked_counts[b] -= 1;
                        }
                    }
                }
                return true;
            }
        }
        false
    }

    /// Whether the (completed) current block is pure EOS — the
    /// block-level early-exit trigger.
    pub fn block_all_eos(&self, block_size: usize) -> bool {
        let (s, e) = self.block_span(self.block, block_size);
        (s..e).all(|i| self.tokens[i] == self.eos_id)
    }

    /// Fill every remaining masked generation position with EOS
    /// (used when a sequence early-exits).
    pub fn finish_with_eos(&mut self) {
        for i in self.p0..self.total_len() {
            if self.is_masked(i) {
                self.tokens[i] = self.eos_id;
            }
        }
        self.masked_counts.fill(0);
        self.finished = true;
    }

    /// Generated region (after the prompt).
    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.p0..]
    }

    /// Paper throughput metric: committed non-EOS tokens in the
    /// generation region ("we count only non EOS tokens").
    pub fn non_eos_tokens(&self) -> usize {
        self.generated()
            .iter()
            .filter(|&&t| t != self.eos_id && t != self.mask_id)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn special() -> SpecialTokens {
        SpecialTokens { pad: 0, mask: 1, bos: 2, eos: 3, sep: 4 }
    }

    fn seq(prompt_len: usize, gen_len: usize) -> SeqState {
        let prompt: Vec<i32> = (10..10 + prompt_len as i32).collect();
        SeqState::new(&prompt, gen_len, &special())
    }

    #[test]
    fn initial_state_all_masked() {
        let s = seq(5, 16);
        assert_eq!(s.total_len(), 21);
        assert_eq!(s.masked_in_block(8), (5..13).collect::<Vec<_>>());
        assert!((s.mask_ratio(8) - 1.0).abs() < 1e-6);
        assert!(!s.block_done(8));
    }

    #[test]
    fn commit_reduces_mask_ratio() {
        let mut s = seq(5, 16);
        s.commit(5, 42);
        s.commit(6, 42);
        assert!((s.mask_ratio(8) - 0.75).abs() < 1e-6);
        assert_eq!(s.masked_in_block(8).len(), 6);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_commit_panics_in_debug() {
        let mut s = seq(2, 8);
        s.commit(2, 9);
        s.commit(2, 9);
    }

    #[test]
    fn early_exit_fills_block_after_committed_eos() {
        let mut s = seq(0, 8);
        for i in 0..3 {
            s.commit(i, 42);
        }
        s.commit(3, 3); // EOS
        assert!(s.early_exit_scan(8));
        assert!(s.block_done(8));
        assert_eq!(&s.tokens[4..8], &[3, 3, 3, 3]);
    }

    #[test]
    fn early_exit_blocked_by_preceding_mask() {
        let mut s = seq(0, 8);
        s.commit(1, 3); // EOS at index 1, but index 0 still masked
        assert!(!s.early_exit_scan(8));
        assert!(s.is_masked(0));
    }

    #[test]
    fn finish_with_eos_completes_everything() {
        let mut s = seq(4, 16);
        s.commit(4, 42);
        s.finish_with_eos();
        assert!(s.finished);
        assert_eq!(s.non_eos_tokens(), 1);
        assert!(s.generated().iter().all(|&t| t != 1));
    }

    #[test]
    fn non_eos_counts_exclude_eos_and_mask() {
        let mut s = seq(0, 8);
        s.commit(0, 42);
        s.commit(1, 3);
        assert_eq!(s.non_eos_tokens(), 1);
    }

    #[test]
    fn block_counts_track_commits_and_remasks() {
        let mut s = seq(5, 16);
        s.init_block_counts(8);
        assert_eq!(s.masked_count_in(0, 8), 8);
        assert_eq!(s.masked_count_in(1, 8), 8);
        s.commit_with_conf(5, 42, 0.3);
        s.commit_with_conf(6, 43, 0.9);
        assert_eq!(s.masked_count_in(0, 8), 6);
        assert!((s.mask_ratio(8) - 0.75).abs() < 1e-6);
        // remasking puts the position back
        assert_eq!(s.remask_low_confidence(8, 0.5), 1);
        assert_eq!(s.masked_count_in(0, 8), 7);
        // cached and scanned counts agree at every step
        assert_eq!(s.masked_count_in(0, 8), s.masked_in_block(8).len());
    }

    #[test]
    fn block_counts_survive_eos_fill_paths() {
        let mut s = seq(0, 16);
        s.init_block_counts(8);
        for i in 0..3 {
            s.commit(i, 42);
        }
        s.commit(3, 3); // EOS
        assert!(s.early_exit_scan(8));
        assert_eq!(s.masked_count_in(0, 8), 0);
        assert!(s.block_done(8));
        s.finish_with_eos();
        assert_eq!(s.masked_count_in(1, 8), 0);
    }

    #[test]
    fn block_counts_fall_back_for_other_block_sizes() {
        let mut s = seq(5, 16);
        s.init_block_counts(8);
        s.commit(5, 42);
        // queries at a different block size scan instead of reading the
        // 8-keyed cache
        assert_eq!(s.masked_count_in(0, 4), 3);
        assert_eq!(s.masked_count_in(1, 4), 4);
        // re-keying rebuilds from the canvas
        s.init_block_counts(4);
        assert_eq!(s.masked_count_in(0, 4), 3);
    }

    #[test]
    fn reset_matches_fresh_state() {
        let mut s = seq(5, 16);
        s.init_block_counts(8);
        s.commit(5, 42);
        s.block = 1;
        s.steps = 9;
        s.finish_with_eos();
        let prompt: Vec<i32> = (30..34).collect();
        s.reset(&prompt, 8, &special());
        let fresh = SeqState::new(&prompt, 8, &special());
        assert_eq!(s.tokens, fresh.tokens);
        assert_eq!(s.p0, fresh.p0);
        assert_eq!(s.gen_len, fresh.gen_len);
        assert_eq!(s.block, 0);
        assert!(!s.finished);
        assert_eq!(s.steps, 0);
        assert_eq!(s.commit_conf, fresh.commit_conf);
        assert_eq!(s.remasked, fresh.remasked);
        assert_eq!(s.masked_count_in(0, 8), 8);
    }

    #[test]
    fn trend_tracks_streaks_and_previous_confidence() {
        let mut s = seq(5, 16);
        // first observation: flat trend
        let t = s.observe_trend(5, 42, 0.6);
        assert_eq!(t, Trend { prev_conf: 0.6, streak: 0 });
        // same token again: streak counts the prior matching step
        let t = s.observe_trend(5, 42, 0.7);
        assert_eq!(t, Trend { prev_conf: 0.6, streak: 1 });
        let t = s.observe_trend(5, 42, 0.8);
        assert_eq!(t, Trend { prev_conf: 0.7, streak: 2 });
        // prediction flips: streak resets, prev_conf still reported
        let t = s.observe_trend(5, 43, 0.4);
        assert_eq!(t, Trend { prev_conf: 0.8, streak: 0 });
        // positions are independent
        let t = s.observe_trend(6, 42, 0.5);
        assert_eq!(t, Trend { prev_conf: 0.5, streak: 0 });
        // reset clears trend history
        let prompt: Vec<i32> = (10..15).collect();
        s.reset(&prompt, 16, &special());
        let t = s.observe_trend(5, 42, 0.9);
        assert_eq!(t, Trend { prev_conf: 0.9, streak: 0 });
    }

    #[test]
    fn block_spans_clip_at_end() {
        let s = seq(3, 16);
        assert_eq!(s.block_span(0, 8), (3, 11));
        assert_eq!(s.block_span(1, 8), (11, 19));
        // block beyond the generation region clips
        assert_eq!(s.block_span(2, 8), (19, 19));
    }
}
