//! Attenuation-guided suffix modeling (paper §3.3, Eq. 7–8), driven by
//! the spatial axis of the decode policy.
//!
//! When decoding block c, the full masked suffix is replaced by the query
//! bundle the active [`SpatialPolicy`] selects: the current block always
//! rides first, followed by (depending on the variant) the entire
//! suffix, a sliding window of `w` suffix tokens, an attenuating window
//! that shrinks block by block, or a DPad-style thinned suffix — plus
//! optionally the trailing position id (the final token of the
//! generation region) as a coarse representation of overall length.
//! Everything the policy leaves out is simply *absent* from the forward
//! — that's the spatial saving: the bundle picks a smaller executable
//! bucket.

use super::config::GenConfig;
use super::policy::{attenuated_window, dropout_survivor, SpatialPolicy};
use super::sequence::SeqState;

/// The query bundle for one sequence at its current block: absolute
/// positions, in the order they are fed to the decode executable
/// (current block first — the policy layer indexes commits by bundle
/// slot j < K).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Bundle {
    pub positions: Vec<usize>,
    /// how many leading slots belong to the current block
    pub block_len: usize,
}

impl Bundle {
    /// Drop all positions (an inert bundle for finished/waiting rows)
    /// without releasing the backing allocation.
    pub fn clear(&mut self) {
        self.positions.clear();
        self.block_len = 0;
    }
}

/// Build the bundle per the active spatial policy, reusing `out`'s
/// allocation (the decode hot path calls this every step for every
/// row). Invariant (pinned by property tests): the bundle is always a
/// subset of {current block ∪ suffix} and starts with the full current
/// block.
pub fn build_bundle_into(seq: &SeqState, cfg: &GenConfig, out: &mut Bundle) {
    let (bs, be) = seq.block_span(seq.block, cfg.block_size);
    let end = seq.total_len();
    out.positions.clear();
    out.positions.extend(bs..be);
    out.block_len = out.positions.len();

    match cfg.policy.spatial {
        SpatialPolicy::FullSuffix => out.positions.extend(be..end),
        SpatialPolicy::Window { window, trailing } => {
            extend_windowed(out, be, end, window, trailing);
        }
        SpatialPolicy::Attenuating { window, min_window, trailing } => {
            let w = attenuated_window(window, min_window, seq.block, seq.n_blocks(cfg.block_size));
            extend_windowed(out, be, end, w, trailing);
        }
        SpatialPolicy::Dropout { window, stride, seed, trailing } => {
            let win_end = (be + window).min(end);
            out.positions.extend(be..win_end);
            // far suffix thinned to one deterministic survivor per
            // stride-sized chunk (the trailing id is handled separately)
            let far_end = if trailing { end - 1 } else { end };
            if far_end > win_end {
                let rest = far_end - win_end;
                for chunk in 0..rest.div_ceil(stride) {
                    let cs = win_end + chunk * stride;
                    let clen = stride.min(far_end - cs);
                    out.positions.push(cs + dropout_survivor(seed, chunk, clen));
                }
            }
            if trailing && win_end < end {
                out.positions.push(end - 1);
            }
        }
    }
}

/// Window of `window` suffix tokens after the block, plus the trailing
/// position id when the window falls short of the suffix end:
/// Ĩ ∪ {p_L + L} — keep the final position id (Eq. 7).
fn extend_windowed(out: &mut Bundle, be: usize, end: usize, window: usize, trailing: bool) {
    let win_end = (be + window).min(end);
    out.positions.extend(be..win_end);
    if trailing && win_end < end {
        out.positions.push(end - 1);
    }
}

/// Allocating convenience wrapper over [`build_bundle_into`].
pub fn build_bundle(seq: &SeqState, cfg: &GenConfig) -> Bundle {
    let mut out = Bundle::default();
    build_bundle_into(seq, cfg, &mut out);
    out
}

/// Gather bundle tokens from the sequence canvas (suffix positions are
/// still MASK by construction; current block may be partially committed).
pub fn bundle_tokens(seq: &SeqState, bundle: &Bundle) -> Vec<i32> {
    bundle.positions.iter().map(|&p| seq.tokens[p]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::config::{GenConfig, Method};
    use crate::engine::policy::DecodePolicy;
    use crate::engine::types::SpecialTokens;

    fn special() -> SpecialTokens {
        SpecialTokens { pad: 0, mask: 1, bos: 2, eos: 3, sep: 4 }
    }

    fn seq(p0: usize, gen: usize) -> SeqState {
        let prompt: Vec<i32> = (10..10 + p0 as i32).collect();
        SeqState::new(&prompt, gen, &special())
    }

    fn streaming(gen: usize, window: usize) -> GenConfig {
        let mut c = GenConfig::preset(Method::Streaming, gen);
        c.set_window(window);
        c
    }

    #[test]
    fn pruned_bundle_is_block_window_trailing() {
        let s = seq(10, 64);
        let c = streaming(64, 16);
        let b = build_bundle(&s, &c);
        // block 0: [10,18) + window [18,34) + trailing 73
        assert_eq!(b.block_len, 8);
        assert_eq!(b.positions.len(), 8 + 16 + 1);
        assert_eq!(*b.positions.last().unwrap(), 73);
        assert_eq!(b.positions[8], 18);
        assert_eq!(b.positions[23], 33);
    }

    #[test]
    fn window_clips_at_end_drops_trailing() {
        let mut s = seq(10, 64);
        s.block = 7; // last block: [66, 74)
        let c = streaming(64, 16);
        let b = build_bundle(&s, &c);
        // no suffix remains: bundle = block only
        assert_eq!(b.positions.len(), 8);
        assert_eq!(b.positions, (66..74).collect::<Vec<_>>());
    }

    #[test]
    fn window_reaching_end_has_no_duplicate_trailing() {
        let mut s = seq(10, 64);
        s.block = 6; // block [58, 66), suffix [66, 74) = 8 tokens
        let c = streaming(64, 16);
        let b = build_bundle(&s, &c);
        // window covers the whole suffix; trailing must not duplicate
        assert_eq!(b.positions.len(), 16);
        let mut sorted = b.positions.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), b.positions.len());
    }

    #[test]
    fn no_trailing_when_disabled() {
        let s = seq(10, 64);
        let mut c = streaming(64, 16);
        c.set_trailing(false);
        let b = build_bundle(&s, &c);
        assert_eq!(b.positions.len(), 8 + 16);
        assert_eq!(*b.positions.last().unwrap(), 33);
    }

    #[test]
    fn full_suffix_without_pruning() {
        let s = seq(10, 64);
        let c = GenConfig::preset(Method::FastDllm, 64);
        let b = build_bundle(&s, &c);
        assert_eq!(b.positions.len(), 64); // whole generation region
        assert_eq!(b.positions, (10..74).collect::<Vec<_>>());
    }

    #[test]
    fn build_into_reuses_and_matches_allocating_path() {
        let mut s = seq(10, 64);
        let c = streaming(64, 16);
        let mut reused = Bundle::default();
        for blk in 0..8 {
            s.block = blk;
            build_bundle_into(&s, &c, &mut reused);
            assert_eq!(reused, build_bundle(&s, &c), "block {blk}");
        }
        reused.clear();
        assert!(reused.positions.is_empty());
        assert_eq!(reused.block_len, 0);
    }

    #[test]
    fn bundle_tokens_track_commits() {
        let mut s = seq(2, 16);
        s.commit(2, 42);
        let c = streaming(16, 8);
        let b = build_bundle(&s, &c);
        let toks = bundle_tokens(&s, &b);
        assert_eq!(toks[0], 42);
        assert!(toks[1..].iter().all(|&t| t == 1)); // rest masked
    }

    #[test]
    fn attenuating_matches_fixed_window_at_block_zero() {
        // the attenuating schedule starts at its full window, so block 0
        // is bit-identical to the fixed-window policy
        let s = seq(10, 64);
        let mut att = GenConfig::preset(Method::Streaming, 64);
        att.policy = DecodePolicy::parse("attenuating").unwrap();
        let fixed = streaming(64, 24);
        assert_eq!(build_bundle(&s, &att), build_bundle(&s, &fixed));
    }

    #[test]
    fn attenuating_window_shrinks_to_min_by_the_last_blocks() {
        let mut att = GenConfig::preset(Method::Streaming, 64);
        att.policy = DecodePolicy::parse("attenuating").unwrap(); // 24 → 8
        let mut s = seq(10, 64);
        // block 0: full window 24 → 8 + 24 + 1
        let b0 = build_bundle(&s, &att);
        assert_eq!(b0.positions.len(), 33);
        // block 6: the attenuated window (11) exceeds the 8 remaining
        // suffix tokens → it covers them all, so no trailing id
        s.block = 6;
        let b6 = build_bundle(&s, &att);
        assert_eq!(b6.positions.len(), 16);
        // the attenuating bundle never exceeds the fixed-window bundle
        let fixed = streaming(64, 24);
        for blk in 0..8 {
            s.block = blk;
            let a = build_bundle(&s, &att);
            let f = build_bundle(&s, &fixed);
            assert!(a.positions.len() <= f.positions.len(), "block {blk}");
            assert!(a.positions.iter().all(|p| f.positions.contains(p)), "block {blk}");
        }
    }

    #[test]
    fn dropout_thins_the_far_suffix_deterministically() {
        let mut c = GenConfig::preset(Method::Streaming, 64);
        c.policy = DecodePolicy::parse("dropout").unwrap();
        c.set_window(8);
        let s = seq(10, 64);
        let b = build_bundle(&s, &c);
        // block [10,18) + near window [18,26) + ceil(47/4)=12 survivors
        // from [26,73) + trailing 73
        assert_eq!(b.block_len, 8);
        assert_eq!(b.positions.len(), 8 + 8 + 12 + 1);
        assert_eq!(b.positions.len(), c.policy.spatial.max_bundle_len(8, 64));
        assert_eq!(*b.positions.last().unwrap(), 73);
        // strictly increasing (no duplicates, canvas order)
        assert!(b.positions.windows(2).all(|w| w[0] < w[1]));
        // survivors live strictly inside the far region
        for &p in &b.positions[16..b.positions.len() - 1] {
            assert!((26..73).contains(&p));
        }
        // deterministic: the same seed rebuilds the same bundle
        assert_eq!(b, build_bundle(&s, &c));
    }

    #[test]
    fn bundle_len_at_matches_built_bundles_for_every_spatial_variant() {
        // the warm-up planner relies on bundle_len_at being the *exact*
        // per-block bundle length — pin it against the real builder for
        // all four spatial variants across every block
        let variants = ["streaming", "fast-dllm", "attenuating", "dropout"];
        for name in variants {
            let mut c = GenConfig::preset(Method::Streaming, 64);
            c.policy = DecodePolicy::parse(name).unwrap();
            let n_blocks = c.n_blocks();
            let mut s = seq(10, 64);
            for blk in 0..n_blocks {
                s.block = blk;
                let b = build_bundle(&s, &c);
                let suffix_len = 64 - (blk + 1) * c.block_size;
                let want =
                    c.policy.spatial.bundle_len_at(blk, n_blocks, c.block_size, suffix_len);
                assert_eq!(b.positions.len(), want, "{name} block {blk}");
                assert!(want <= c.policy.spatial.max_bundle_len(c.block_size, 64));
            }
        }
    }

    #[test]
    fn dropout_without_trailing_covers_to_the_end() {
        let mut c = GenConfig::preset(Method::Streaming, 64);
        c.policy = DecodePolicy::parse("dropout").unwrap();
        c.set_window(8);
        c.set_trailing(false);
        let s = seq(10, 64);
        let b = build_bundle(&s, &c);
        // far region is [26,74): ceil(48/4) = 12 survivors, no trailing
        assert_eq!(b.positions.len(), 8 + 8 + 12);
        assert!(b.positions.windows(2).all(|w| w[0] < w[1]));
        assert!(*b.positions.last().unwrap() < 74);
    }
}
