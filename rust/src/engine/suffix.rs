//! Attenuation-guided suffix modeling (paper §3.3, Eq. 7–8).
//!
//! When decoding block c, the full masked suffix is replaced by the query
//! bundle: the current block, a sliding window of `w` suffix tokens
//! immediately after it, and the trailing position id (the final token of
//! the generation region) as a coarse representation of overall length.
//! Everything between window and trailing token is simply *absent* from
//! the forward — that's the spatial saving: the bundle picks a smaller
//! executable bucket.

use super::config::GenConfig;
use super::sequence::SeqState;

/// The query bundle for one sequence at its current block: absolute
/// positions, in the order they are fed to the decode executable
/// (current block first — the policy layer indexes commits by bundle
/// slot j < K).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Bundle {
    pub positions: Vec<usize>,
    /// how many leading slots belong to the current block
    pub block_len: usize,
}

impl Bundle {
    /// Drop all positions (an inert bundle for finished/waiting rows)
    /// without releasing the backing allocation.
    pub fn clear(&mut self) {
        self.positions.clear();
        self.block_len = 0;
    }
}

/// Build the bundle per the active method, reusing `out`'s allocation
/// (the decode hot path calls this every step for every row):
/// - suffix pruning on  → current block + w-token window + trailing pos
/// - suffix pruning off → current block + the entire remaining suffix
pub fn build_bundle_into(seq: &SeqState, cfg: &GenConfig, out: &mut Bundle) {
    let (bs, be) = seq.block_span(seq.block, cfg.block_size);
    let end = seq.total_len();
    out.positions.clear();
    out.positions.extend(bs..be);
    out.block_len = out.positions.len();

    if cfg.suffix_pruning {
        let win_end = (be + cfg.window).min(end);
        out.positions.extend(be..win_end);
        if cfg.trailing_position && win_end < end {
            // Ĩ ∪ {p_L + L}: keep the final position id (Eq. 7)
            out.positions.push(end - 1);
        }
    } else {
        out.positions.extend(be..end);
    }
}

/// Allocating convenience wrapper over [`build_bundle_into`].
pub fn build_bundle(seq: &SeqState, cfg: &GenConfig) -> Bundle {
    let mut out = Bundle::default();
    build_bundle_into(seq, cfg, &mut out);
    out
}

/// Gather bundle tokens from the sequence canvas (suffix positions are
/// still MASK by construction; current block may be partially committed).
pub fn bundle_tokens(seq: &SeqState, bundle: &Bundle) -> Vec<i32> {
    bundle.positions.iter().map(|&p| seq.tokens[p]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::config::{GenConfig, Method};
    use crate::engine::types::SpecialTokens;

    fn special() -> SpecialTokens {
        SpecialTokens { pad: 0, mask: 1, bos: 2, eos: 3, sep: 4 }
    }

    fn seq(p0: usize, gen: usize) -> SeqState {
        let prompt: Vec<i32> = (10..10 + p0 as i32).collect();
        SeqState::new(&prompt, gen, &special())
    }

    fn streaming(gen: usize, window: usize) -> GenConfig {
        let mut c = GenConfig::preset(Method::Streaming, gen);
        c.window = window;
        c
    }

    #[test]
    fn pruned_bundle_is_block_window_trailing() {
        let s = seq(10, 64);
        let c = streaming(64, 16);
        let b = build_bundle(&s, &c);
        // block 0: [10,18) + window [18,34) + trailing 73
        assert_eq!(b.block_len, 8);
        assert_eq!(b.positions.len(), 8 + 16 + 1);
        assert_eq!(*b.positions.last().unwrap(), 73);
        assert_eq!(b.positions[8], 18);
        assert_eq!(b.positions[23], 33);
    }

    #[test]
    fn window_clips_at_end_drops_trailing() {
        let mut s = seq(10, 64);
        s.block = 7; // last block: [66, 74)
        let c = streaming(64, 16);
        let b = build_bundle(&s, &c);
        // no suffix remains: bundle = block only
        assert_eq!(b.positions.len(), 8);
        assert_eq!(b.positions, (66..74).collect::<Vec<_>>());
    }

    #[test]
    fn window_reaching_end_has_no_duplicate_trailing() {
        let mut s = seq(10, 64);
        s.block = 6; // block [58, 66), suffix [66, 74) = 8 tokens
        let c = streaming(64, 16);
        let b = build_bundle(&s, &c);
        // window covers the whole suffix; trailing must not duplicate
        assert_eq!(b.positions.len(), 16);
        let mut sorted = b.positions.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), b.positions.len());
    }

    #[test]
    fn no_trailing_when_disabled() {
        let s = seq(10, 64);
        let mut c = streaming(64, 16);
        c.trailing_position = false;
        let b = build_bundle(&s, &c);
        assert_eq!(b.positions.len(), 8 + 16);
        assert_eq!(*b.positions.last().unwrap(), 33);
    }

    #[test]
    fn full_suffix_without_pruning() {
        let s = seq(10, 64);
        let c = GenConfig::preset(Method::FastDllm, 64);
        let b = build_bundle(&s, &c);
        assert_eq!(b.positions.len(), 64); // whole generation region
        assert_eq!(b.positions, (10..74).collect::<Vec<_>>());
    }

    #[test]
    fn build_into_reuses_and_matches_allocating_path() {
        let mut s = seq(10, 64);
        let c = streaming(64, 16);
        let mut reused = Bundle::default();
        for blk in 0..8 {
            s.block = blk;
            build_bundle_into(&s, &c, &mut reused);
            assert_eq!(reused, build_bundle(&s, &c), "block {blk}");
        }
        reused.clear();
        assert!(reused.positions.is_empty());
        assert_eq!(reused.block_len, 0);
    }

    #[test]
    fn bundle_tokens_track_commits() {
        let mut s = seq(2, 16);
        s.commit(2, 42);
        let c = streaming(16, 8);
        let b = build_bundle(&s, &c);
        let toks = bundle_tokens(&s, &b);
        assert_eq!(toks[0], 42);
        assert!(toks[1..].iter().all(|&t| t == 1)); // rest masked
    }
}
