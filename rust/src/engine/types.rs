//! Backend-neutral model-contract types.
//!
//! Everything the scheduler needs to know about *any* model backend —
//! special token ids, the packed (token, confidence) decode output, the
//! bucket grids and their selection rule, and the detokenization rule —
//! lives here, free of PJRT/xla types. `runtime::ModelRuntime` (the
//! PJRT path, behind the `pjrt` feature) and `engine::ReferenceBackend`
//! (the pure-Rust toy model) both implement `engine::Backend` in terms
//! of these.

/// Tokenizer special ids, mirrored from `python/compile/tokenizer.py`
/// (`0 PAD, 1 MASK, 2 BOS, 3 EOS, 4 SEP`) — the first `N_SPECIAL` ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecialTokens {
    pub pad: i32,
    pub mask: i32,
    pub bos: i32,
    pub eos: i32,
    pub sep: i32,
}

/// Number of special ids at the head of every vocabulary.
pub const N_SPECIAL: usize = 5;

impl Default for SpecialTokens {
    fn default() -> SpecialTokens {
        SpecialTokens { pad: 0, mask: 1, bos: 2, eos: 3, sep: 4 }
    }
}

/// Packed decode output: `[B, Q, 2]` of (token id, confidence).
pub struct DecodeOut {
    pub data: Vec<f32>,
    pub batch: usize,
    pub q: usize,
}

impl DecodeOut {
    /// Zero-filled output of the given shape; backends fill slots via
    /// [`DecodeOut::put`].
    pub fn filled(batch: usize, q: usize) -> DecodeOut {
        DecodeOut { data: vec![0.0; batch * q * 2], batch, q }
    }

    /// Write the (token, confidence) pair for slot (b, i). Confidences
    /// must be finite: selection orders by `total_cmp` (NaN-tolerant),
    /// but a non-finite confidence is always a backend bug, so it is
    /// rejected here at the boundary in debug builds.
    pub fn put(&mut self, b: usize, i: usize, tok: i32, conf: f32) {
        debug_assert!(conf.is_finite(), "non-finite confidence {conf} for slot ({b}, {i})");
        let idx = (b * self.q + i) * 2;
        self.data[idx] = tok as f32;
        self.data[idx + 1] = conf;
    }

    pub fn token(&self, b: usize, i: usize) -> i32 {
        self.data[(b * self.q + i) * 2] as i32
    }

    pub fn conf(&self, b: usize, i: usize) -> f32 {
        self.data[(b * self.q + i) * 2 + 1]
    }
}

/// Smallest bucket ≥ `need` from a sorted grid — the shared selection
/// rule: padding is masked inside the model graph, so a live length
/// simply rides the next compiled size up.
pub fn pick_bucket(grid: &[usize], need: usize) -> Option<usize> {
    grid.iter().copied().filter(|&b| b >= need).min()
}

/// The four bucket grids a backend exposes (what the AOT manifest
/// declares on the PJRT side; what the reference backend makes up).
#[derive(Debug, Clone)]
pub struct Buckets {
    pub batch: Vec<usize>,
    pub prefix: Vec<usize>,
    pub query: Vec<usize>,
    pub seq: Vec<usize>,
}

impl Buckets {
    pub fn pick_batch(&self, need: usize) -> Option<usize> {
        pick_bucket(&self.batch, need)
    }

    pub fn pick_prefix(&self, need: usize) -> Option<usize> {
        pick_bucket(&self.prefix, need)
    }

    pub fn pick_query(&self, need: usize) -> Option<usize> {
        pick_bucket(&self.query, need)
    }

    pub fn pick_seq(&self, need: usize) -> Option<usize> {
        pick_bucket(&self.seq, need)
    }
}

/// Decode a token-id sequence to text, stopping at EOS and skipping
/// special ids — must match `tokenizer.decode_until_eos` on the python
/// side (pinned by tests on both the manifest and reference vocabs).
pub fn detokenize_until_eos(vocab: &[String], special: &SpecialTokens, ids: &[i32]) -> String {
    let mut s = String::new();
    for &id in ids {
        if id == special.eos {
            break;
        }
        if (id as usize) < N_SPECIAL || (id as usize) >= vocab.len() {
            continue;
        }
        s.push_str(&vocab[id as usize]);
    }
    s
}

/// The fixed character alphabet shared with the python tokenizer:
/// specials, digits, lowercase letters, task glyphs — 54 entries.
pub fn reference_vocab() -> Vec<String> {
    let mut v: Vec<String> =
        ["<pad>", "<mask>", "<bos>", "<eos>", "<sep>"].iter().map(|s| s.to_string()).collect();
    for c in "0123456789abcdefghijklmnopqrstuvwxyz+-*%=;?:>(), ".chars() {
        v.push(c.to_string());
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_bucket_smallest_geq() {
        let grid = [96, 160, 224, 352, 736];
        assert_eq!(pick_bucket(&grid, 1), Some(96));
        assert_eq!(pick_bucket(&grid, 96), Some(96));
        assert_eq!(pick_bucket(&grid, 97), Some(160));
        assert_eq!(pick_bucket(&grid, 736), Some(736));
        assert_eq!(pick_bucket(&grid, 737), None);
    }

    #[test]
    fn reference_vocab_matches_python_layout() {
        let v = reference_vocab();
        assert_eq!(v.len(), 54);
        assert_eq!(v[0], "<pad>");
        assert_eq!(v[5], "0");
        assert_eq!(v[14], "9");
        assert_eq!(v[15], "a");
        assert_eq!(v[40], "z");
        assert_eq!(v[46], ";");
        assert_eq!(v[53], " ");
    }

    #[test]
    fn detokenize_stops_at_eos_and_skips_specials() {
        let v = reference_vocab();
        let sp = SpecialTokens::default();
        // "a9;81" + EOS + junk — mirrors tokenizer.decode_until_eos
        let ids = [15i32, 14, 46, 13, 6, 3, 20, 21];
        assert_eq!(detokenize_until_eos(&v, &sp, &ids), "a9;81");
        // specials inside the prefix are skipped, out-of-vocab ignored
        assert_eq!(detokenize_until_eos(&v, &sp, &[2, 15, 4, 14, 99]), "a9");
    }

    #[test]
    fn decode_out_indexing() {
        let data = vec![10.0, 0.5, 11.0, 0.75, 12.0, 0.25, 13.0, 1.0];
        let out = DecodeOut { data, batch: 2, q: 2 };
        assert_eq!(out.token(0, 0), 10);
        assert_eq!(out.token(0, 1), 11);
        assert_eq!(out.token(1, 0), 12);
        assert!((out.conf(1, 1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn decode_out_put_roundtrips() {
        let mut out = DecodeOut::filled(2, 3);
        out.put(1, 2, 42, 0.625);
        assert_eq!(out.token(1, 2), 42);
        assert!((out.conf(1, 2) - 0.625).abs() < 1e-6);
        assert_eq!(out.token(0, 0), 0);
    }
}
