//! The zero-allocation decode core.
//!
//! `StepWorkspace` is a scratch arena threaded through every decode
//! step: padded host buffers (prefill `tokens`/`pos`/`valid`/`p0`,
//! decode `q_tok`/`q_pos`/`q_valid`), per-row query bundles and the
//! candidate/selection scratch are all reused across steps, blocks and
//! whole `generate` calls — after warmup the per-step hot path performs
//! no heap allocation. On the reference backend, where host overhead
//! dominates wall time, this is the difference the `host_overhead`
//! bench measures.
//!
//! The block-round functions here are the shared engine between
//! [`crate::engine::Generator`] (batch-at-a-time, seed-compatible
//! schedule) and [`crate::engine::BatchEngine`] (slot-based streaming
//! admission): one prefill per row-block, then decode steps until every
//! live row's *own* current block is complete, then per-row cursor
//! advance with early exit. Rows carry their block cursor themselves
//! (`SeqState::block`), so rows at different blocks coexist in one
//! batch — that is what lets the router admit requests mid-flight.

use std::time::Instant;

use anyhow::{bail, Result};

use super::backend::{Backend, CachedSpan};
use super::config::{GenConfig, Method};
use super::generator::{GenReport, StepEvent};
use super::policy::{select_soa, TemporalPolicy, Trend};
use super::prefix_cache::PrefixHandle;
use super::sequence::SeqState;
use super::suffix::{build_bundle_into, Bundle};
use super::types::{DecodeOut, SpecialTokens};

/// Structure-of-arrays candidate scratch for one decode row: positions,
/// sanitized tokens and confidences in parallel contiguous slices, so
/// the threshold compare and argmax run as chunked kernels
/// (`policy::select_soa`) instead of walking `Candidate` structs. One
/// instance per decode thread, reused across steps.
#[derive(Debug, Default)]
struct RowScratch {
    pos: Vec<usize>,
    tok: Vec<i32>,
    conf: Vec<f32>,
    trends: Vec<Trend>,
    picked: Vec<usize>,
}

impl RowScratch {
    fn clear(&mut self) {
        self.pos.clear();
        self.tok.clear();
        self.conf.clear();
        self.trends.clear();
    }
}

/// Reusable per-step scratch. All buffers grow monotonically to the
/// high-water mark of the workload and are reset (not reallocated) each
/// use; `grows`/`steps` expose an allocations-per-step proxy for the
/// `host_overhead` bench.
#[derive(Debug, Default)]
pub struct StepWorkspace {
    // prefill / vanilla host buffers
    tokens: Vec<i32>,
    pos: Vec<i32>,
    valid: Vec<i32>,
    p0s: Vec<i32>,
    // decode host buffers
    q_tok: Vec<i32>,
    q_pos: Vec<i32>,
    q_valid: Vec<i32>,
    // per-row cached-prefix spans handed to `prefill_cached`
    cached: Vec<CachedSpan>,
    // per-row query bundles (position vecs reused across steps)
    bundles: Vec<Bundle>,
    // SoA candidate/selection scratch, one slot per decode thread
    scratch: Vec<RowScratch>,
    /// buffer-growth events (capacity misses) since construction
    pub grows: u64,
    /// decode/logits steps driven through this workspace
    pub steps: u64,
}

impl StepWorkspace {
    pub fn new() -> StepWorkspace {
        StepWorkspace::default()
    }
}

/// Reset `buf` to `len` elements of `fill`, reporting whether the
/// backing allocation had to grow (the allocs-per-step signal).
fn reset_i32(buf: &mut Vec<i32>, len: usize, fill: i32) -> bool {
    let grew = buf.capacity() < len;
    buf.clear();
    buf.resize(len, fill);
    grew
}

/// A batch of decode rows: the caller's live sequences plus the
/// generator's recycled padding rows, addressed by one flat row index
/// (real rows first). `BatchEngine` passes an empty pad slice and lets
/// the buffer-fill code pad with inert rows instead.
pub(crate) struct RowsMut<'a> {
    pub real: &'a mut [SeqState],
    pub pad: &'a mut [SeqState],
}

impl RowsMut<'_> {
    pub fn len(&self) -> usize {
        self.real.len() + self.pad.len()
    }

    pub fn is_real(&self, b: usize) -> bool {
        b < self.real.len()
    }

    pub fn get(&self, b: usize) -> &SeqState {
        if b < self.real.len() {
            &self.real[b]
        } else {
            &self.pad[b - self.real.len()]
        }
    }

    pub fn get_mut(&mut self, b: usize) -> &mut SeqState {
        if b < self.real.len() {
            &mut self.real[b]
        } else {
            &mut self.pad[b - self.real.len()]
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &SeqState> {
        self.real.iter().chain(self.pad.iter())
    }
}

/// The head can in principle emit special tokens that would corrupt the
/// canvas (committing MASK would livelock the loop). Map them to EOS —
/// never a legal content token, and harmless to answer extraction.
pub(crate) fn sanitize(tok: i32, mask: i32, pad: i32, eos: i32) -> i32 {
    if tok == mask || tok == pad {
        eos
    } else {
        tok
    }
}

/// Prefix forward for every row at its own committed prefix (finished
/// rows collapse to a 1-token stub; inert padding rows `b ≥ rows.len()`
/// carry a 1-token BOS prompt). `batch` is the padded batch bucket.
///
/// When a prefix-cache handle is supplied, fresh rows (first prefill of
/// their life) look up their prompt in the radix cache first; hits ride
/// along as [`CachedSpan`]s so the backend can skip the covered work,
/// and misses are captured and inserted after the forward. Cached spans
/// never change *which* calls happen — only how much each one computes
/// — so decode output stays bit-identical to a cold run.
pub(crate) fn prefill_rows<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    ws: &mut StepWorkspace,
    rows: &mut RowsMut,
    batch: usize,
    prefix: Option<&PrefixHandle>,
    report: &mut GenReport,
) -> Result<B::Kv> {
    let k = cfg.block_size;
    let special = rt.special();

    // Fresh real rows (no decode work done yet) consult the cache once;
    // the hit span is pinned on the row for its whole lifetime so later
    // re-prefills (dKV refresh) reuse it without another lookup.
    if let Some(px) = prefix {
        for b in 0..rows.len() {
            let s = rows.get(b);
            if s.finished || s.block != 0 || s.steps != 0 || s.cached_prefix.is_some() {
                continue;
            }
            let p0 = s.p0;
            if let Some(hit) = px.cache.lookup(px.scope, &s.tokens[..p0]) {
                rows.get_mut(b).cached_prefix =
                    Some(CachedSpan { len: hit.len.min(p0), capture: Some(hit.capture) });
            }
        }
    }

    let p_need = rows
        .iter()
        .map(|s| if s.finished { 1 } else { s.prefix_len(k) })
        .max()
        .unwrap_or(1)
        .max(1);
    let p_bucket = rt
        .pick_prefix(p_need)
        .ok_or_else(|| anyhow::anyhow!("prefix {p_need} exceeds buckets"))?;

    ws.grows += reset_i32(&mut ws.tokens, batch * p_bucket, special.pad) as u64;
    ws.grows += reset_i32(&mut ws.pos, batch * p_bucket, 0) as u64;
    ws.grows += reset_i32(&mut ws.valid, batch, 1) as u64;
    ws.grows += reset_i32(&mut ws.p0s, batch, 0) as u64;
    ws.cached.clear();
    ws.cached.resize_with(batch, CachedSpan::default);
    let mut total_tokens = 0usize;
    let mut covered_tokens = 0usize;
    let mut fresh_any = false;
    for b in 0..batch {
        for j in 0..p_bucket {
            ws.pos[b * p_bucket + j] = j as i32;
        }
        if b >= rows.len() {
            // inert padding row: 1-token BOS prompt, nothing to decode
            ws.tokens[b * p_bucket] = special.bos;
            ws.p0s[b] = 1;
            continue;
        }
        let s = rows.get(b);
        let plen = if s.finished { 1 } else { s.prefix_len(k) };
        ws.valid[b] = plen as i32;
        ws.p0s[b] = s.p0 as i32;
        for j in 0..plen.min(s.tokens.len()) {
            ws.tokens[b * p_bucket + j] = s.tokens[j];
        }
        if !s.finished {
            total_tokens += plen;
            if s.block == 0 && s.steps == 0 {
                fresh_any = true;
            }
            if let Some(span) = &s.cached_prefix {
                covered_tokens += span.len.min(plen);
                ws.cached[b] = span.clone();
            }
        }
    }
    let t = Instant::now();
    let kv = rt.prefill_cached(
        batch,
        p_bucket,
        &ws.tokens,
        &ws.pos,
        &ws.valid,
        if rt.wants_p0() { Some(&ws.p0s) } else { None },
        &ws.cached,
    )?;
    let secs = t.elapsed().as_secs_f64();
    report.prefill_secs += secs;
    report.prefills += 1;
    if fresh_any {
        report.init_prefill_secs += secs;
        report.init_prefills += 1;
    } else {
        report.reprefill_secs += secs;
        report.reprefills += 1;
    }

    if let Some(px) = prefix {
        px.cache.note_prefill(secs, total_tokens.saturating_sub(covered_tokens));
        // Capture and publish the prompt-prefix state of rows the cache
        // did not (fully) cover, so the next same-prefix request hits.
        for b in 0..rows.len() {
            let s = rows.get(b);
            if s.finished || s.block != 0 || s.steps != 0 {
                continue;
            }
            let p0 = s.p0;
            let covered = s.cached_prefix.as_ref().map(|sp| sp.len).unwrap_or(0);
            if covered >= p0 {
                continue;
            }
            if let Some(cap) = rt.capture_prefix(&kv, b, p0) {
                px.cache.insert(px.scope, &s.tokens[..p0], cap.clone());
                rows.get_mut(b).cached_prefix =
                    Some(CachedSpan { len: p0, capture: Some(cap) });
            }
        }
    }
    Ok(kv)
}

/// Per-row tail of the decode inner loop: SoA candidate gather, policy
/// selection, commits, remask and early-exit scan. Row-independent by
/// construction — only this row's `SeqState` is mutated — which is what
/// lets `decode_threads` fan rows across a scoped thread pool. Returns
/// the early-exit blocks-skipped delta (counted for real rows only) and
/// the step event for flat row 0 when an observer is attached.
#[allow(clippy::too_many_arguments)]
fn process_row(
    b: usize,
    is_real: bool,
    s: &mut SeqState,
    bun: &Bundle,
    out: &DecodeOut,
    cfg: &GenConfig,
    special: &SpecialTokens,
    early_exit: bool,
    want_event: bool,
    step_in_block: usize,
    scratch: &mut RowScratch,
) -> (u64, Option<StepEvent>) {
    let k = cfg.block_size;
    if s.finished || s.block_done(k) {
        return (0, None);
    }
    let r_mask = s.mask_ratio(k);
    // candidates: masked positions within the current block, which
    // occupy the first `block_len` bundle slots. Confidence trends
    // are tracked only for policies that read them.
    let temporal = &cfg.policy.temporal;
    let track_trend = temporal.uses_trend();
    scratch.clear();
    for j in 0..bun.block_len {
        let abs = bun.positions[j];
        if s.is_masked(abs) {
            let token = sanitize(out.token(b, j), special.mask, special.pad, special.eos);
            let conf = out.conf(b, j);
            if track_trend {
                scratch.trends.push(s.observe_trend(abs, token, conf));
            }
            scratch.pos.push(abs);
            scratch.tok.push(token);
            scratch.conf.push(conf);
        }
    }
    if scratch.conf.is_empty() {
        return (0, None);
    }
    select_soa(temporal, r_mask, &scratch.conf, &scratch.trends, &mut scratch.picked);
    let event = (b == 0 && want_event).then(|| StepEvent {
        block: s.block,
        step_in_block,
        masked_confs: scratch.conf.clone(),
        threshold: temporal.threshold(r_mask),
        committed: scratch.picked.len(),
    });
    for &i in scratch.picked.iter() {
        s.commit_with_conf(scratch.pos[i], scratch.tok[i], scratch.conf[i]);
    }
    // ReMDM extension: revise low-confidence commits (once per
    // position) while the block is still open.
    if cfg.remask && !s.block_done(k) {
        s.remask_low_confidence(k, cfg.remask_tau);
    }
    s.steps += 1;
    let mut skipped = 0u64;
    if early_exit && s.early_exit_scan(k) {
        // rest of the block was EOS-filled; skipped blocks counted
        // exactly once per real row, here or never. The budget is
        // the row's own (`SeqState::n_blocks`), so mixed-length
        // batches account each row against its own gen_len.
        if is_real {
            skipped = (s.n_blocks(k) - (s.block + 1)) as u64;
        }
        s.finish_with_eos();
    }
    (skipped, event)
}

/// One diffusion decode step over every live row's query bundle.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_step<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    ws: &mut StepWorkspace,
    rows: &mut RowsMut,
    batch: usize,
    kv: &B::Kv,
    step_in_block: usize,
    early_exit: bool,
    report: &mut GenReport,
    on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
) -> Result<()> {
    let k = cfg.block_size;
    let special = rt.special();
    let StepWorkspace { q_tok, q_pos, q_valid, bundles, scratch, grows, steps, .. } = ws;

    // Bundles for live rows; finished / block-complete / padding rows
    // get an inert bundle (q_valid 0), so dead rows stop inflating the
    // query bucket and the backend skips them entirely.
    if bundles.len() < batch {
        bundles.resize_with(batch, Bundle::default);
    }
    let mut q_need = 1usize;
    for b in 0..batch {
        let bun = &mut bundles[b];
        if b >= rows.len() {
            bun.clear();
            continue;
        }
        let s = rows.get(b);
        if s.finished || s.block_done(k) {
            bun.clear();
            continue;
        }
        build_bundle_into(s, cfg, bun);
        q_need = q_need.max(bun.positions.len());
    }
    let q_bucket = rt
        .pick_query(q_need)
        .ok_or_else(|| anyhow::anyhow!("query {q_need} exceeds buckets"))?;

    *grows += reset_i32(q_tok, batch * q_bucket, special.mask) as u64;
    *grows += reset_i32(q_pos, batch * q_bucket, 0) as u64;
    *grows += reset_i32(q_valid, batch, 0) as u64;
    for b in 0..batch {
        let bun = &bundles[b];
        if bun.positions.is_empty() {
            continue;
        }
        let s = rows.get(b);
        q_valid[b] = bun.positions.len() as i32;
        let base = b * q_bucket;
        for (j, &p) in bun.positions.iter().enumerate() {
            q_tok[base + j] = s.tokens[p];
            q_pos[base + j] = p as i32;
        }
    }

    let t = Instant::now();
    let out = rt.decode(kv, q_bucket, q_tok, q_pos, q_valid)?;
    report.decode_secs += t.elapsed().as_secs_f64();
    report.steps += 1;
    *steps += 1;

    // ---- selection/commit inner loop (measured: `select_secs`) ------
    let t_sel = Instant::now();
    let n_rows = rows.len();
    let n_real = rows.real.len();
    let threads = cfg.decode_threads.clamp(1, n_rows.max(1));
    if scratch.len() < threads {
        scratch.resize_with(threads, RowScratch::default);
    }
    let want_event = on_step.is_some();
    let mut skipped_total = 0u64;
    let mut event = None;
    if threads <= 1 {
        let sc = &mut scratch[0];
        for b in 0..n_rows {
            let is_real = rows.is_real(b);
            let bun = &bundles[b];
            let s = rows.get_mut(b);
            let (sk, ev) = process_row(
                b,
                is_real,
                s,
                bun,
                &out,
                cfg,
                &special,
                early_exit,
                want_event,
                step_in_block,
                sc,
            );
            skipped_total += sk;
            event = ev.or(event);
        }
    } else {
        // Fan contiguous row chunks across a scoped pool: each thread
        // owns a disjoint `&mut SeqState` span plus its own scratch
        // slot, and per-chunk outcomes are reduced in row order after
        // the join — output and report stay bit-identical to the
        // single-threaded schedule regardless of thread timing.
        let mut refs: Vec<&mut SeqState> =
            rows.real.iter_mut().chain(rows.pad.iter_mut()).collect();
        let per = n_rows.div_ceil(threads);
        let bundles_ref: &[Bundle] = bundles;
        let out_ref = &out;
        let special_ref = &special;
        let results: Vec<(u64, Option<StepEvent>)> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            let mut rows_rest: &mut [&mut SeqState] = &mut refs;
            let mut scratch_rest: &mut [RowScratch] = scratch;
            let mut base = 0usize;
            while !rows_rest.is_empty() {
                let take = per.min(rows_rest.len());
                let (chunk, tail) = std::mem::take(&mut rows_rest).split_at_mut(take);
                rows_rest = tail;
                let (sc_head, sc_tail) = std::mem::take(&mut scratch_rest).split_at_mut(1);
                scratch_rest = sc_tail;
                let sc = &mut sc_head[0];
                let b0 = base;
                base += take;
                handles.push(scope.spawn(move || {
                    let mut skipped = 0u64;
                    let mut event = None;
                    for (off, s) in chunk.iter_mut().enumerate() {
                        let b = b0 + off;
                        let (sk, ev) = process_row(
                            b,
                            b < n_real,
                            s,
                            &bundles_ref[b],
                            out_ref,
                            cfg,
                            special_ref,
                            early_exit,
                            want_event,
                            step_in_block,
                            sc,
                        );
                        skipped += sk;
                        event = ev.or(event);
                    }
                    (skipped, event)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("decode row thread panicked")).collect()
        });
        for (sk, ev) in results {
            skipped_total += sk;
            event = ev.or(event);
        }
    }
    report.blocks_skipped += skipped_total;
    report.select_secs += t_sel.elapsed().as_secs_f64();
    if let Some(ev) = event {
        if let Some(cb) = on_step.as_mut() {
            cb(ev);
        }
    }
    Ok(())
}

/// Per-row block-cursor advance after a completed block round: early
/// exit on all-EOS blocks (skipped blocks counted once per real row),
/// otherwise step the cursor and retire rows that ran out of *their
/// own* block budget — rows with different `gen_len` coexist in one
/// batch and each retires when its own cursor finishes.
pub(crate) fn advance_blocks(
    cfg: &GenConfig,
    rows: &mut RowsMut,
    early_exit: bool,
    report: &mut GenReport,
) {
    let k = cfg.block_size;
    for b in 0..rows.len() {
        let is_real = rows.is_real(b);
        let s = rows.get_mut(b);
        if s.finished {
            continue;
        }
        let row_blocks = s.n_blocks(k);
        if early_exit && s.block_all_eos(k) {
            if is_real {
                report.blocks_skipped += (row_blocks - (s.block + 1)) as u64;
            }
            s.finish_with_eos();
            continue;
        }
        s.block += 1;
        if s.block >= row_blocks {
            s.finished = true;
        }
    }
}

/// One block round for every live row: prefill at each row's committed
/// prefix, decode until every live row's current block completes (with
/// dKV-Cache periodic prefix refresh), then advance cursors.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_block_round<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    ws: &mut StepWorkspace,
    rows: &mut RowsMut,
    batch: usize,
    prefix: Option<&PrefixHandle>,
    report: &mut GenReport,
    on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
) -> Result<()> {
    let k = cfg.block_size;
    let early_exit = cfg.method == Method::Streaming && cfg.early_exit;
    let mut kv = prefill_rows(rt, cfg, ws, rows, batch, prefix, report)?;

    let mut step_in_block = 0usize;
    let guard_max = k * 4 + 8 + if cfg.remask { k } else { 0 };
    loop {
        let any_masked = rows.iter().any(|s| !s.finished && !s.block_done(k));
        if !any_masked {
            break;
        }
        if step_in_block > guard_max {
            bail!("block decode failed to terminate");
        }
        // dKV-Cache emulation: delayed refresh pays periodic prefix
        // recompute inside the block.
        if cfg.method == Method::DkvCache
            && step_in_block > 0
            && step_in_block % cfg.dkv_refresh == 0
        {
            kv = prefill_rows(rt, cfg, ws, rows, batch, prefix, report)?;
        }
        decode_step(rt, cfg, ws, rows, batch, &kv, step_in_block, early_exit, report, on_step)?;
        step_in_block += 1;
    }

    advance_blocks(cfg, rows, early_exit, report);
    Ok(())
}

/// Vanilla baseline: full forward over the whole canvas every step, one
/// commit per row per step, no cache — reusing the workspace buffers.
///
/// `step_budget` bounds the forwards taken in this call; the function
/// returns early (rows left unfinished, all state in `SeqState`) once
/// it is spent, so the slot engine can slice a vanilla decode into
/// block-sized turns instead of monopolizing its thread for the whole
/// drain. Callers wanting the classic run-to-completion semantics pass
/// `u64::MAX`. Every step makes progress (a commit or a block-cursor
/// advance per live row), so chunked calls always terminate.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_vanilla<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    ws: &mut StepWorkspace,
    rows: &mut RowsMut,
    batch: usize,
    report: &mut GenReport,
    on_step: &mut Option<&mut dyn FnMut(StepEvent)>,
    step_budget: u64,
) -> Result<()> {
    let k = cfg.block_size;
    let special = rt.special();
    if ws.scratch.is_empty() {
        ws.scratch.push(RowScratch::default());
    }
    let s_need = rows.iter().map(|s| s.total_len()).max().unwrap_or(1).max(1);
    let s_bucket =
        rt.pick_seq(s_need).ok_or_else(|| anyhow::anyhow!("seq {s_need} exceeds buckets"))?;

    ws.grows += reset_i32(&mut ws.tokens, batch * s_bucket, special.pad) as u64;
    ws.grows += reset_i32(&mut ws.pos, batch * s_bucket, 0) as u64;
    ws.grows += reset_i32(&mut ws.valid, batch, 1) as u64;
    ws.grows += reset_i32(&mut ws.p0s, batch, 0) as u64;
    for b in 0..batch {
        for j in 0..s_bucket {
            ws.pos[b * s_bucket + j] = j as i32;
        }
        if b >= rows.len() {
            ws.tokens[b * s_bucket] = special.bos;
            ws.p0s[b] = 1;
            continue;
        }
        let s = rows.get(b);
        ws.valid[b] = s.total_len() as i32;
        ws.p0s[b] = s.p0 as i32;
    }

    let max_blocks = rows.iter().map(|s| s.n_blocks(k)).max().unwrap_or(1);
    let max_steps = (max_blocks * k * 4) as u64 + 8;
    let mut guard = 0u64;
    while rows.iter().any(|s| !s.finished) {
        if guard >= step_budget {
            return Ok(()); // budget spent; resume from SeqState next call
        }
        guard += 1;
        if guard > max_steps {
            bail!("vanilla decode failed to terminate");
        }
        for b in 0..rows.len() {
            let s = rows.get(b);
            let base = b * s_bucket;
            for (j, &t) in s.tokens.iter().enumerate() {
                ws.tokens[base + j] = t;
            }
            for j in s.tokens.len()..s_bucket {
                ws.tokens[base + j] = special.pad;
            }
        }
        let t = Instant::now();
        let out = rt.logits(
            batch,
            s_bucket,
            &ws.tokens,
            &ws.pos,
            &ws.valid,
            if rt.wants_p0() { Some(&ws.p0s) } else { None },
        )?;
        report.decode_secs += t.elapsed().as_secs_f64();
        report.steps += 1;
        ws.steps += 1;

        let t_sel = Instant::now();
        for b in 0..rows.len() {
            let s = rows.get_mut(b);
            if s.finished {
                continue;
            }
            let row_blocks = s.n_blocks(k);
            let (bs, be) = s.block_span(s.block, k);
            let sc = &mut ws.scratch[0];
            sc.clear();
            for abs in bs..be {
                if s.is_masked(abs) {
                    sc.pos.push(abs);
                    sc.tok.push(sanitize(
                        out.token(b, abs),
                        special.mask,
                        special.pad,
                        special.eos,
                    ));
                    sc.conf.push(out.conf(b, abs));
                }
            }
            if sc.conf.is_empty() {
                // advance block cursor
                s.block += 1;
                if s.block >= row_blocks {
                    s.finished = true;
                }
                continue;
            }
            if b == 0 {
                if let Some(cb) = on_step.as_mut() {
                    cb(StepEvent {
                        block: s.block,
                        step_in_block: k - sc.conf.len().min(k),
                        masked_confs: sc.conf.clone(),
                        threshold: 1.0,
                        committed: 1,
                    });
                }
            }
            select_soa(&TemporalPolicy::OnePerStep, 1.0, &sc.conf, &[], &mut sc.picked);
            for &i in sc.picked.iter() {
                s.commit_with_conf(sc.pos[i], sc.tok[i], sc.conf[i]);
            }
            s.steps += 1;
            if s.block_done(k) {
                s.block += 1;
                if s.block >= row_blocks {
                    s.finished = true;
                }
            }
        }
        report.select_secs += t_sel.elapsed().as_secs_f64();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_maps_specials_to_eos() {
        assert_eq!(sanitize(1, 1, 0, 3), 3);
        assert_eq!(sanitize(0, 1, 0, 3), 3);
        assert_eq!(sanitize(42, 1, 0, 3), 42);
        assert_eq!(sanitize(3, 1, 0, 3), 3);
    }

    #[test]
    fn reset_reports_growth_once() {
        let mut buf = Vec::new();
        assert!(reset_i32(&mut buf, 8, 7));
        assert_eq!(buf, vec![7; 8]);
        buf[0] = 99;
        assert!(!reset_i32(&mut buf, 8, 5));
        assert_eq!(buf, vec![5; 8]);
        assert!(!reset_i32(&mut buf, 4, 1));
        assert_eq!(buf.len(), 4);
    }
}
