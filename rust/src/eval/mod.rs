//! Eval harness (the lm-eval stand-in): loads the suite JSONL files
//! that `python/compile/tasks.py` exports — or synthesizes a suite from
//! the reference backend's oracle when no artifacts exist — runs them
//! through a `Generator`, and scores exact-match accuracy with the
//! shared answer-extraction rule. Every tableN bench and the examples
//! go through `run_suite`, which is generic over `engine::Backend`.

pub mod similarity;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::engine::{
    AnyBackend, Backend, GenConfig, Generator, ReferenceBackend, SeqState, StepEvent,
};
use crate::util::bench::Cell;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Samples;

/// One eval item: the pre-tokenized prompt and the expected final answer.
#[derive(Debug, Clone)]
pub struct EvalItem {
    pub prompt: Vec<i32>,
    pub answer: String,
    /// full chain-of-thought target (present for gsm/math suites)
    pub cot: String,
}

/// Load a `.jsonl` eval file.
pub fn load_suite(path: &Path) -> Result<Vec<EvalItem>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut items = vec![];
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
        let prompt = j
            .req("prompt")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("prompt not an array"))?
            .iter()
            .map(|x| x.as_i64().unwrap_or(0) as i32)
            .collect();
        items.push(EvalItem {
            prompt,
            answer: j.req("answer").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("").to_string(),
            cot: j.get("cot").and_then(|c| c.as_str()).unwrap_or("").to_string(),
        });
    }
    Ok(items)
}

/// Answer-extraction rule — must match `tasks.extract_final` on the
/// python side (pinned by integration tests): segment after the last
/// ';', or the whole string when there is none.
pub fn extract_final(text: &str) -> &str {
    match text.rfind(';') {
        Some(i) => &text[i + 1..],
        None => text,
    }
}

/// Result of running a suite.
#[derive(Debug, Clone, Default)]
pub struct SuiteResult {
    pub n: usize,
    pub correct: usize,
    /// Σ normalized CoT similarity (partial credit; see `similarity`)
    pub cot_sim_sum: f64,
    pub wall_secs: f64,
    pub non_eos_tokens: u64,
    pub steps: u64,
    pub prefills: u64,
    pub latencies: Vec<f64>,
}

impl SuiteResult {
    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            100.0 * self.correct as f64 / self.n as f64
        }
    }

    /// Mean chain-of-thought similarity in percent — the partial-credit
    /// quality signal (meaningful below the exact-match floor).
    pub fn cot_similarity(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            100.0 * self.cot_sim_sum / self.n as f64
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.non_eos_tokens as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            self.latencies.iter().sum::<f64>() / self.latencies.len() as f64
        }
    }

    pub fn to_cell(&self) -> Cell {
        Cell {
            accuracy: self.accuracy(),
            cot_sim: self.cot_similarity(),
            tokens_per_s: self.tokens_per_sec(),
            latency_s: self.mean_latency(),
            nfe: if self.n > 0 { self.steps as f64 / self.n as f64 } else { 0.0 },
        }
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        let mut s = Samples::new();
        for &l in &self.latencies {
            s.push(l);
        }
        s.percentile(p)
    }
}

/// Run `items` through the generator one request at a time (the paper's
/// lm-eval setting: batch = 1). `on_step` taps row-0 step events.
pub fn run_suite<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    items: &[EvalItem],
    mut on_step: Option<&mut dyn FnMut(StepEvent)>,
) -> Result<SuiteResult> {
    let mut generator = Generator::new(rt, cfg.clone())?;
    let special = rt.special();
    let mut res = SuiteResult { n: items.len(), ..Default::default() };
    for item in items {
        let mut seqs = vec![SeqState::new(&item.prompt, cfg.gen_len, &special)];
        let hook: Option<&mut dyn FnMut(StepEvent)> = match on_step {
            Some(ref mut f) => Some(&mut **f),
            None => None,
        };
        // Lazy AOT-executable compilation is a one-time startup cost (a
        // real deployment pre-warms, cf. ModelRuntime::warm); exclude it
        // per item so throughput AND latency ratios are undistorted.
        let compile_before = rt.compile_secs();
        let report = generator.generate(&mut seqs, hook)?;
        let compile_delta = rt.compile_secs() - compile_before;
        let wall = (report.wall_secs - compile_delta).max(1e-9);
        let text = rt.detokenize(seqs[0].generated());
        if extract_final(&text) == item.answer {
            res.correct += 1;
        }
        if !item.cot.is_empty() {
            res.cot_sim_sum += similarity::similarity(&text, &item.cot);
        } else if extract_final(&text) == item.answer {
            res.cot_sim_sum += 1.0;
        }
        res.wall_secs += wall;
        res.non_eos_tokens += report.non_eos_tokens;
        res.steps += report.steps;
        res.prefills += report.prefills;
        res.latencies.push(wall);
    }
    Ok(res)
}

/// Batched variant used by the serving example: slices items into
/// `batch`-sized groups.
pub fn run_suite_batched<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    items: &[EvalItem],
    batch: usize,
) -> Result<SuiteResult> {
    let mut generator = Generator::new(rt, cfg.clone())?;
    let special = rt.special();
    let mut res = SuiteResult { n: items.len(), ..Default::default() };
    for chunk in items.chunks(batch) {
        let mut seqs: Vec<SeqState> =
            chunk.iter().map(|it| SeqState::new(&it.prompt, cfg.gen_len, &special)).collect();
        let compile_before = rt.compile_secs();
        let report = generator.generate(&mut seqs, None)?;
        let compile_delta = rt.compile_secs() - compile_before;
        let wall = (report.wall_secs - compile_delta).max(1e-9);
        for (s, it) in seqs.iter().zip(chunk.iter()) {
            let text = rt.detokenize(s.generated());
            if extract_final(&text) == it.answer {
                res.correct += 1;
            }
            if !it.cot.is_empty() {
                res.cot_sim_sum += similarity::similarity(&text, &it.cot);
            } else if extract_final(&text) == it.answer {
                res.cot_sim_sum += 1.0;
            }
            res.latencies.push(wall);
        }
        res.wall_secs += wall;
        res.non_eos_tokens += report.non_eos_tokens;
        res.steps += report.steps;
        res.prefills += report.prefills;
    }
    Ok(res)
}

/// Synthesize an eval suite from the reference backend's oracle: random
/// prompts over the shared alphabet, expected answers computed by the
/// backend's own `oracle_text`. In toy mode that is the function every
/// decode schedule converges to; in causal mode it is the
/// *fully-sequential* hash chain (the AR-teacher analogue), so
/// aggressive schedules score below 100% — the paper's quality axis.
/// Deterministic in `seed`, so CI bench runs are comparable across
/// commits.
pub fn synthetic_suite(be: &ReferenceBackend, n: usize, seed: u64) -> Vec<EvalItem> {
    let mut rng = Rng::new(seed ^ 0x5eed_ba5e);
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let mut prompt = vec![be.special.bos];
        let len = rng.range(6, 18);
        for _ in 0..len {
            // digits + lowercase letters (ids 5..41)
            prompt.push(5 + rng.below(36) as i32);
        }
        prompt.push(47); // '?' — the query glyph the synthetic tasks end with
        let cot = be.oracle_text(&prompt);
        let answer = extract_final(&cot).to_string();
        items.push(EvalItem { prompt, answer, cot });
    }
    items
}

/// FNV-1a of a suite name — the per-suite seed for `synthetic_suite`.
fn suite_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Items per synthesized suite (env-overridable: `SDLLM_SYNTH_N`).
fn synth_n() -> usize {
    std::env::var("SDLLM_SYNTH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// The suite for a backend: reference backends synthesize from their
/// oracle (mode-matched: a causal backend yields causal-chain answers);
/// the PJRT path loads the artifact JSONL exported by
/// `python/compile/tasks.py`.
#[cfg_attr(not(feature = "pjrt"), allow(unused_variables))]
pub fn suite_for(backend: &AnyBackend, root: &Path, suite: &str) -> Result<Vec<EvalItem>> {
    match backend {
        AnyBackend::Reference(b) => Ok(synthetic_suite(b, synth_n(), suite_seed(suite))),
        #[cfg(feature = "pjrt")]
        AnyBackend::Pjrt(_) => {
            let index = crate::runtime::ArtifactsIndex::load(root)?;
            load_suite(&index.eval_dir.join(format!("{suite}.jsonl")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_final_rules() {
        assert_eq!(extract_final("a9;b81;81"), "81");
        assert_eq!(extract_final("12;14;0"), "0");
        assert_eq!(extract_final("edcba"), "edcba");
        assert_eq!(extract_final("1 2 3"), "1 2 3");
        assert_eq!(extract_final(""), "");
        assert_eq!(extract_final("x;"), "");
    }

    #[test]
    fn suite_result_math() {
        let mut r = SuiteResult {
            n: 4,
            correct: 3,
            wall_secs: 2.0,
            non_eos_tokens: 40,
            ..Default::default()
        };
        r.latencies = vec![0.5, 0.5, 0.5, 0.5];
        assert!((r.accuracy() - 75.0).abs() < 1e-9);
        assert!((r.tokens_per_sec() - 20.0).abs() < 1e-9);
        assert!((r.mean_latency() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn synthetic_suite_answers_follow_backend_mode() {
        let toy = ReferenceBackend::toy(crate::engine::REFERENCE_SEED);
        let causal = ReferenceBackend::causal(crate::engine::REFERENCE_SEED);
        let a = synthetic_suite(&toy, 4, 3);
        let b = synthetic_suite(&causal, 4, 3);
        // same prompt stream (prompts only depend on the seed) …
        let pa: Vec<_> = a.iter().map(|it| it.prompt.clone()).collect();
        let pb: Vec<_> = b.iter().map(|it| it.prompt.clone()).collect();
        assert_eq!(pa, pb);
        // … but causal answers come from the sequential chain, not the
        // toy function
        assert!(
            a.iter().zip(&b).any(|(x, y)| x.cot != y.cot),
            "causal oracle should differ from toy"
        );
    }

    #[test]
    fn load_suite_parses_jsonl() {
        let dir = std::env::temp_dir().join("sdllm_eval_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        let lines = concat!(
            "{\"prompt\": [2, 10, 11], \"answer\": \"7\", \"cot\": \"a7;7\"}\n",
            "\n",
            "{\"prompt\": [2], \"answer\": \"x\"}\n"
        );
        std::fs::write(&p, lines).unwrap();
        let items = load_suite(&p).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].prompt, vec![2, 10, 11]);
        assert_eq!(items[0].answer, "7");
        assert_eq!(items[0].cot, "a7;7");
        assert_eq!(items[1].cot, "");
    }
}
