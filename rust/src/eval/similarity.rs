//! Partial-credit generation-quality metric.
//!
//! Exact match is the paper's headline accuracy, but it saturates at 0
//! when a backbone is below the all-or-nothing threshold — which hides
//! *relative* quality differences between decoding methods (the thing
//! the paper's accuracy columns actually compare). `cot_similarity`
//! scores the generated text against the reference chain-of-thought with
//! a normalized Levenshtein similarity in [0, 1], giving a smooth signal
//! that differentiates "aggressive decoding corrupted the output" from
//! "the backbone was equally imperfect everywhere".

/// Levenshtein edit distance (chars), O(|a|·|b|) time, O(min) space.
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    // keep the shorter string in the inner dimension
    let (outer, inner) = if a.len() >= b.len() { (&a, &b) } else { (&b, &a) };
    let mut prev: Vec<usize> = (0..=inner.len()).collect();
    let mut cur = vec![0usize; inner.len() + 1];
    for (i, &oc) in outer.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &ic) in inner.iter().enumerate() {
            let sub = prev[j] + usize::from(oc != ic);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[inner.len()]
}

/// Normalized similarity in [0, 1]: 1 − dist / max(len). Empty vs empty
/// is a perfect match.
pub fn similarity(a: &str, b: &str) -> f64 {
    let denom = a.chars().count().max(b.chars().count());
    if denom == 0 {
        return 1.0;
    }
    1.0 - levenshtein(a, b) as f64 / denom as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn known_distances() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("", "ab"), 2);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("a9;b81;81", "a9;b81;81"), 0);
        assert_eq!(levenshtein("a9;b81;81", "a9;b82;82"), 2);
    }

    #[test]
    fn similarity_bounds() {
        assert_eq!(similarity("", ""), 1.0);
        assert_eq!(similarity("abc", "abc"), 1.0);
        assert_eq!(similarity("abc", ""), 0.0);
        let s = similarity("a9;b81;81", "a9;b82;82");
        assert!(s > 0.7 && s < 1.0);
    }

    #[test]
    fn prop_metric_axioms() {
        prop::check(200, |g| {
            let alphabet = "ab;19";
            let mk = |g: &mut crate::util::prop::Gen| -> String {
                let n = g.usize(0, 12);
                (0..n)
                    .map(|_| alphabet.chars().nth(g.usize(0, 4)).unwrap())
                    .collect()
            };
            let a = mk(g);
            let b = mk(g);
            let c = mk(g);
            let dab = levenshtein(&a, &b);
            // symmetry
            if dab != levenshtein(&b, &a) {
                return Err("not symmetric".into());
            }
            // identity
            if levenshtein(&a, &a) != 0 {
                return Err("d(a,a) != 0".into());
            }
            // triangle inequality
            if dab > levenshtein(&a, &c) + levenshtein(&c, &b) {
                return Err("triangle violated".into());
            }
            // bounds
            if dab > a.chars().count().max(b.chars().count()) {
                return Err("distance exceeds max len".into());
            }
            let s = similarity(&a, &b);
            if !(0.0..=1.0).contains(&s) {
                return Err(format!("similarity {s} out of range"));
            }
            Ok(())
        });
    }
}
