//! Streaming-dLLM: a serving framework for diffusion LLMs, reproducing
//! *"Streaming-dLLM: Accelerating Diffusion LLMs via Suffix Pruning and
//! Dynamic Decoding"*.
//!
//! Three-layer architecture (see DESIGN.md):
//! - L1/L2 (build-time python): Pallas kernels + JAX masked-diffusion
//!   transformer, AOT-lowered to HLO-text executables per bucket.
//! - L3 (this crate): the coordinator — request router, dynamic batcher,
//!   block-diffusion scheduler implementing the paper's three
//!   mechanisms (attenuation-guided suffix pruning, dynamic
//!   confidence-aware parallel decoding, EOS early exit) and all
//!   baselines (vanilla, dKV-Cache, Prefix-Cache, Fast-dLLM).
//!
//! Model backends (`engine::Backend`):
//! - `engine::ReferenceBackend` — deterministic pure-Rust toy model;
//!   the default build's backend, so the whole engine/coordinator stack
//!   builds, tests and benches on a bare CPU checkout.
//! - `runtime::ModelRuntime` — the PJRT bridge (xla crate) executing
//!   the AOT artifacts with device-resident parameters; compiled only
//!   with `--features pjrt`.

pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod runtime;
pub mod util;

/// Default artifacts location, overridable via `SDLLM_ARTIFACTS`.
pub fn artifacts_root() -> std::path::PathBuf {
    std::env::var("SDLLM_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
