//! `streaming-dllm` CLI: serve the TCP endpoint, run a one-shot
//! generation, or evaluate a suite — the leader entrypoint.
//!
//! Backend selection (`--backend reference|pjrt|auto`): the default
//! `auto` uses the PJRT runtime when this build carries it *and* AOT
//! artifacts exist, and the deterministic pure-Rust reference model
//! otherwise — so every subcommand works on a bare checkout.

use std::time::Duration;

use anyhow::{bail, Result};

use streaming_dllm::coordinator::{RouterHandle, Server};
use streaming_dllm::engine::{AnyBackend, Backend, GenConfig, Generator, Method, RefMode, SeqState};
use streaming_dllm::eval::{run_suite, suite_for};
use streaming_dllm::util::cli::Args;

const ABOUT: &str = "Streaming-dLLM serving framework (suffix pruning + dynamic decoding)";

fn main() -> Result<()> {
    let args = Args::parse_env()
        .describe("backend", "model backend: reference|pjrt|auto", Some("auto"))
        .describe("ref-mode", "reference mode: toy|causal (env: SDLLM_REF_MODE)", Some("toy"))
        .describe("artifacts", "artifacts directory", Some("artifacts"))
        .describe("model", "backbone to serve", Some("llada15-mini"))
        .describe("method", "vanilla|dkv-cache|prefix-cache|fast-dllm|streaming", Some("streaming"))
        .describe("gen-len", "generation length L", Some("64"))
        .describe("addr", "serve: listen address", Some("127.0.0.1:7333"))
        .describe("max-batch", "serve: dynamic batcher max batch", Some("4"))
        .describe("max-wait-ms", "serve: batcher flush deadline", Some("20"))
        .describe("suite", "eval: suite jsonl name", Some("gsm-mini"))
        .describe("n", "eval: item count", Some("50"))
        .describe("remask", "flag: enable ReMDM-style remasking (extension)", None)
        .describe("remask-tau", "remasking confidence threshold", Some("0.5"));
    args.handle_help("streaming-dllm", ABOUT);

    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "eval" => eval(&args),
        "generate" => generate(&args),
        "models" => list_models(&args),
        _ => {
            println!("{}", args.help("streaming-dllm", ABOUT));
            println!("commands: serve | eval | generate | models");
            Ok(())
        }
    }
}

fn artifacts(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(streaming_dllm::artifacts_root)
}

/// The reference mode for this invocation: `--ref-mode` wins, then
/// `SDLLM_REF_MODE`, then toy — normalized exactly like
/// `AnyBackend::env_ref_mode` (trimmed, lowercased, empty = toy) so the
/// CLI and the benches can't drift on the same value.
fn reference_mode(args: &Args) -> Result<RefMode> {
    let raw = args.get_env_or("ref-mode", "SDLLM_REF_MODE", "toy");
    let s = raw.trim().to_lowercase();
    if s.is_empty() {
        return Ok(RefMode::Toy);
    }
    RefMode::parse(&s).ok_or_else(|| anyhow::anyhow!("unknown --ref-mode '{raw}' (toy|causal)"))
}

/// Build the in-process backend for one-shot commands.
fn backend_for(args: &Args) -> Result<AnyBackend> {
    let root = artifacts(args);
    let model = args.get_or("model", "llada15-mini");
    match args.get_or("backend", "auto") {
        "reference" => Ok(AnyBackend::reference_with(reference_mode(args)?)),
        "pjrt" => pjrt_backend(&root, model),
        "auto" => AnyBackend::auto_with(&root, model, reference_mode(args)?),
        other => bail!("unknown backend '{other}' (reference|pjrt|auto)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(root: &std::path::Path, model: &str) -> Result<AnyBackend> {
    AnyBackend::pjrt(root, model)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_root: &std::path::Path, _model: &str) -> Result<AnyBackend> {
    bail!(
        "this binary was built without PJRT support; rebuild with `--features pjrt` \
         or use --backend reference"
    )
}

/// Build the serving router (the engine thread owns its backend).
fn router_for(args: &Args) -> Result<RouterHandle> {
    let root = artifacts(args);
    let model = args.get_or("model", "llada15-mini").to_string();
    let max_batch = args.get_usize("max-batch", 4);
    let max_wait = Duration::from_millis(args.get_usize("max-wait-ms", 20) as u64);
    match args.get_or("backend", "auto") {
        "reference" => {
            Ok(RouterHandle::spawn_reference_mode(reference_mode(args)?, max_batch, max_wait))
        }
        "pjrt" => pjrt_router(root, model, max_batch, max_wait),
        "auto" => {
            if AnyBackend::pjrt_available(&root) {
                pjrt_router(root, model, max_batch, max_wait)
            } else {
                Ok(RouterHandle::spawn_reference_mode(reference_mode(args)?, max_batch, max_wait))
            }
        }
        other => bail!("unknown backend '{other}' (reference|pjrt|auto)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_router(
    root: std::path::PathBuf,
    model: String,
    max_batch: usize,
    max_wait: Duration,
) -> Result<RouterHandle> {
    Ok(RouterHandle::spawn(root, model, max_batch, max_wait))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_router(
    _root: std::path::PathBuf,
    _model: String,
    _max_batch: usize,
    _max_wait: Duration,
) -> Result<RouterHandle> {
    bail!(
        "this binary was built without PJRT support; rebuild with `--features pjrt` \
         or use --backend reference"
    )
}

fn serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "llada15-mini").to_string();
    let addr = args.get_or("addr", "127.0.0.1:7333");
    let router = router_for(args)?;
    let server = Server::bind(addr, router)?;
    println!("serving {model} on {addr} (line-delimited JSON; {{\"cmd\":\"stats\"}} for metrics)");
    server.serve_forever()
}

fn eval(args: &Args) -> Result<()> {
    let root = artifacts(args);
    let backend = backend_for(args)?;
    let method = Method::parse(args.get_or("method", "streaming"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let mut cfg = GenConfig::preset(method, args.get_usize("gen-len", 64));
    if args.has_flag("remask") {
        cfg.remask = true;
        cfg.remask_tau = args.get_f32("remask-tau", 0.5);
    }
    let suite = args.get_or("suite", "gsm-mini");
    let items = suite_for(&backend, &root, suite)?;
    let n = args.get_usize("n", 50).min(items.len());
    let res = run_suite(&backend, &cfg, &items[..n], None)?;
    println!(
        "[{}] {suite} method={} L={}: acc {:.1}% (cot {:.1}%) | {:.1} tok/s | {:.2}s | NFE {:.1}",
        backend.describe(),
        method.name(),
        cfg.gen_len,
        res.accuracy(),
        res.cot_similarity(),
        res.tokens_per_sec(),
        res.mean_latency(),
        res.steps as f64 / n.max(1) as f64,
    );
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let root = artifacts(args);
    let backend = backend_for(args)?;
    let method = Method::parse(args.get_or("method", "streaming"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let cfg = GenConfig::preset(method, args.get_usize("gen-len", 64));

    // prompt: token ids as a comma list, or a sample from a suite
    let prompt: Vec<i32> = match args.get("prompt-ids") {
        Some(s) => s.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
        None => {
            let suite = args.get_or("suite", "gsm-mini");
            let items = suite_for(&backend, &root, suite)?;
            if items.is_empty() {
                bail!("empty suite");
            }
            println!("[no --prompt-ids; using first {suite} eval item]");
            items[0].prompt.clone()
        }
    };
    let mut generator = Generator::new(&backend, cfg.clone())?;
    let mut seqs = vec![SeqState::new(&prompt, cfg.gen_len, &backend.special())];
    let report = generator.generate(&mut seqs, None)?;
    println!("generated: {:?}", backend.detokenize(seqs[0].generated()));
    println!(
        "steps {} | prefills {} | {:.1} tok/s | {:.3}s",
        report.steps,
        report.prefills,
        report.tokens_per_sec(),
        report.wall_secs
    );
    Ok(())
}

fn list_models(args: &Args) -> Result<()> {
    let root = artifacts(args);
    if root.join("index.json").exists() {
        let index = streaming_dllm::runtime::ArtifactsIndex::load(&root)?;
        for m in &index.models {
            println!("{m}");
        }
    } else {
        println!(
            "reference (no artifacts at {}; run `make artifacts` for PJRT models)",
            root.display()
        );
    }
    Ok(())
}
