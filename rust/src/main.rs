//! `streaming-dllm` CLI: serve the TCP endpoint, run a one-shot
//! generation, or evaluate a suite — the leader entrypoint.

use std::time::Duration;

use anyhow::{bail, Result};

use streaming_dllm::coordinator::{Request, RouterHandle, Server};
use streaming_dllm::engine::{GenConfig, Method};
use streaming_dllm::eval::{load_suite, run_suite};
use streaming_dllm::runtime::{ArtifactsIndex, ModelRuntime, Runtime};
use streaming_dllm::util::cli::Args;

const ABOUT: &str = "Streaming-dLLM serving framework (suffix pruning + dynamic decoding)";

fn main() -> Result<()> {
    let args = Args::parse_env()
        .describe("artifacts", "artifacts directory", Some("artifacts"))
        .describe("model", "backbone to serve", Some("llada15-mini"))
        .describe("method", "vanilla|dkv-cache|prefix-cache|fast-dllm|streaming", Some("streaming"))
        .describe("gen-len", "generation length L", Some("64"))
        .describe("addr", "serve: listen address", Some("127.0.0.1:7333"))
        .describe("max-batch", "serve: dynamic batcher max batch", Some("4"))
        .describe("max-wait-ms", "serve: batcher flush deadline", Some("20"))
        .describe("suite", "eval: suite jsonl name", Some("gsm-mini"))
        .describe("n", "eval: item count", Some("50"))
        .describe("remask", "flag: enable ReMDM-style remasking (extension)", None)
        .describe("remask-tau", "remasking confidence threshold", Some("0.5"));
    args.handle_help("streaming-dllm", ABOUT);

    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "eval" => eval(&args),
        "generate" => generate(&args),
        "models" => list_models(&args),
        _ => {
            println!("{}", args.help("streaming-dllm", ABOUT));
            println!("commands: serve | eval | generate | models");
            Ok(())
        }
    }
}

fn artifacts(args: &Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(streaming_dllm::artifacts_root)
}

fn serve(args: &Args) -> Result<()> {
    let root = artifacts(args);
    let model = args.get_or("model", "llada15-mini").to_string();
    let addr = args.get_or("addr", "127.0.0.1:7333");
    let router = RouterHandle::spawn(
        root,
        model.clone(),
        args.get_usize("max-batch", 4),
        Duration::from_millis(args.get_usize("max-wait-ms", 20) as u64),
    );
    let server = Server::bind(addr, router)?;
    println!("serving {model} on {addr} (line-delimited JSON; {{\"cmd\":\"stats\"}} for metrics)");
    server.serve_forever()
}

fn eval(args: &Args) -> Result<()> {
    let root = artifacts(args);
    let index = ArtifactsIndex::load(&root)?;
    let model = args.get_or("model", "llada15-mini");
    let rt = Runtime::cpu()?;
    let model_rt = ModelRuntime::load(&rt, &index.model_dir(model))?;
    let method = Method::parse(args.get_or("method", "streaming"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let mut cfg = GenConfig::preset(method, args.get_usize("gen-len", 64));
    if args.has_flag("remask") {
        cfg.remask = true;
        cfg.remask_tau = args.get_f32("remask-tau", 0.5);
    }
    let suite = args.get_or("suite", "gsm-mini");
    let items = load_suite(&index.eval_dir.join(format!("{suite}.jsonl")))?;
    let n = args.get_usize("n", 50).min(items.len());
    let res = run_suite(&model_rt, &cfg, &items[..n], None)?;
    println!(
        "{model} {suite} method={} L={}: acc {:.1}% (cot-sim {:.1}%) | {:.1} tok/s | {:.2}s/sample | NFE {:.1}",
        method.name(),
        cfg.gen_len,
        res.accuracy(),
        res.cot_similarity(),
        res.tokens_per_sec(),
        res.mean_latency(),
        res.steps as f64 / n.max(1) as f64,
    );
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let root = artifacts(args);
    let index = ArtifactsIndex::load(&root)?;
    let model = args.get_or("model", "llada15-mini");
    let rt = Runtime::cpu()?;
    let model_rt = ModelRuntime::load(&rt, &index.model_dir(model))?;
    let method = Method::parse(args.get_or("method", "streaming"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let cfg = GenConfig::preset(method, args.get_usize("gen-len", 64));

    // prompt: token ids as a comma list, or a sample from a suite
    let prompt: Vec<i32> = match args.get("prompt-ids") {
        Some(s) => s.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
        None => {
            let suite = args.get_or("suite", "gsm-mini");
            let items = load_suite(&index.eval_dir.join(format!("{suite}.jsonl")))?;
            if items.is_empty() {
                bail!("empty suite");
            }
            println!("[no --prompt-ids; using first {suite} eval item]");
            items[0].prompt.clone()
        }
    };
    let router_cfg = cfg.clone();
    let generator = streaming_dllm::engine::Generator::new(&model_rt, router_cfg)?;
    let mut seqs = vec![streaming_dllm::engine::SeqState::new(
        &prompt,
        cfg.gen_len,
        &model_rt.manifest.special,
    )];
    let report = generator.generate(&mut seqs, None)?;
    println!("generated: {:?}", model_rt.manifest.detokenize_until_eos(seqs[0].generated()));
    println!(
        "steps {} | prefills {} | {:.1} tok/s | {:.3}s",
        report.steps,
        report.prefills,
        report.tokens_per_sec(),
        report.wall_secs
    );
    let _ = Request { id: 0, prompt, method, gen_len: cfg.gen_len }; // wire type sanity
    Ok(())
}

fn list_models(args: &Args) -> Result<()> {
    let root = artifacts(args);
    let index = ArtifactsIndex::load(&root)?;
    for m in &index.models {
        println!("{m}");
    }
    Ok(())
}
