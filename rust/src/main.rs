//! `streaming-dllm` CLI: serve the TCP endpoint, run a one-shot
//! generation, or evaluate a suite — the leader entrypoint.
//!
//! All serving knobs resolve through [`ServeConfig`] with one
//! precedence rule — CLI flag > `SDLLM_*` environment variable >
//! default — so `--ref-mode`/`SDLLM_REF_MODE`, `--max-engines`, and
//! friends mean the same thing here, in the serve_batch example and in
//! the stress harness.
//!
//! Backend selection (`--backend reference|pjrt|auto`): the default
//! `auto` uses the PJRT runtime when this build carries it *and* AOT
//! artifacts exist, and the deterministic pure-Rust reference model
//! otherwise — so every subcommand works on a bare checkout.

use anyhow::{bail, Result};

use streaming_dllm::coordinator::{RouterHandle, ServeConfig, Server, PROTOCOL_VERSION};
use streaming_dllm::engine::{AnyBackend, Backend, GenConfig, Generator, Method, SeqState};
use streaming_dllm::eval::{run_suite, suite_for};
use streaming_dllm::util::cli::Args;

const ABOUT: &str = "Streaming-dLLM serving framework (suffix pruning + dynamic decoding)";

fn main() -> Result<()> {
    let args = Args::parse_env()
        .describe("backend", "model backend: reference|pjrt|auto (env: SDLLM_BACKEND)", Some("auto"))
        .describe("ref-mode", "reference mode: toy|causal (env: SDLLM_REF_MODE)", Some("toy"))
        .describe("artifacts", "artifacts directory (env: SDLLM_ARTIFACTS)", Some("artifacts"))
        .describe("model", "backbone to serve (env: SDLLM_MODEL)", Some("llada15-mini"))
        .describe("method", "vanilla|dkv-cache|prefix-cache|fast-dllm|streaming", Some("streaming"))
        .describe("policy", "decode policy preset; default = the method's own (env: SDLLM_POLICY)", None)
        .describe("gen-len", "generation length L", Some("64"))
        .describe("addr", "serve: listen address (env: SDLLM_ADDR)", Some("127.0.0.1:7333"))
        .describe("max-batch", "serve: dynamic batcher max batch (env: SDLLM_MAX_BATCH)", Some("4"))
        .describe("max-wait-ms", "serve: batcher flush deadline (env: SDLLM_MAX_WAIT_MS)", Some("20"))
        .describe("max-engines", "serve: worker-thread cap (env: SDLLM_MAX_ENGINES)", Some("4"))
        .describe("max-queue-depth", "serve: per-method admission cap (env: SDLLM_MAX_QUEUE_DEPTH)", Some("256"))
        .describe("max-connections", "serve: concurrent-connection cap (env: SDLLM_MAX_CONNECTIONS)", Some("64"))
        .describe("deadline-ms", "serve: default SLA budget, 0 = none (env: SDLLM_DEADLINE_MS)", Some("0"))
        .describe("suite", "eval: suite jsonl name", Some("gsm-mini"))
        .describe("n", "eval: item count", Some("50"))
        .describe("remask", "flag: enable ReMDM-style remasking (extension)", None)
        .describe("remask-tau", "remasking confidence threshold", Some("0.5"));
    args.handle_help("streaming-dllm", ABOUT);

    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "eval" => eval(&args),
        "generate" => generate(&args),
        "models" => list_models(&args),
        _ => {
            println!("{}", args.help("streaming-dllm", ABOUT));
            println!("commands: serve | eval | generate | models");
            Ok(())
        }
    }
}

/// Build the in-process backend for one-shot commands.
fn backend_for(cfg: &ServeConfig) -> Result<AnyBackend> {
    let root = cfg.artifacts_root();
    match cfg.backend.as_str() {
        "reference" => Ok(AnyBackend::reference_with(cfg.ref_mode)),
        "pjrt" => pjrt_backend(&root, &cfg.model),
        "auto" => AnyBackend::auto_with(&root, &cfg.model, cfg.ref_mode),
        other => bail!("unknown backend '{other}' (reference|pjrt|auto)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(root: &std::path::Path, model: &str) -> Result<AnyBackend> {
    AnyBackend::pjrt(root, model)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_root: &std::path::Path, _model: &str) -> Result<AnyBackend> {
    bail!(
        "this binary was built without PJRT support; rebuild with `--features pjrt` \
         or use --backend reference"
    )
}

/// Build the serving router (every worker thread owns its own backend).
fn router_for(cfg: &ServeConfig) -> Result<RouterHandle> {
    let root = cfg.artifacts_root();
    match cfg.backend.as_str() {
        "reference" => {
            Ok(RouterHandle::spawn_reference_opts(cfg.ref_mode, cfg.router_options()))
        }
        "pjrt" => pjrt_router(cfg),
        "auto" => {
            if AnyBackend::pjrt_available(&root) {
                pjrt_router(cfg)
            } else {
                Ok(RouterHandle::spawn_reference_opts(cfg.ref_mode, cfg.router_options()))
            }
        }
        other => bail!("unknown backend '{other}' (reference|pjrt|auto)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_router(cfg: &ServeConfig) -> Result<RouterHandle> {
    Ok(RouterHandle::spawn_pjrt_opts(
        cfg.artifacts_root(),
        cfg.model.clone(),
        cfg.router_options(),
    ))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_router(_cfg: &ServeConfig) -> Result<RouterHandle> {
    bail!(
        "this binary was built without PJRT support; rebuild with `--features pjrt` \
         or use --backend reference"
    )
}

fn serve(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_env_and_args(args)?;
    let router = router_for(&cfg)?;
    let server = Server::bind(&cfg.addr, router)?
        .with_max_connections(cfg.max_connections)
        .with_default_policy(cfg.policy);
    println!(
        "serving {} on {} (wire protocol v{PROTOCOL_VERSION}; line-delimited JSON; \
         {{\"cmd\":\"stats\"}} for metrics)",
        cfg.model, cfg.addr
    );
    server.serve_forever()
}

fn eval(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_env_and_args(args)?;
    let root = cfg.artifacts_root();
    let backend = backend_for(&cfg)?;
    let method = Method::parse(args.get_or("method", "streaming"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let mut gen_cfg = GenConfig::preset(method, args.get_usize("gen-len", 64));
    if let Some(p) = cfg.policy {
        gen_cfg.policy = p;
    }
    if args.has_flag("remask") {
        gen_cfg.remask = true;
        gen_cfg.remask_tau = args.get_f32("remask-tau", 0.5);
    }
    let suite = args.get_or("suite", "gsm-mini");
    let items = suite_for(&backend, &root, suite)?;
    let n = args.get_usize("n", 50).min(items.len());
    let res = run_suite(&backend, &gen_cfg, &items[..n], None)?;
    println!(
        "[{}] {suite} method={} L={}: acc {:.1}% (cot {:.1}%) | {:.1} tok/s | {:.2}s | NFE {:.1}",
        backend.describe(),
        method.name(),
        gen_cfg.gen_len,
        res.accuracy(),
        res.cot_similarity(),
        res.tokens_per_sec(),
        res.mean_latency(),
        res.steps as f64 / n.max(1) as f64,
    );
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_env_and_args(args)?;
    let root = cfg.artifacts_root();
    let backend = backend_for(&cfg)?;
    let method = Method::parse(args.get_or("method", "streaming"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let mut gen_cfg = GenConfig::preset(method, args.get_usize("gen-len", 64));
    if let Some(p) = cfg.policy {
        gen_cfg.policy = p;
    }

    // prompt: token ids as a comma list, or a sample from a suite
    let prompt: Vec<i32> = match args.get("prompt-ids") {
        Some(s) => s.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
        None => {
            let suite = args.get_or("suite", "gsm-mini");
            let items = suite_for(&backend, &root, suite)?;
            if items.is_empty() {
                bail!("empty suite");
            }
            println!("[no --prompt-ids; using first {suite} eval item]");
            items[0].prompt.clone()
        }
    };
    let mut generator = Generator::new(&backend, gen_cfg.clone())?;
    let mut seqs = vec![SeqState::new(&prompt, gen_cfg.gen_len, &backend.special())];
    let report = generator.generate(&mut seqs, None)?;
    println!("generated: {:?}", backend.detokenize(seqs[0].generated()));
    println!(
        "steps {} | prefills {} | {:.1} tok/s | {:.3}s",
        report.steps,
        report.prefills,
        report.tokens_per_sec(),
        report.wall_secs
    );
    Ok(())
}

fn list_models(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_env_and_args(args)?;
    let root = cfg.artifacts_root();
    if root.join("index.json").exists() {
        let index = streaming_dllm::runtime::ArtifactsIndex::load(&root)?;
        for m in &index.models {
            println!("{m}");
        }
    } else {
        println!(
            "reference (no artifacts at {}; run `make artifacts` for PJRT models)",
            root.display()
        );
    }
    Ok(())
}
