//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the serving runtime.
//!
//! Each trained backbone ships a `manifest.json` describing its HLO-text
//! executables (kind + bucket sizes + input signature), the parameter
//! order for `params.npz`, the tokenizer special ids and the bucket
//! grids. The runtime loads this once and uses it for bucket selection:
//! pick the smallest compiled bucket ≥ the live length — padding is
//! masked out inside the model graph, so smaller live lengths simply ride
//! a slightly larger executable, while suffix pruning drops the request
//! into a genuinely smaller bucket.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

pub use crate::engine::types::SpecialTokens;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ExeKind {
    Prefill,
    Decode,
    Logits,
}

impl ExeKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ExeKind::Prefill => "prefill",
            ExeKind::Decode => "decode",
            ExeKind::Logits => "logits",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "prefill" => ExeKind::Prefill,
            "decode" => ExeKind::Decode,
            "logits" => ExeKind::Logits,
            other => bail!("unknown executable kind '{other}'"),
        })
    }
}

/// Registry key: (kind, batch bucket, prefix/seq bucket, query bucket).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExeKey {
    pub kind: ExeKind,
    pub batch: usize,
    /// prefix bucket for prefill/decode, sequence bucket for logits
    pub len: usize,
    /// query bucket (decode only; 0 otherwise)
    pub query: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub key: ExeKey,
    pub file: PathBuf,
}

#[derive(Debug, Clone)]
pub struct KvDims {
    pub layers: usize,
    pub heads: usize,
    pub d_head: usize,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub attn_mode: String,
    pub wants_p0: bool,
    pub special: SpecialTokens,
    pub vocab: Vec<String>,
    pub kv_dims: KvDims,
    pub params_file: PathBuf,
    pub param_order: Vec<ParamSpec>,
    pub batch_buckets: Vec<usize>,
    pub prefix_buckets: Vec<usize>,
    pub query_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    pub artifacts: BTreeMap<ExeKey, ArtifactEntry>,
}

fn usizes(j: &Json, key: &str) -> Result<Vec<usize>> {
    j.req(key)?
        .as_arr()
        .ok_or_else(|| anyhow!("'{key}' not an array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("'{key}' has non-numeric entry")))
        .collect()
}

impl Manifest {
    pub fn load(model_dir: &Path) -> Result<Manifest> {
        let path = model_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let special = {
            let s = j.req("special_tokens").map_err(|e| anyhow!("{e}"))?;
            let g = |k: &str| -> Result<i32> {
                Ok(s.req(k).map_err(|e| anyhow!("{e}"))?.as_i64().unwrap_or(-1) as i32)
            };
            SpecialTokens {
                pad: g("pad")?,
                mask: g("mask")?,
                bos: g("bos")?,
                eos: g("eos")?,
                sep: g("sep")?,
            }
        };

        let kv = j.req("kv_dims").map_err(|e| anyhow!("{e}"))?;
        let kv_dims = KvDims {
            layers: kv.req("layers").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            heads: kv.req("heads").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            d_head: kv.req("d_head").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
        };

        let param_order = j
            .req("param_order")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("param_order not an array"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .req("name")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_str()
                        .unwrap_or("")
                        .to_string(),
                    shape: p
                        .req("shape")
                        .map_err(|e| anyhow!("{e}"))?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let buckets = j.req("buckets").map_err(|e| anyhow!("{e}"))?;

        let mut artifacts = BTreeMap::new();
        for a in j
            .req("artifacts")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts not an array"))?
        {
            let kind_str = a.req("kind").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("");
            let kind = ExeKind::parse(kind_str)?;
            let batch = a.req("batch").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0);
            let len = match kind {
                ExeKind::Logits => {
                    a.req("seq").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0)
                }
                _ => a.req("prefix").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0),
            };
            let query = match kind {
                ExeKind::Decode => {
                    a.req("query").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0)
                }
                _ => 0,
            };
            let key = ExeKey { kind, batch, len, query };
            let rel = a.req("file").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("");
            let file = model_dir.join(rel);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            artifacts.insert(key, ArtifactEntry { key, file });
        }

        Ok(Manifest {
            model: j.req("model").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("").to_string(),
            dir: model_dir.to_path_buf(),
            attn_mode: j
                .req("attn_mode")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .unwrap_or("full")
                .to_string(),
            wants_p0: j.req("wants_p0").map_err(|e| anyhow!("{e}"))?.as_bool().unwrap_or(false),
            special,
            vocab: j
                .req("vocab")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_str().unwrap_or("").to_string())
                .collect(),
            kv_dims,
            params_file: model_dir.join(
                j.req("params_file").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("params.npz"),
            ),
            param_order,
            batch_buckets: usizes(j.req("buckets").map_err(|e| anyhow!("{e}"))?, "batch")?,
            prefix_buckets: usizes(buckets, "prefix")?,
            query_buckets: usizes(buckets, "query")?,
            seq_buckets: usizes(buckets, "seq")?,
            artifacts,
        })
    }

    /// Smallest bucket ≥ `need` from a sorted grid (shared rule in
    /// `engine::types::pick_bucket`).
    pub fn pick_bucket(grid: &[usize], need: usize) -> Option<usize> {
        crate::engine::types::pick_bucket(grid, need)
    }

    pub fn pick_batch(&self, need: usize) -> Option<usize> {
        Self::pick_bucket(&self.batch_buckets, need)
    }

    pub fn pick_prefix(&self, need: usize) -> Option<usize> {
        Self::pick_bucket(&self.prefix_buckets, need)
    }

    pub fn pick_query(&self, need: usize) -> Option<usize> {
        Self::pick_bucket(&self.query_buckets, need)
    }

    pub fn pick_seq(&self, need: usize) -> Option<usize> {
        Self::pick_bucket(&self.seq_buckets, need)
    }

    pub fn entry(&self, key: ExeKey) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(&key)
            .ok_or_else(|| anyhow!("no artifact for {key:?} in model '{}'", self.model))
    }

    /// Decode a token-id sequence to text, stopping at EOS and skipping
    /// special tokens — must match `tokenizer.decode_until_eos` on the
    /// python side (pinned by an integration test).
    pub fn detokenize_until_eos(&self, ids: &[i32]) -> String {
        crate::engine::types::detokenize_until_eos(&self.vocab, &self.special, ids)
    }
}

/// Top-level artifacts index (artifacts/index.json).
#[derive(Debug, Clone)]
pub struct ArtifactsIndex {
    pub root: PathBuf,
    pub models: Vec<String>,
    pub eval_dir: PathBuf,
    pub models_dir: PathBuf,
}

impl ArtifactsIndex {
    pub fn load(root: &Path) -> Result<ArtifactsIndex> {
        let path = root.join("index.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text)?;
        Ok(ArtifactsIndex {
            root: root.to_path_buf(),
            models: j
                .req("models")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|m| m.as_str().unwrap_or("").to_string())
                .collect(),
            eval_dir: root
                .join(j.req("eval_dir").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("eval")),
            models_dir: root.join(
                j.req("models_dir").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("models"),
            ),
        })
    }

    pub fn model_dir(&self, model: &str) -> PathBuf {
        self.models_dir.join(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_bucket_smallest_geq() {
        let grid = [96, 160, 224, 352, 736];
        assert_eq!(Manifest::pick_bucket(&grid, 1), Some(96));
        assert_eq!(Manifest::pick_bucket(&grid, 96), Some(96));
        assert_eq!(Manifest::pick_bucket(&grid, 97), Some(160));
        assert_eq!(Manifest::pick_bucket(&grid, 736), Some(736));
        assert_eq!(Manifest::pick_bucket(&grid, 737), None);
    }

    #[test]
    fn exe_kind_parse_roundtrip() {
        for k in [ExeKind::Prefill, ExeKind::Decode, ExeKind::Logits] {
            assert_eq!(ExeKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(ExeKind::parse("bogus").is_err());
    }
}
