//! Runtime: artifact manifests (always available) and the PJRT bridge
//! (behind the `pjrt` cargo feature).
//!
//! The PJRT path loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once (lazily, memoized),
//! keeps model parameters device-resident, and executes
//! decode/prefill/logits steps from the serving hot path — python is
//! never involved at runtime. The default build compiles none of that:
//! manifest parsing and bucket math stay, so evaluation tooling can
//! inspect artifacts without an accelerator toolchain, while the
//! scheduler stack runs against `engine::ReferenceBackend`.
//!
//! PJRT pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute_b` (device buffers in, device buffers out).

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod model;
#[cfg(feature = "pjrt")]
pub mod warmup;

pub use artifact::{ArtifactsIndex, ExeKey, ExeKind, Manifest};
#[cfg(feature = "pjrt")]
pub use model::{DecodeOut, KvCache, ModelRuntime, RuntimeStats};
#[cfg(feature = "pjrt")]
pub use warmup::{plan_keys, warm_for};

#[cfg(feature = "pjrt")]
use std::sync::Arc;

#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Shared PJRT client. One per process.
#[cfg(feature = "pjrt")]
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client: Arc::new(client) })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
