//! Runtime: the PJRT bridge. Loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once (lazily, memoized), keeps
//! model parameters device-resident, and executes decode/prefill/logits
//! steps from the serving hot path — python is never involved at runtime.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute_b` (device buffers in, device buffers out).

pub mod artifact;
pub mod model;
pub mod warmup;

pub use artifact::{ArtifactsIndex, ExeKey, ExeKind, Manifest};
pub use model::{DecodeOut, KvCache, ModelRuntime, RuntimeStats};
pub use warmup::{plan_keys, warm_for};

use std::sync::Arc;

use anyhow::Result;

/// Shared PJRT client. One per process.
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client: Arc::new(client) })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
