//! Per-model runtime: device-resident parameters + lazily compiled
//! executable registry + typed prefill/decode/logits entrypoints.
//!
//! Threading model: a `ModelRuntime` is built on — and then owned by —
//! exactly one coordinator worker thread (`engine::Backend: Send`, not
//! `Sync`); the router funnels requests to the workers over channels
//! (see `coordinator::router` / `coordinator::worker`). The executable
//! registry is `Arc`-backed so the owning thread can move across spawn
//! boundaries; interior mutability stays `RefCell` because no two
//! threads ever share one instance.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtLoadedExecutable};

use crate::engine::types::SpecialTokens;
use crate::engine::Backend;

use super::artifact::{ExeKey, ExeKind, Manifest};
use super::Runtime;

pub use crate::engine::types::DecodeOut;

/// Execution counters — the NFE/compute accounting the benches report.
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub prefill_calls: u64,
    pub decode_calls: u64,
    pub logits_calls: u64,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub logits_secs: f64,
    pub compile_count: u64,
    pub compile_secs: f64,
    /// Σ (batch · bucket) per kind — a FLOP-proportional cost proxy.
    pub prefill_cells: u64,
    pub decode_cells: u64,
    pub logits_cells: u64,
}

impl RuntimeStats {
    pub fn total_calls(&self) -> u64 {
        self.prefill_calls + self.decode_calls + self.logits_calls
    }

    pub fn total_model_secs(&self) -> f64 {
        self.prefill_secs + self.decode_secs + self.logits_secs
    }
}

/// A device-resident KV cache: [NL, 2, B, H, P, Dh] f32 produced by
/// `prefill` and consumed by `decode` without a host round-trip.
pub struct KvCache {
    pub buffer: PjRtBuffer,
    pub batch: usize,
    pub p_bucket: usize,
    /// live prefix length per row (≤ p_bucket)
    pub valid: Vec<i32>,
    /// device copy of `valid`, uploaded once at prefill time — decode
    /// steps reuse it instead of re-uploading every step (§Perf: saves
    /// one host→device transfer per diffusion step).
    pub valid_buf: PjRtBuffer,
}

pub struct ModelRuntime {
    rt: Runtime,
    pub manifest: Manifest,
    params: Vec<PjRtBuffer>,
    exes: RefCell<HashMap<ExeKey, Arc<PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl ModelRuntime {
    /// Load a model: parse manifest, upload params.npz to the device.
    pub fn load(rt: &Runtime, model_dir: &std::path::Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(model_dir)?;
        let named = Literal::read_npz(&manifest.params_file, &())
            .with_context(|| format!("reading {}", manifest.params_file.display()))?;
        let by_name: HashMap<String, Literal> = named.into_iter().collect();
        let mut params = Vec::with_capacity(manifest.param_order.len());
        for spec in &manifest.param_order {
            let lit = by_name
                .get(&spec.name)
                .ok_or_else(|| anyhow!("params.npz missing '{}'", spec.name))?;
            let elems: usize = spec.shape.iter().product();
            if lit.element_count() != elems {
                bail!(
                    "param '{}' has {} elements, manifest says {:?}",
                    spec.name,
                    lit.element_count(),
                    spec.shape
                );
            }
            // NOTE: upload via buffer_from_host_buffer, which uses
            // kImmutableOnlyDuringCall semantics (copy completes before
            // returning). buffer_from_host_literal is ASYNC in the
            // underlying PJRT CPU client and would read the Literal's
            // memory after we drop it — a use-after-free segfault.
            let host: Vec<f32> = lit.to_vec::<f32>()?;
            params.push(rt.client().buffer_from_host_buffer(&host, &spec.shape, None)?);
        }
        Ok(ModelRuntime {
            rt: rt.clone(),
            manifest,
            params,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RuntimeStats::default();
    }

    /// Pre-compile a set of keys (startup warmup; otherwise lazy).
    pub fn warm(&self, keys: &[ExeKey]) -> Result<()> {
        for &k in keys {
            self.executable(k)?;
        }
        Ok(())
    }

    fn executable(&self, key: ExeKey) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&key) {
            return Ok(e.clone());
        }
        let entry = self.manifest.entry(key)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.rt.client().compile(&comp)?);
        let mut st = self.stats.borrow_mut();
        st.compile_count += 1;
        st.compile_secs += t0.elapsed().as_secs_f64();
        drop(st);
        self.exes.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.rt.client().buffer_from_host_buffer(data, dims, None)?)
    }

    fn run(&self, exe: &PjRtLoadedExecutable, inputs: &[&PjRtBuffer]) -> Result<PjRtBuffer> {
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(self.params.len() + inputs.len());
        args.extend(self.params.iter());
        args.extend(inputs.iter().copied());
        let mut out = exe.execute_b(&args)?;
        let mut first = out
            .pop()
            .ok_or_else(|| anyhow!("no output device list"))?;
        if !out.is_empty() {
            bail!("unexpected multi-device output");
        }
        first.pop().ok_or_else(|| anyhow!("empty output buffer list"))
    }

    /// Prefix forward. `tokens`/`pos` are row-major [B, p_bucket]
    /// (pre-padded by the caller), `valid` the live length per row,
    /// `p0` the per-row prompt length (block-causal models only).
    pub fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<KvCache> {
        debug_assert_eq!(tokens.len(), batch * p_bucket);
        let key = ExeKey { kind: ExeKind::Prefill, batch, len: p_bucket, query: 0 };
        let exe = self.executable(key)?;
        let t_buf = self.buf_i32(tokens, &[batch, p_bucket])?;
        let p_buf = self.buf_i32(pos, &[batch, p_bucket])?;
        let v_buf = self.buf_i32(valid, &[batch])?;
        let t0 = Instant::now();
        let out = if self.manifest.wants_p0 {
            let p0 = p0.ok_or_else(|| anyhow!("model '{}' needs p0", self.manifest.model))?;
            let p0_buf = self.buf_i32(p0, &[batch])?;
            self.run(&exe, &[&t_buf, &p_buf, &v_buf, &p0_buf])?
        } else {
            self.run(&exe, &[&t_buf, &p_buf, &v_buf])?
        };
        let mut st = self.stats.borrow_mut();
        st.prefill_calls += 1;
        st.prefill_secs += t0.elapsed().as_secs_f64();
        st.prefill_cells += (batch * p_bucket) as u64;
        Ok(KvCache { buffer: out, batch, p_bucket, valid: valid.to_vec(), valid_buf: v_buf })
    }

    /// One diffusion decode step over the query bundle.
    pub fn decode(
        &self,
        kv: &KvCache,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        let batch = kv.batch;
        debug_assert_eq!(q_tok.len(), batch * q_bucket);
        let key = ExeKey { kind: ExeKind::Decode, batch, len: kv.p_bucket, query: q_bucket };
        let exe = self.executable(key)?;
        let qt = self.buf_i32(q_tok, &[batch, q_bucket])?;
        let qp = self.buf_i32(q_pos, &[batch, q_bucket])?;
        let qv = self.buf_i32(q_valid, &[batch])?;
        let t0 = Instant::now();
        let out = self.run(&exe, &[&kv.buffer, &qt, &qp, &kv.valid_buf, &qv])?;
        let lit = out.to_literal_sync()?;
        let data = lit.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.decode_calls += 1;
        st.decode_secs += t0.elapsed().as_secs_f64();
        st.decode_cells += (batch * (kv.p_bucket + q_bucket)) as u64;
        debug_assert_eq!(data.len(), batch * q_bucket * 2);
        Ok(DecodeOut { data, batch, q: q_bucket })
    }

    /// Full-sequence forward (vanilla baseline): packed [B, S, 2].
    pub fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        debug_assert_eq!(tokens.len(), batch * s_bucket);
        let key = ExeKey { kind: ExeKind::Logits, batch, len: s_bucket, query: 0 };
        let exe = self.executable(key)?;
        let t_buf = self.buf_i32(tokens, &[batch, s_bucket])?;
        let p_buf = self.buf_i32(pos, &[batch, s_bucket])?;
        let v_buf = self.buf_i32(valid, &[batch])?;
        let t0 = Instant::now();
        let out = if self.manifest.wants_p0 {
            let p0 = p0.ok_or_else(|| anyhow!("model '{}' needs p0", self.manifest.model))?;
            let p0_buf = self.buf_i32(p0, &[batch])?;
            self.run(&exe, &[&t_buf, &p_buf, &v_buf, &p0_buf])?
        } else {
            self.run(&exe, &[&t_buf, &p_buf, &v_buf])?
        };
        let lit = out.to_literal_sync()?;
        let data = lit.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.logits_calls += 1;
        st.logits_secs += t0.elapsed().as_secs_f64();
        st.logits_cells += (batch * s_bucket) as u64;
        Ok(DecodeOut { data, batch, q: s_bucket })
    }
}

/// The production `engine::Backend`: bucket selection and tokenizer
/// views come from the manifest, forwards run on PJRT.
impl Backend for ModelRuntime {
    type Kv = KvCache;

    fn special(&self) -> SpecialTokens {
        self.manifest.special.clone()
    }

    fn wants_p0(&self) -> bool {
        self.manifest.wants_p0
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.manifest.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.manifest.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.manifest.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.manifest.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<KvCache> {
        ModelRuntime::prefill(self, batch, p_bucket, tokens, pos, valid, p0)
    }

    fn decode(
        &self,
        kv: &KvCache,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> Result<DecodeOut> {
        ModelRuntime::decode(self, kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> Result<DecodeOut> {
        ModelRuntime::logits(self, batch, s_bucket, tokens, pos, valid, p0)
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        self.manifest.detokenize_until_eos(ids)
    }

    fn compile_secs(&self) -> f64 {
        self.stats.borrow().compile_secs
    }
}
