//! Warmup planner: pre-compile the executables a (config, workload)
//! combination will touch, so the first request doesn't pay lazy
//! compilation (the same cost EXPERIMENTS.md excludes from serving
//! metrics — this is the mechanism that makes the exclusion honest in
//! deployment).

use anyhow::Result;

use crate::engine::GenConfig;

use super::artifact::{ExeKey, ExeKind};
use super::model::ModelRuntime;

/// Compute the executable keys a generation with `cfg` can touch for
/// prompts up to `max_prompt_len`, at batch bucket `batch`.
pub fn plan_keys(
    rt: &ModelRuntime,
    cfg: &GenConfig,
    max_prompt_len: usize,
    batch: usize,
) -> Result<Vec<ExeKey>> {
    let man = &rt.manifest;
    let batch = man
        .pick_batch(batch)
        .ok_or_else(|| anyhow::anyhow!("batch {batch} exceeds buckets"))?;
    let k = cfg.block_size;
    let n_blocks = cfg.n_blocks();
    let mut keys = std::collections::BTreeSet::new();

    if !cfg.uses_cache() {
        let s = man
            .pick_seq(max_prompt_len + cfg.gen_len)
            .ok_or_else(|| anyhow::anyhow!("seq exceeds buckets"))?;
        keys.insert(ExeKey { kind: ExeKind::Logits, batch, len: s, query: 0 });
    } else {
        for blk in 0..n_blocks {
            let p_need = (max_prompt_len + blk * k).max(1);
            let p = man
                .pick_prefix(p_need)
                .ok_or_else(|| anyhow::anyhow!("prefix {p_need} exceeds buckets"))?;
            keys.insert(ExeKey { kind: ExeKind::Prefill, batch, len: p, query: 0 });
            // query-bundle size this block produces under the spatial
            // policy (exact per-block length, not the worst case)
            let suffix_len = cfg.gen_len - (blk + 1) * k;
            let q_need = cfg.policy.spatial.bundle_len_at(blk, n_blocks, k, suffix_len).max(1);
            let q = man
                .pick_query(q_need)
                .ok_or_else(|| anyhow::anyhow!("query {q_need} exceeds buckets"))?;
            keys.insert(ExeKey { kind: ExeKind::Decode, batch, len: p, query: q });
        }
    }
    Ok(keys.into_iter().collect())
}

/// Plan + compile. Returns how many executables were compiled.
pub fn warm_for(
    rt: &ModelRuntime,
    cfg: &GenConfig,
    max_prompt_len: usize,
    batch: usize,
) -> Result<usize> {
    let keys = plan_keys(rt, cfg, max_prompt_len, batch)?;
    let before = rt.stats().compile_count;
    rt.warm(&keys)?;
    Ok((rt.stats().compile_count - before) as usize)
}
