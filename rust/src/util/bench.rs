//! Bench harness (no `criterion` in the offline toolchain).
//!
//! Each `rust/benches/*.rs` target sets `harness = false` and drives this:
//! warmup, timed repetitions, mean ± std reporting, and the paper-style
//! table printer (accuracy on top, tokens/s + speedup below) that every
//! tableN bench uses so EXPERIMENTS.md rows can be pasted verbatim.

use std::time::Instant;

use super::stats::Welford;

/// Time `f` over `reps` repetitions after `warmup` unmeasured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Welford {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        w.push(t0.elapsed().as_secs_f64());
    }
    w
}

/// One cell of a paper-style table: accuracy + throughput + latency.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub accuracy: f64,     // percent, exact match (paper metric)
    pub cot_sim: f64,      // percent, partial-credit CoT similarity
    pub tokens_per_s: f64, // non-EOS tokens / wall second (paper metric)
    pub latency_s: f64,    // mean per-sample latency
    pub nfe: f64,          // mean model evaluations per sample
}

/// A table row: one (benchmark, gen-length) setting across methods.
pub struct Row {
    pub label: String,
    pub cells: Vec<(String, Cell)>, // (method name, cell)
}

/// Print rows the way the paper formats Tables 1/2/8: accuracy on the
/// first line, `tokens/s (speedup×)` on the second, with the first method
/// as the 1× baseline.
pub fn print_table(title: &str, rows: &[Row]) {
    println!("\n=== {title} ===");
    if rows.is_empty() {
        return;
    }
    let methods: Vec<&str> = rows[0].cells.iter().map(|(m, _)| m.as_str()).collect();
    let width = 22usize;
    print!("{:<28}", "benchmark");
    for m in &methods {
        print!("{m:<width$}");
    }
    println!();
    for row in rows {
        let base_tps = row.cells.first().map(|(_, c)| c.tokens_per_s).unwrap_or(1.0);
        print!("{:<28}", row.label);
        for (_, c) in &row.cells {
            // exact-match (partial-credit CoT similarity)
            print!("{:<width$}", format!("{:.1} ({:.0})", c.accuracy, c.cot_sim));
        }
        println!();
        print!("{:<28}", "");
        for (_, c) in &row.cells {
            let speedup = if base_tps > 0.0 { c.tokens_per_s / base_tps } else { 0.0 };
            print!("{:<width$}", format!("{:.1} ({:.1}x)", c.tokens_per_s, speedup));
        }
        println!();
    }
}

/// Latency variant (paper Tables 9/10/11): seconds + speedup (inverse).
pub fn print_latency_table(title: &str, rows: &[Row]) {
    println!("\n=== {title} (latency s/sample) ===");
    if rows.is_empty() {
        return;
    }
    let methods: Vec<&str> = rows[0].cells.iter().map(|(m, _)| m.as_str()).collect();
    let width = 22usize;
    print!("{:<28}", "benchmark");
    for m in &methods {
        print!("{m:<width$}");
    }
    println!();
    for row in rows {
        let base = row.cells.first().map(|(_, c)| c.latency_s).unwrap_or(1.0);
        print!("{:<28}", row.label);
        for (_, c) in &row.cells {
            let speedup = if c.latency_s > 0.0 { base / c.latency_s } else { 0.0 };
            print!("{:<width$}", format!("{:.2}s ({:.1}x)", c.latency_s, speedup));
        }
        println!();
    }
}

/// Machine-readable dump next to the human table (picked up by
/// EXPERIMENTS.md tooling and the fig1 scatter bench).
pub fn rows_to_json(rows: &[Row]) -> super::json::Json {
    use super::json::Json;
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("label", Json::Str(r.label.clone())),
                    (
                        "cells",
                        Json::Arr(
                            r.cells
                                .iter()
                                .map(|(m, c)| {
                                    Json::obj(vec![
                                        ("method", Json::Str(m.clone())),
                                        ("accuracy", Json::Num(c.accuracy)),
                                        ("cot_sim", Json::Num(c.cot_sim)),
                                        ("tokens_per_s", Json::Num(c.tokens_per_s)),
                                        ("latency_s", Json::Num(c.latency_s)),
                                        ("nfe", Json::Num(c.nfe)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// Write the JSON dump under target/bench-results/ (best effort). The
/// `BENCH_` prefix is the contract with CI's bench-smoke job, which
/// uploads `target/bench-results/BENCH_*.json` as run artifacts so the
/// perf trajectory accumulates across commits.
pub fn save_rows(name: &str, rows: &[Row]) {
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("BENCH_{name}.json"));
    let _ = std::fs::write(&path, rows_to_json(rows).to_string());
    println!("[saved {}]", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_reps() {
        let mut n = 0;
        let w = time_fn(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn rows_json_shape() {
        let cell =
            Cell { accuracy: 50.0, cot_sim: 70.0, tokens_per_s: 2.0, latency_s: 1.0, nfe: 64.0 };
        let rows = vec![Row { label: "gsm 64".into(), cells: vec![("vanilla".into(), cell)] }];
        let j = rows_to_json(&rows);
        let s = j.to_string();
        assert!(s.contains("vanilla") && s.contains("gsm 64"));
    }
}
