//! Minimal CLI argument parser (no `clap` in the offline toolchain).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; every binary in the workspace (main, examples, benches)
//! parses through this so `--help` output stays uniform.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    spec: Vec<(String, String, Option<String>)>, // (name, help, default)
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args`.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut a = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.options.insert(name.to_string(), v);
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn parse_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    /// Register an option for --help (fluent, optional).
    pub fn describe(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.spec.push((name.to_string(), help.to_string(), default.map(|s| s.to_string())));
        self
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Option value with an environment fallback: `--name` wins, then
    /// the `env` variable, then `default` (how `--ref-mode` layers over
    /// `SDLLM_REF_MODE`).
    pub fn get_env_or(&self, name: &str, env: &str, default: &str) -> String {
        match self.get(name) {
            Some(v) => v.to_string(),
            None => std::env::var(env).unwrap_or_else(|_| default.to_string()),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list option, e.g. `--lens 64,128`.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn help(&self, binary: &str, about: &str) -> String {
        let mut s = format!("{binary} — {about}\n\noptions:\n");
        for (name, help, default) in &self.spec {
            let d = default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{name:<18} {help}{d}\n"));
        }
        s
    }

    /// Print help and exit if --help was passed.
    pub fn handle_help(&self, binary: &str, about: &str) {
        if self.has_flag("help") {
            println!("{}", self.help(binary, about));
            std::process::exit(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--n", "10", "--model=llada-mini", "pos1", "--verbose"]);
        assert_eq!(a.get("n"), Some("10"));
        assert_eq!(a.get("model"), Some("llada-mini"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "10", "--tau", "0.85"]);
        assert_eq!(a.get_usize("n", 1), 10);
        assert!((a.get_f32("tau", 0.0) - 0.85).abs() < 1e-6);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn list_option() {
        let a = parse(&["--lens", "64, 128,256"]);
        assert_eq!(a.get_list("lens", &[]), vec!["64", "128", "256"]);
        assert_eq!(a.get_list("other", &["a"]), vec!["a"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn env_fallback_layers_cli_env_default() {
        // unique env var name: tests run in parallel within one process
        let var = "SDLLM_CLI_TEST_GET_ENV_OR";
        std::env::remove_var(var);
        let a = parse(&["--mode", "cli-wins"]);
        assert_eq!(a.get_env_or("mode", var, "dflt"), "cli-wins");
        assert_eq!(a.get_env_or("other", var, "dflt"), "dflt");
        std::env::set_var(var, "env-wins");
        assert_eq!(a.get_env_or("mode", var, "dflt"), "cli-wins");
        assert_eq!(a.get_env_or("other", var, "dflt"), "env-wins");
        std::env::remove_var(var);
    }
}
