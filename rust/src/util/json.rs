//! Minimal JSON parser / writer.
//!
//! The offline toolchain has no `serde_json`, so the artifact manifest,
//! eval JSONL files, server wire protocol and bench reports go through
//! this small self-contained implementation. It supports the full JSON
//! value model with the usual escapes; numbers are kept as f64 (all
//! manifest integers are well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but errors with the key name — manifest loading wants
    /// actionable messages, not silent Nones.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- construction helpers ----------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- parsing ------------------------------------------------------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- serialization --------------------------------------------------
    // (via `Display`, so `.to_string()` comes from the blanket impl)

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs unsupported (not produced by our writers)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x\n"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x\n")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    // The module now carries the server wire protocol and the CI bench
    // reports; the tests below pin the round-trip guarantees those rely
    // on: every value we *write* must parse back to an equal value.

    fn roundtrip(v: &Json) -> Json {
        Json::parse(&v.to_string()).unwrap_or_else(|e| panic!("re-parse failed: {e} on {v:?}"))
    }

    #[test]
    fn escape_roundtrip_exhaustive_controls() {
        // every C0 control plus the two mandatory escapes
        for cp in (0u32..0x20).chain(['"' as u32, '\\' as u32]) {
            let s: String = char::from_u32(cp).unwrap().to_string();
            let v = Json::Str(s.clone());
            assert_eq!(roundtrip(&v).as_str(), Some(s.as_str()), "codepoint {cp:#x}");
        }
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse("\"\\u0041\\u00e9\"").unwrap().as_str(), Some("Aé"));
        // lone bad escape is rejected, not mangled
        assert!(Json::parse("\"\\u00g1\"").is_err());
        assert!(Json::parse("\"\\u00\"").is_err());
    }

    #[test]
    fn nested_obj_arr_roundtrip() {
        let v = Json::obj(vec![
            (
                "rows",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("label", Json::Str("gsm \"quoted\"\n".into())),
                        ("cells", Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Bool(false)])),
                    ]),
                    Json::Arr(vec![]),
                    Json::Obj(Default::default()),
                ]),
            ),
            ("n", Json::Num(3.0)),
        ]);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn f64_edge_cases_roundtrip() {
        for x in [
            0.0,
            -1.0,
            0.1,
            1e-7,
            -2.5e10,
            1.5e300,
            f64::MIN_POSITIVE,
            (1u64 << 53) as f64,       // integer precision boundary
            ((1u64 << 53) - 1) as f64, // largest exact integer
            1e15,                      // integer-formatting threshold
            1e15 + 2.0,
            0.30000000000000004, // classic accumulation artifact
        ] {
            let v = Json::Num(x);
            let back = roundtrip(&v).as_f64().unwrap();
            assert_eq!(back, x, "value {x:e} did not survive the wire");
        }
    }

    #[test]
    fn exponent_forms_parse() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("1E-2").unwrap().as_f64(), Some(0.01));
        assert_eq!(Json::parse("-1.25e+2").unwrap().as_f64(), Some(-125.0));
    }

    #[test]
    fn large_integers_stay_integral_on_the_wire() {
        // ids/counters are u64-as-f64; below 2^53 they serialize without
        // a fraction and re-parse exactly
        let v = Json::Num(9007199254740991.0); // 2^53 - 1 — above the 1e15 pretty-print cutoff
        let s = v.to_string();
        assert!(!s.contains('.'), "unexpected fraction in {s}");
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut v = Json::Num(7.0);
        for _ in 0..64 {
            v = Json::Arr(vec![v]);
        }
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn key_ordering_is_stable() {
        let a = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"b":1}"#).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.to_string(), b.to_string());
    }
}
