//! Minimal JSON parser / writer.
//!
//! The offline toolchain has no `serde_json`, so the artifact manifest,
//! eval JSONL files, server wire protocol and bench reports go through
//! this small self-contained implementation. It supports the full JSON
//! value model with the usual escapes; numbers are kept as f64 (all
//! manifest integers are well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but errors with the key name — manifest loading wants
    /// actionable messages, not silent Nones.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- construction helpers ----------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- parsing ------------------------------------------------------

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- serialization --------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs unsupported (not produced by our writers)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x\n"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x\n")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }
}
