//! Substrate utilities the offline toolchain forces us to own: JSON,
//! CLI parsing, seeded PRNG, property testing, stats, bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
