//! Mini property-testing framework (no `proptest` in the offline
//! toolchain). Seeded, with failure-case shrinking for the common input
//! shapes the coordinator invariants are stated over (integers, vectors).
//!
//! Usage:
//! ```ignore
//! prop::check(200, |g| {
//!     let k = g.usize(1, 64);
//!     let xs = g.vec_usize(0, 100, 0..50);
//!     // ... assert invariant, return Err(msg) on violation
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Input generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Log of drawn scalars (for reporting failing cases).
    pub trace: Vec<(String, String)>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: vec![] }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.trace.push(("usize".into(), v.to_string()));
        v
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + (hi - lo) * self.rng.f32();
        self.trace.push(("f32".into(), v.to_string()));
        v
    }

    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.bool(p);
        self.trace.push(("bool".into(), v.to_string()));
        v
    }

    pub fn vec_usize(
        &mut self,
        lo: usize,
        hi: usize,
        len_range: std::ops::Range<usize>,
    ) -> Vec<usize> {
        let hi_len = len_range.end.saturating_sub(1).max(len_range.start);
        let n = self.rng.range(len_range.start, hi_len);
        let v: Vec<usize> = (0..n).map(|_| self.rng.range(lo, hi)).collect();
        self.trace.push(("vec_usize".into(), format!("{v:?}")));
        v
    }

    pub fn vec_f32(&mut self, lo: f32, hi: f32, len_range: std::ops::Range<usize>) -> Vec<f32> {
        let hi_len = len_range.end.saturating_sub(1).max(len_range.start);
        let n = self.rng.range(len_range.start, hi_len);
        let v: Vec<f32> = (0..n).map(|_| lo + (hi - lo) * self.rng.f32()).collect();
        self.trace.push(("vec_f32".into(), format!("{v:?}")));
        v
    }

    /// Raw access for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`; panic with the seed and the drawn
/// inputs on the first failure. Seeds are deterministic per call site via
/// `base_seed`, so failures reproduce.
pub fn check_seeded<F>(base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(1_000_003).wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed (case {case}, seed {seed}): {msg}\ndrawn inputs: {:?}",
                g.trace
            );
        }
    }
}

/// Default-seed variant.
pub fn check<F>(cases: usize, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(0xD11A_5EED, cases, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(100, |g| {
            let a = g.usize(0, 100);
            let b = g.usize(0, 100);
            if a + b >= a {
                Ok(())
            } else {
                Err("addition broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(100, |g| {
            let a = g.usize(0, 100);
            if a < 90 {
                Ok(())
            } else {
                Err(format!("a too big: {a}"))
            }
        });
    }

    #[test]
    fn deterministic_draws() {
        let mut first = vec![];
        check_seeded(7, 5, |g| {
            first.push(g.usize(0, 1000));
            Ok(())
        });
        let mut second = vec![];
        check_seeded(7, 5, |g| {
            second.push(g.usize(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
