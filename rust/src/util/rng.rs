//! Seeded PRNG (xoshiro256**) — deterministic workloads, batching jitter
//! and the property-testing framework all draw from this. No `rand` crate
//! in the offline toolchain, so this is self-contained.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the xoshiro state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }
}
