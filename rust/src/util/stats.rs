//! Small statistics helpers: online mean/variance (Welford), percentile
//! summaries, and histogram-ish latency recording for the metrics layer
//! and the bench harness.

#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Collects samples and answers percentile queries. Used for latency
/// distributions; sample counts here are small (≤ thousands), so an exact
/// sorted-vector implementation is the right tool.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    /// Nearest-rank percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.xs.len() as f64 - 1.0)).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }
}

/// Interquartile-range summary of a series — the paper's Figures 3/7–14
/// plot mean + IQR(25–75%) per diffusion step; `fig3_confidence` uses this.
pub fn mean_iqr(xs: &[f64]) -> (f64, f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| {
        let rank = (p * (v.len() as f64 - 1.0)).round() as usize;
        v[rank.min(v.len() - 1)]
    };
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    (mean, q(0.25), q(0.75))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        let p50 = s.percentile(50.0);
        assert!((49.0..=51.0).contains(&p50));
    }

    #[test]
    fn iqr_summary() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        let (mean, q25, q75) = mean_iqr(&xs);
        assert!((mean - 50.0).abs() < 1e-9);
        assert!((q25 - 25.0).abs() <= 1.0);
        assert!((q75 - 75.0).abs() <= 1.0);
    }

    #[test]
    fn empty_safe() {
        let mut s = Samples::new();
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(mean_iqr(&[]), (0.0, 0.0, 0.0));
    }
}
