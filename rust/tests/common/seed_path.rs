//! A faithful replica of the seed (pre-workspace) decode hot path:
//! fresh bundle/candidate/host-buffer allocations every step, the
//! `SeqState` clone round-trip per batch, and the block-lockstep march.
//!
//! Shared (via `#[path]`) between `tests/parity.rs` — which pins the
//! production workspace core bit-identical to this — and
//! `benches/host_overhead.rs`, which measures it as the `before` arm.
//! Keep it byte-for-byte equivalent to the code the workspace refactor
//! deleted; any behavioral edit here invalidates both the parity pins
//! and the before/after comparison.
#![allow(dead_code)]

use anyhow::{bail, Result};
use streaming_dllm::engine::{
    build_bundle, bundle_tokens, select, Backend, Candidate, GenConfig, Method, SeqState,
    TemporalPolicy,
};

pub struct SeedReport {
    pub steps: u64,
    pub prefills: u64,
}

fn sanitize(tok: i32, mask: i32, pad: i32, eos: i32) -> i32 {
    if tok == mask || tok == pad {
        eos
    } else {
        tok
    }
}

pub fn generate<B: Backend>(rt: &B, cfg: &GenConfig, seqs: &mut [SeqState]) -> Result<SeedReport> {
    let mut report = SeedReport { steps: 0, prefills: 0 };
    if seqs.is_empty() {
        return Ok(report);
    }
    let batch = rt.pick_batch(seqs.len()).expect("batch bucket");
    let special = rt.special();
    let gen_len = cfg.gen_len;
    let mut all: Vec<SeqState> = Vec::with_capacity(batch);
    let n_real = seqs.len();
    for s in seqs.iter() {
        all.push(s.clone());
    }
    for _ in n_real..batch {
        all.push(SeqState::new(&[special.bos], gen_len, &special));
    }
    match cfg.method {
        Method::Vanilla => run_vanilla(rt, cfg, &mut all, &mut report)?,
        _ => run_cached(rt, cfg, &mut all, &mut report)?,
    }
    for (dst, src) in seqs.iter_mut().zip(all.iter()) {
        *dst = src.clone();
    }
    Ok(report)
}

fn run_vanilla<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    seqs: &mut [SeqState],
    report: &mut SeedReport,
) -> Result<()> {
    let batch = seqs.len();
    let k = cfg.block_size;
    let s_need = seqs.iter().map(|s| s.total_len()).max().unwrap();
    let s_bucket = rt.pick_seq(s_need).expect("seq bucket");
    let special = rt.special();

    let mut tokens = vec![special.pad; batch * s_bucket];
    let mut pos = vec![0i32; batch * s_bucket];
    let mut valid = vec![0i32; batch];
    let mut p0s = vec![0i32; batch];
    for (b, s) in seqs.iter().enumerate() {
        valid[b] = s.total_len() as i32;
        p0s[b] = s.p0 as i32;
        for j in 0..s_bucket {
            pos[b * s_bucket + j] = j as i32;
        }
    }

    let n_blocks = cfg.n_blocks();
    let max_steps = (n_blocks * k * 4) as u64 + 8;
    let mut guard = 0u64;
    while seqs.iter().any(|s| !s.finished) {
        guard += 1;
        if guard > max_steps {
            bail!("vanilla decode failed to terminate");
        }
        for (b, s) in seqs.iter().enumerate() {
            for (j, &t) in s.tokens.iter().enumerate() {
                tokens[b * s_bucket + j] = t;
            }
            for j in s.tokens.len()..s_bucket {
                tokens[b * s_bucket + j] = special.pad;
            }
        }
        let out = rt.logits(
            batch,
            s_bucket,
            &tokens,
            &pos,
            &valid,
            if rt.wants_p0() { Some(&p0s) } else { None },
        )?;
        report.steps += 1;

        for (b, s) in seqs.iter_mut().enumerate() {
            if s.finished {
                continue;
            }
            let masked = s.masked_in_block(k);
            if masked.is_empty() {
                s.block += 1;
                if s.block >= n_blocks {
                    s.finished = true;
                }
                continue;
            }
            let cands: Vec<Candidate> = masked
                .iter()
                .map(|&p| Candidate {
                    pos: p,
                    token: sanitize(out.token(b, p), special.mask, special.pad, special.eos),
                    conf: out.conf(b, p),
                })
                .collect();
            for i in select(&TemporalPolicy::OnePerStep, 1.0, &cands, &[]) {
                s.commit_with_conf(cands[i].pos, cands[i].token, cands[i].conf);
            }
            s.steps += 1;
            if s.block_done(k) {
                s.block += 1;
                if s.block >= n_blocks {
                    s.finished = true;
                }
            }
        }
    }
    Ok(())
}

fn run_cached<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    seqs: &mut [SeqState],
    report: &mut SeedReport,
) -> Result<()> {
    let k = cfg.block_size;
    let n_blocks = cfg.n_blocks();
    let early_exit = cfg.method == Method::Streaming && cfg.early_exit;

    for _blk in 0..n_blocks {
        if seqs.iter().all(|s| s.finished) {
            break;
        }
        let mut kv = prefill_block(rt, cfg, seqs)?;
        report.prefills += 1;

        let mut step_in_block = 0usize;
        let guard_max = k * 4 + 8 + if cfg.remask { k } else { 0 };
        loop {
            let any_masked = seqs.iter().any(|s| !s.finished && !s.block_done(k));
            if !any_masked {
                break;
            }
            if step_in_block > guard_max {
                bail!("block decode failed to terminate");
            }
            if cfg.method == Method::DkvCache
                && step_in_block > 0
                && step_in_block % cfg.dkv_refresh == 0
            {
                kv = prefill_block(rt, cfg, seqs)?;
                report.prefills += 1;
            }
            decode_step(rt, cfg, seqs, &kv, early_exit, report)?;
            step_in_block += 1;
        }

        for s in seqs.iter_mut() {
            if s.finished {
                continue;
            }
            if early_exit && s.block_all_eos(k) {
                s.finish_with_eos();
                continue;
            }
            s.block += 1;
            if s.block >= n_blocks {
                s.finished = true;
            }
        }
    }
    Ok(())
}

fn prefill_block<B: Backend>(rt: &B, cfg: &GenConfig, seqs: &[SeqState]) -> Result<B::Kv> {
    let batch = seqs.len();
    let k = cfg.block_size;
    let special = rt.special();
    let p_need = seqs
        .iter()
        .map(|s| if s.finished { 1 } else { s.p0 + s.block * k })
        .max()
        .unwrap()
        .max(1);
    let p_bucket = rt.pick_prefix(p_need).expect("prefix bucket");

    let mut tokens = vec![special.pad; batch * p_bucket];
    let mut pos = vec![0i32; batch * p_bucket];
    let mut valid = vec![1i32; batch];
    let mut p0s = vec![0i32; batch];
    for (b, s) in seqs.iter().enumerate() {
        let plen = if s.finished { 1 } else { s.p0 + s.block * k };
        valid[b] = plen as i32;
        p0s[b] = s.p0 as i32;
        for j in 0..p_bucket {
            pos[b * p_bucket + j] = j as i32;
        }
        for j in 0..plen.min(s.tokens.len()) {
            tokens[b * p_bucket + j] = s.tokens[j];
        }
    }
    rt.prefill(
        batch,
        p_bucket,
        &tokens,
        &pos,
        &valid,
        if rt.wants_p0() { Some(&p0s) } else { None },
    )
}

fn decode_step<B: Backend>(
    rt: &B,
    cfg: &GenConfig,
    seqs: &mut [SeqState],
    kv: &B::Kv,
    early_exit: bool,
    report: &mut SeedReport,
) -> Result<()> {
    let batch = seqs.len();
    let k = cfg.block_size;
    let special = rt.special();

    let bundles: Vec<_> = seqs.iter().map(|s| build_bundle(s, cfg)).collect();
    let q_need = bundles.iter().map(|b| b.positions.len()).max().unwrap().max(1);
    let q_bucket = rt.pick_query(q_need).expect("query bucket");

    let mut q_tok = vec![special.mask; batch * q_bucket];
    let mut q_pos = vec![0i32; batch * q_bucket];
    let mut q_valid = vec![0i32; batch];
    for (b, s) in seqs.iter().enumerate() {
        let bun = &bundles[b];
        q_valid[b] = bun.positions.len() as i32;
        let toks = bundle_tokens(s, bun);
        for (j, (&p, &t)) in bun.positions.iter().zip(toks.iter()).enumerate() {
            q_tok[b * q_bucket + j] = t;
            q_pos[b * q_bucket + j] = p as i32;
        }
    }

    let out = rt.decode(kv, q_bucket, &q_tok, &q_pos, &q_valid)?;
    report.steps += 1;

    for (b, s) in seqs.iter_mut().enumerate() {
        if s.finished || s.block_done(k) {
            continue;
        }
        let bun = &bundles[b];
        let r_mask = s.mask_ratio(k);
        let mut cands = Vec::with_capacity(bun.block_len);
        for j in 0..bun.block_len {
            let abs = bun.positions[j];
            if s.is_masked(abs) {
                cands.push(Candidate {
                    pos: abs,
                    token: sanitize(out.token(b, j), special.mask, special.pad, special.eos),
                    conf: out.conf(b, j),
                });
            }
        }
        if cands.is_empty() {
            continue;
        }
        let picked = select(&cfg.policy.temporal, r_mask, &cands, &[]);
        for &i in &picked {
            s.commit_with_conf(cands[i].pos, cands[i].token, cands[i].conf);
        }
        if cfg.remask && !s.block_done(k) {
            s.remask_low_confidence(k, cfg.remask_tau);
        }
        s.steps += 1;
        if early_exit && s.early_exit_scan(k) {
            s.finish_with_eos();
        }
    }
    Ok(())
}
