//! Golden-oracle regression tests: the reference oracles are pinned as
//! literal strings so an accidental change to the hash functions, the
//! RNG, the vocabulary layout or the signature window can't silently
//! shift every synthesized suite's expected answers (which would make
//! accuracy trends incomparable across commits). If one of these fails
//! after an *intentional* oracle change, update the literals — and
//! expect every accuracy trajectory in `BENCH_*.json` to reset.

use streaming_dllm::engine::{
    GenConfig, Generator, Method, ReferenceBackend, SeqState, REFERENCE_SEED,
};
use streaming_dllm::eval::{extract_final, synthetic_suite};

const PROMPTS: [&[i32]; 4] = [
    &[2, 10, 11, 12],
    &[2, 15, 16, 17, 18, 19],
    &[2, 20, 21, 22, 23, 24, 25],
    &[2, 5, 6, 7, 47],
];

#[test]
fn toy_oracle_golden_reference_seed() {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let got: Vec<String> = PROMPTS.iter().map(|p| be.oracle_text(p)).collect();
    assert_eq!(got, ["e49262x0l687;86", "673g7;18", "8;30", "x7982561372;26"]);
}

#[test]
fn causal_oracle_golden_reference_seed() {
    let be = ReferenceBackend::causal(REFERENCE_SEED);
    let got: Vec<String> = PROMPTS.iter().map(|p| be.oracle_text(p)).collect();
    assert_eq!(got, ["e48738751l89;2j", "0n565;06", "8;43", "89975729t9p;52"]);
}

#[test]
fn oracle_golden_alt_seeds() {
    // the seed must actually steer the oracle (catches a regression
    // where the constructor drops or fixes the seed)
    for (seed, toy_want, causal_want) in [
        (1u64, "m8262z6a2a365;m3", "n6473437247s2;fw"),
        (42u64, "799n686;10", "63734ew;62"),
    ] {
        assert_eq!(ReferenceBackend::toy(seed).oracle_text(PROMPTS[0]), toy_want);
        assert_eq!(ReferenceBackend::causal(seed).oracle_text(PROMPTS[0]), causal_want);
    }
}

#[test]
fn synthetic_suite_first_item_golden() {
    // pins the prompt-generation RNG stream *and* the oracle in one
    // check: a change to either shifts every synthesized suite
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 1, 0x5eed);
    assert_eq!(
        items[0].prompt,
        vec![2, 40, 33, 17, 40, 29, 8, 31, 21, 8, 15, 32, 38, 38, 24, 9, 19, 23, 47]
    );
    assert_eq!(items[0].cot, "m2410;9s");
    assert_eq!(items[0].answer, "9s");
    assert_eq!(extract_final(&items[0].cot), items[0].answer);
}

#[test]
fn toy_decode_is_bit_identical_to_golden_oracles() {
    // schedule independence, end to end: a streaming decode over the
    // toy model must reproduce the pinned oracle byte for byte
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let golden = ["e49262x0l687;86", "673g7;18", "8;30", "x7982561372;26"];
    for (p, want) in PROMPTS.iter().zip(golden) {
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut generator = Generator::new(&be, cfg).unwrap();
        let mut seqs = vec![SeqState::new(p, 64, &be.special)];
        generator.generate(&mut seqs, None).unwrap();
        assert_eq!(be.detokenize(seqs[0].generated()), want);
    }
}
