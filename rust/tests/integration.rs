//! Integration tests, in three tiers so `cargo test` is green — and
//! loud about what it skipped — on any checkout:
//!
//! 1. Reference tier (always runs): end-to-end generation, eval scoring
//!    and the TCP serving stack over the deterministic pure-Rust
//!    reference backend. No artifacts, no xla.
//! 2. Artifact tier (runs when `artifacts/index.json` exists): manifest
//!    contract checks — still xla-free.
//! 3. PJRT tier (`--features pjrt` + artifacts): real runtime smoke
//!    over the AOT executables.

use std::time::{Duration, Instant};

use streaming_dllm::coordinator::{Client, Request, RouterHandle, Server};
use streaming_dllm::engine::{
    Backend, DecodeOut, DecodePolicy, GenConfig, Generator, Method, RefKv, RefMode,
    ReferenceBackend, SeqState, SpecialTokens, REFERENCE_SEED,
};
use streaming_dllm::eval::{extract_final, run_suite, synthetic_suite};
use streaming_dllm::runtime::{ArtifactsIndex, ExeKey, ExeKind, Manifest};

fn artifacts() -> Option<std::path::PathBuf> {
    let root = streaming_dllm::artifacts_root();
    if root.join("index.json").exists() {
        Some(root)
    } else {
        eprintln!(
            "SKIP: no artifacts at {} (run `make artifacts`); reference tier still runs",
            root.display()
        );
        None
    }
}

// ---------------------------------------------------------------------
// Tier 1: reference backend — always runs.
// ---------------------------------------------------------------------

#[test]
fn reference_all_methods_terminate_and_produce_text() {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 1, 42);
    for method in Method::all() {
        let cfg = GenConfig::preset(method, 64);
        let mut generator = Generator::new(&be, cfg).unwrap();
        let mut seqs = vec![SeqState::new(&items[0].prompt, 64, &be.special())];
        let report = generator.generate(&mut seqs, None).unwrap();
        assert!(seqs[0].finished, "{} did not finish", method.name());
        assert!(report.steps > 0);
        assert!(seqs[0].generated().iter().all(|&t| t != be.special().mask));
        let text = be.detokenize(seqs[0].generated());
        assert!(!text.is_empty(), "{} produced empty text", method.name());
    }
}

#[test]
fn reference_every_method_matches_the_oracle() {
    // The toy model is schedule-independent by construction: every
    // decode path must converge to the same text the oracle predicts.
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 8, 7);
    for method in Method::all() {
        let cfg = GenConfig::preset(method, 64);
        let res = run_suite(&be, &cfg, &items, None).unwrap();
        assert!(
            res.accuracy() > 99.0,
            "{} scored {:.1}% against the oracle",
            method.name(),
            res.accuracy()
        );
    }
}

#[test]
fn reference_streaming_uses_fewer_steps_than_vanilla() {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 3, 9);
    let mut steps = std::collections::HashMap::new();
    for method in [Method::Vanilla, Method::FastDllm, Method::Streaming] {
        let cfg = GenConfig::preset(method, 64);
        let mut generator = Generator::new(&be, cfg).unwrap();
        let mut total = 0u64;
        for item in &items {
            let mut seqs = vec![SeqState::new(&item.prompt, 64, &be.special())];
            let report = generator.generate(&mut seqs, None).unwrap();
            total += report.steps;
        }
        steps.insert(method.name(), total);
    }
    assert!(
        steps["streaming"] < steps["vanilla"],
        "streaming {} !< vanilla {}",
        steps["streaming"],
        steps["vanilla"]
    );
}

#[test]
fn reference_batched_generation_matches_single() {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 2, 11);
    let cfg = GenConfig::preset(Method::Streaming, 64);
    let mut generator = Generator::new(&be, cfg).unwrap();

    let mut singles = vec![];
    for item in &items {
        let mut seqs = vec![SeqState::new(&item.prompt, 64, &be.special())];
        generator.generate(&mut seqs, None).unwrap();
        singles.push(be.detokenize(seqs[0].generated()));
    }
    let mut seqs: Vec<SeqState> =
        items.iter().map(|it| SeqState::new(&it.prompt, 64, &be.special())).collect();
    generator.generate(&mut seqs, None).unwrap();
    let batched: Vec<String> = seqs.iter().map(|s| be.detokenize(s.generated())).collect();
    assert_eq!(singles, batched);
}

#[test]
fn causal_reference_sequential_decode_matches_oracle() {
    // one-per-step decoding only ever commits fully-determined
    // predictions, so it replays the causal chain — the AR-teacher
    // analogue the suite scores against
    let be = ReferenceBackend::causal(REFERENCE_SEED);
    let items = synthetic_suite(&be, 6, 17);
    let res = run_suite(&be, &GenConfig::preset(Method::PrefixCache, 64), &items, None).unwrap();
    assert!(res.accuracy() > 99.9, "sequential causal decode scored {:.1}%", res.accuracy());
}

#[test]
fn causal_reference_aggressive_decoding_trades_accuracy_for_steps() {
    // the headline behavior the toy mode cannot show: a low static
    // threshold commits guessed tokens whose masked predecessors make
    // them wrong, buying steps with accuracy
    let oracle = ReferenceBackend::causal(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 6, 17);
    let mut lo_cfg = GenConfig::preset(Method::FastDllm, 64);
    lo_cfg.set_tau0(0.5);
    let lo = run_suite(&ReferenceBackend::causal(REFERENCE_SEED), &lo_cfg, &items, None).unwrap();
    let hi_cfg = GenConfig::preset(Method::PrefixCache, 64);
    let hi = run_suite(&ReferenceBackend::causal(REFERENCE_SEED), &hi_cfg, &items, None).unwrap();
    assert!(lo.steps < hi.steps, "τ=0.5 should save steps: {} !< {}", lo.steps, hi.steps);
    assert!(lo.accuracy() < 60.0, "τ=0.5 should corrupt rows, got {:.1}%", lo.accuracy());
    assert!(hi.accuracy() > 99.9);
}

#[test]
fn causal_reference_server_serves_the_causal_oracle() {
    // the serve path must honor the reference mode: a causal-mode router
    // decoding sequentially (prefix-cache) replays the causal chain, so
    // served answers score against the causal suite — not the toy one
    let oracle = ReferenceBackend::causal(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 2, 23);
    let router = RouterHandle::spawn_reference_mode(RefMode::Causal, 2, Duration::from_millis(5));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));
    let mut client = Client::connect(&addr).unwrap();
    for (i, item) in items.iter().enumerate() {
        let resp = client
            .call(&Request {
                id: i as u64,
                prompt: item.prompt.clone(),
                method: Method::PrefixCache,
                policy: None,
                gen_len: 64,
                deadline_ms: None,
                park_on_miss: false,
            })
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(
            extract_final(&resp.text),
            item.answer,
            "served causal text diverged from the sequential oracle"
        );
    }
    drop(client);
    handle.join().unwrap().unwrap();
}

#[test]
fn detokenize_matches_python_rule() {
    // "a9;81" + EOS + junk — must stop at EOS and skip specials, the
    // `tokenizer.decode_until_eos` rule (ids fixed by the shared vocab).
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let ids = vec![15i32, 14, 46, 13, 6, 3, 20, 21];
    assert_eq!(be.detokenize(&ids), "a9;81");
    // extraction rule parity (mirrors python tasks.extract_final)
    assert_eq!(extract_final("a9;b81;81"), "81");
}

#[test]
fn reference_server_end_to_end_roundtrip() {
    let oracle = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 2, 13);
    let router = RouterHandle::spawn_reference(4, Duration::from_millis(5));
    let metrics = router.metrics.clone();
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.get("requests_ok").is_some());
    for (i, item) in items.iter().enumerate() {
        let resp = client
            .call(&Request {
                id: i as u64,
                prompt: item.prompt.clone(),
                method: Method::Streaming,
                policy: None,
                gen_len: 64,
                deadline_ms: None,
                park_on_miss: false,
            })
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(extract_final(&resp.text), item.answer, "served text diverged from oracle");
        assert!(resp.latency_s > 0.0);
    }
    drop(client);
    handle.join().unwrap().unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap.get("requests_ok").unwrap().as_usize(), Some(2));
    assert!(snap.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn connection_survives_unreadable_lines() {
    use std::io::{BufRead, BufReader, Write};
    // recoverable read problems (bad UTF-8, oversized line) answer a
    // typed error frame and the connection keeps serving; only hard IO
    // errors close it
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(5));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut read_line = |reader: &mut BufReader<std::net::TcpStream>| {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed the connection");
        line
    };

    stream.write_all(&[0xff, 0xfe, 0x80, b'\n']).unwrap();
    let frame = read_line(&mut reader);
    assert!(frame.contains("invalid utf-8"), "expected a utf-8 error frame, got {frame}");

    let mut huge = vec![b'{'; streaming_dllm::coordinator::MAX_LINE_BYTES + 2];
    huge.push(b'\n');
    stream.write_all(&huge).unwrap();
    let frame = read_line(&mut reader);
    assert!(frame.contains("line too long"), "expected an oversize error frame, got {frame}");

    // the same connection still serves real traffic afterwards
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    let frame = read_line(&mut reader);
    assert!(frame.contains("pong"), "expected a pong after recovery, got {frame}");
    let oracle = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 1, 77);
    let req = Request {
        id: 9,
        prompt: items[0].prompt.clone(),
        method: Method::Streaming,
        policy: None,
        gen_len: 64,
        deadline_ms: None,
        park_on_miss: false,
    };
    let mut line = req.to_json().to_string();
    line.push('\n');
    stream.write_all(line.as_bytes()).unwrap();
    let frame = read_line(&mut reader);
    assert!(
        frame.contains("\"text\""),
        "expected a served response after recovery, got {frame}"
    );

    drop(reader);
    drop(stream);
    handle.join().unwrap().unwrap();
}

#[test]
fn malformed_wire_policy_answers_typed_v1_error_and_connection_survives() {
    use std::io::{BufRead, BufReader, Write};
    // a bad policy field is a protocol-level error, not a served
    // failure: the server answers a v1 error frame attributed to the
    // request id and the connection keeps serving
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(5));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut read_line = |reader: &mut BufReader<std::net::TcpStream>| {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed the connection");
        line
    };

    // a policy naming no preset → typed unknown-policy error with the id
    stream
        .write_all(
            b"{\"v\":1,\"type\":\"generate\",\"id\":9,\"prompt\":[2],\
               \"policy\":\"bogus\"}\n",
        )
        .unwrap();
    let frame = read_line(&mut reader);
    assert!(frame.contains("\"type\":\"error\""), "expected a v1 error frame, got {frame}");
    assert!(frame.contains("\"id\":9"), "v1 errors carry the parsed request id: {frame}");
    assert!(frame.contains("unknown policy 'bogus'"), "typed message missing: {frame}");

    // a policy object missing its temporal axis → invalid-policy error
    stream
        .write_all(
            b"{\"v\":1,\"type\":\"generate\",\"id\":10,\"prompt\":[2],\
               \"policy\":{\"spatial\":{\"kind\":\"full\"}}}\n",
        )
        .unwrap();
    let frame = read_line(&mut reader);
    assert!(frame.contains("\"type\":\"error\""), "expected a v1 error frame, got {frame}");
    assert!(frame.contains("\"id\":10"), "v1 errors carry the parsed request id: {frame}");
    assert!(frame.contains("invalid policy"), "typed message missing: {frame}");

    // the same connection then serves a well-formed policy request
    stream
        .write_all(
            b"{\"v\":1,\"type\":\"generate\",\"id\":11,\"prompt\":[2,10,11],\
               \"gen_len\":64,\"policy\":\"attenuating\"}\n",
        )
        .unwrap();
    let frame = read_line(&mut reader);
    assert!(frame.contains("\"type\":\"done\""), "expected a served answer, got {frame}");
    assert!(!frame.contains("\"error\""), "served answer must carry no error: {frame}");

    drop(reader);
    drop(stream);
    handle.join().unwrap().unwrap();
}

#[test]
fn v0_lines_decode_with_the_servers_default_policy() {
    // a legacy v0 line (which cannot spell a policy field) served by a
    // fleet configured with `--policy` still parses and answers the
    // oracle text: the server fills its default policy into the request
    // and the decode runs under it
    let oracle = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 2, 67);
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(5));
    let server = Server::bind("127.0.0.1:0", router)
        .unwrap()
        .with_default_policy(DecodePolicy::parse("dropout"));
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let mut client = Client::connect(&addr).unwrap();
    for (i, item) in items.iter().enumerate() {
        let resp = client
            .call(&Request {
                id: i as u64,
                prompt: item.prompt.clone(),
                method: Method::Streaming,
                policy: None,
                gen_len: 64,
                deadline_ms: None,
                park_on_miss: false,
            })
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(extract_final(&resp.text), item.answer, "v0 answer under the default policy");
    }
    drop(client);
    handle.join().unwrap().unwrap();
}

#[test]
fn connection_cap_answers_busy_and_closes() {
    use std::io::{BufRead, BufReader};
    // over max_connections the server answers one v1 busy error frame
    // and closes instead of spawning an unbounded handler thread
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(5));
    let server = Server::bind("127.0.0.1:0", router).unwrap().with_max_connections(1);
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(2));

    // the first connection occupies the only slot (roundtrip proves the
    // handler is live before the second connection races it)
    let mut first = Client::connect(&addr).unwrap();
    assert!(first.stats().unwrap().get("requests_ok").is_some());

    let second = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(second);
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "no busy frame on the refused socket");
    assert!(
        line.contains("busy: connection limit 1"),
        "expected a busy error frame, got {line}"
    );
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "refused socket must be closed");

    // the occupied slot keeps working, then frees cleanly
    assert!(first.stats().unwrap().get("requests_ok").is_some());
    drop(first);
    handle.join().unwrap().unwrap();
}

#[test]
fn stats_prometheus_text_over_tcp() {
    let oracle = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 1, 31);
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(5));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let mut client = Client::connect(&addr).unwrap();
    let resp = client
        .call(&Request {
            id: 1,
            prompt: items[0].prompt.clone(),
            method: Method::Streaming,
            policy: None,
            gen_len: 64,
            deadline_ms: None,
            park_on_miss: false,
        })
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);

    let body = client.stats_text().unwrap();
    assert!(body.ends_with("# EOF\n"), "text stats must end with the terminator");
    assert!(body.contains("# TYPE sdllm_submitted counter\nsdllm_submitted 1\n"), "{body}");
    assert!(body.contains("sdllm_answered 1\n"), "{body}");
    assert!(body.contains("sdllm_rejected 0\n"), "{body}");

    // line framing is intact: the same connection still answers JSON
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("requests_ok").unwrap().as_usize(), Some(1));
    drop(client);
    handle.join().unwrap().unwrap();
}

/// Reference backend with an artificial per-decode delay — makes batch
/// runs take long enough that mid-flight admission is deterministic to
/// observe, without depending on wall-clock luck.
struct SlowBackend {
    inner: ReferenceBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.inner.special()
    }

    fn wants_p0(&self) -> bool {
        self.inner.wants_p0()
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.inner.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.inner.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.inner.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.inner.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<RefKv> {
        self.inner.prefill(batch, p_bucket, tokens, pos, valid, p0)
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> anyhow::Result<DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.decode(kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<DecodeOut> {
        self.inner.logits(batch, s_bucket, tokens, pos, valid, p0)
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        self.inner.detokenize(ids)
    }
}

#[test]
fn router_serves_mid_flight_join() {
    // Request A decodes a long answer (content past its whole generation
    // region → early exit never fires → 32 full block rounds, slowed to
    // ~2ms per decode step). Request B arrives while A's batch is
    // mid-flight; its prompt sits past the content boundary, so its whole
    // generation is EOS and it early-exits within its first block round.
    // B must join A's running batch and complete long before A drains —
    // the continuous-batching acceptance path.
    let boundary = 300usize;
    let router = RouterHandle::spawn_with(
        move || {
            Ok(SlowBackend {
                inner: ReferenceBackend::scripted(boundary),
                delay: Duration::from_millis(2),
            })
        },
        2,
        Duration::from_millis(1),
    );
    let metrics = router.metrics.clone();

    let rx_a = router.submit(Request {
        id: 1,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 256,
        deadline_ms: None,
        park_on_miss: false,
    });
    // wait (bounded) until A's engine has actually started
    let t0 = Instant::now();
    loop {
        let started = metrics.snapshot().get("batches").unwrap().as_usize().unwrap_or(0);
        if started >= 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "engine never started");
        std::thread::sleep(Duration::from_millis(2));
    }
    let rx_b = router.submit(Request {
        id: 2,
        prompt: vec![2; 301],
        method: Method::Streaming,
        policy: None,
        gen_len: 256,
        deadline_ms: None,
        park_on_miss: false,
    });

    let resp_b = rx_b.recv_timeout(Duration::from_secs(20)).expect("B never completed");
    assert!(resp_b.error.is_none(), "{:?}", resp_b.error);
    assert_eq!(resp_b.non_eos_tokens, 0, "B's generation is pure EOS");
    // B finished while A was still decoding: A's reply must not exist yet
    assert!(
        rx_a.try_recv().is_err(),
        "B should complete without waiting for A's batch to drain"
    );

    let resp_a = rx_a.recv_timeout(Duration::from_secs(120)).expect("A never completed");
    assert!(resp_a.error.is_none(), "{:?}", resp_a.error);
    assert!(resp_a.non_eos_tokens > 0);

    // shutdown drains the worker's final events (Retired carries the
    // engine-round totals) before the counters are inspected
    router.shutdown().unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap.get("joins").unwrap().as_usize(), Some(1), "B must join mid-flight");
    assert!(snap.get("engine_rounds").unwrap().as_usize().unwrap() >= 32);
}

#[test]
fn short_row_retirement_frees_slot_for_next_join() {
    // Per-row block budgets: request A decodes gen_len 256 (content past
    // its whole generation region → 32 slow block rounds), B joins
    // mid-flight with gen_len 16 and retires after its *own* two block
    // rounds — freeing the slot while A continues — and C then joins
    // into exactly that freed slot. Both short requests must complete
    // long before A drains, and both admissions must be mid-flight
    // joins (engine capacity is 2, so this only works if B's
    // retirement actually released its slot).
    let boundary = 300usize;
    let router = RouterHandle::spawn_with(
        move || {
            Ok(SlowBackend {
                inner: ReferenceBackend::scripted(boundary),
                delay: Duration::from_millis(2),
            })
        },
        2,
        Duration::from_millis(1),
    );
    let metrics = router.metrics.clone();

    let rx_a = router.submit(Request {
        id: 1,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 256,
        deadline_ms: None,
        park_on_miss: false,
    });
    let t0 = Instant::now();
    loop {
        let started = metrics.snapshot().get("batches").unwrap().as_usize().unwrap_or(0);
        if started >= 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "engine never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    let rx_b = router.submit(Request {
        id: 2,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 16,
        deadline_ms: Some(5_000),
        park_on_miss: false,
    });
    let resp_b = rx_b.recv_timeout(Duration::from_secs(20)).expect("B never completed");
    assert!(resp_b.error.is_none(), "{:?}", resp_b.error);
    assert!(rx_a.try_recv().is_err(), "B must finish while A is still decoding");

    // B's slot is free again: C joins the same still-running engine
    let rx_c = router.submit(Request {
        id: 3,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 16,
        deadline_ms: None,
        park_on_miss: false,
    });
    let resp_c = rx_c.recv_timeout(Duration::from_secs(20)).expect("C never completed");
    assert!(resp_c.error.is_none(), "{:?}", resp_c.error);
    assert!(
        rx_a.try_recv().is_err(),
        "C should complete in B's freed slot without waiting for A's batch to drain"
    );

    let resp_a = rx_a.recv_timeout(Duration::from_secs(120)).expect("A never completed");
    assert!(resp_a.error.is_none(), "{:?}", resp_a.error);
    assert!(resp_a.non_eos_tokens > 0);

    let snap = metrics.snapshot();
    assert_eq!(snap.get("joins").unwrap().as_usize(), Some(2), "B and C must join mid-flight");
    assert_eq!(snap.get("batches").unwrap().as_usize(), Some(1), "one engine serves all three");
    router.shutdown().unwrap();
    let snap = metrics.snapshot();
    assert!(
        snap.get("mixed_len_rounds").unwrap().as_usize().unwrap() >= 1,
        "rounds with 16- and 256-length rows live together must be counted as mixed"
    );
    assert_eq!(
        snap.get("admissions").unwrap().as_usize(),
        Some(3),
        "batch-start + join admissions must conserve"
    );
    assert_eq!(snap.get("batch_started").unwrap().as_usize(), Some(1));
}

// ---------------------------------------------------------------------
// Tier 2: artifact manifests — runs when `make artifacts` has been run;
// loudly skips otherwise. Pure manifest parsing, no xla.
// ---------------------------------------------------------------------

#[test]
fn manifests_load_for_all_models() {
    let Some(root) = artifacts() else { return };
    let index = ArtifactsIndex::load(&root).expect("index.json present but unreadable");
    assert!(!index.models.is_empty());
    for m in &index.models {
        let man = Manifest::load(&index.model_dir(m)).expect("manifest unreadable");
        assert_eq!(&man.model, m);
        assert!(!man.artifacts.is_empty());
        assert!(!man.param_order.is_empty());
        assert_eq!(man.special.mask, 1);
        assert_eq!(man.special.eos, 3);
        // every declared bucket combination exists for decode
        for &b in &man.batch_buckets {
            for &p in &man.prefix_buckets {
                for &q in &man.query_buckets {
                    man.entry(ExeKey { kind: ExeKind::Decode, batch: b, len: p, query: q })
                        .unwrap();
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Tier 3: PJRT runtime smoke — needs `--features pjrt` AND artifacts.
// ---------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_tier_skipped_without_feature() {
    eprintln!("SKIP: built without `--features pjrt`; PJRT runtime tests not compiled");
}

#[cfg(feature = "pjrt")]
mod pjrt_tier {
    use super::*;
    use std::sync::{Mutex, MutexGuard, OnceLock};

    use streaming_dllm::eval::load_suite;
    use streaming_dllm::runtime::{ModelRuntime, Runtime};

    /// PJRT CPU clients are not safe to create concurrently from
    /// multiple test threads; serialize every test that touches the
    /// runtime.
    fn serial() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        match LOCK.get_or_init(|| Mutex::new(())).lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn load(model: &str) -> Option<(Runtime, ModelRuntime)> {
        let root = artifacts()?;
        let index = ArtifactsIndex::load(&root).unwrap();
        let rt = Runtime::cpu().unwrap();
        let mrt = ModelRuntime::load(&rt, &index.model_dir(model)).unwrap();
        Some((rt, mrt))
    }

    #[test]
    fn prefill_decode_logits_smoke() {
        let _g = serial();
        let Some((_rt, mrt)) = load("llada15-mini") else { return };
        let b = 1;
        let p = *mrt.manifest.prefix_buckets.first().unwrap();
        let q = *mrt.manifest.query_buckets.first().unwrap();
        let tokens = vec![2i32; b * p];
        let pos: Vec<i32> = (0..p as i32).collect();
        let valid = vec![8i32];
        let kv = mrt.prefill(b, p, &tokens, &pos, &valid, None).unwrap();
        assert_eq!(kv.p_bucket, p);

        let q_tok = vec![1i32; b * q];
        let q_pos: Vec<i32> = (8..8 + q as i32).collect();
        let q_valid = vec![q as i32];
        let out = mrt.decode(&kv, q, &q_tok, &q_pos, &q_valid).unwrap();
        assert_eq!(out.data.len(), b * q * 2);
        for i in 0..q {
            let tok = out.token(0, i);
            let conf = out.conf(0, i);
            assert!((0..54).contains(&tok), "token {tok} out of vocab");
            assert!((0.0..=1.0001).contains(&conf), "conf {conf} out of range");
        }

        let s = *mrt.manifest.seq_buckets.first().unwrap();
        let toks = vec![2i32; b * s];
        let pos: Vec<i32> = (0..s as i32).collect();
        let s_valid = vec![16i32];
        let out = mrt.logits(b, s, &toks, &pos, &s_valid, None).unwrap();
        assert_eq!(out.data.len(), b * s * 2);
    }

    #[test]
    fn all_methods_terminate_and_produce_text() {
        let _g = serial();
        let Some((_rt, mrt)) = load("llada15-mini") else { return };
        let root = artifacts().unwrap();
        let items = load_suite(&root.join("eval/gsm-mini.jsonl")).unwrap();
        let item = &items[0];
        for method in Method::all() {
            let cfg = GenConfig::preset(method, 64);
            let mut generator = Generator::new(&mrt, cfg.clone()).unwrap();
            let mut seqs = vec![SeqState::new(&item.prompt, 64, &mrt.manifest.special)];
            let report = generator.generate(&mut seqs, None).unwrap();
            assert!(seqs[0].finished, "{} did not finish", method.name());
            assert!(report.steps > 0);
            assert!(seqs[0].generated().iter().all(|&t| t != mrt.manifest.special.mask));
            let text = mrt.manifest.detokenize_until_eos(seqs[0].generated());
            assert!(!text.is_empty(), "{} produced empty text", method.name());
        }
    }

    #[test]
    fn streaming_uses_fewer_steps_than_vanilla() {
        let _g = serial();
        let Some((_rt, mrt)) = load("llada15-mini") else { return };
        let root = artifacts().unwrap();
        let items = load_suite(&root.join("eval/gsm-mini.jsonl")).unwrap();
        let mut steps = std::collections::HashMap::new();
        for method in [Method::Vanilla, Method::FastDllm, Method::Streaming] {
            let cfg = GenConfig::preset(method, 64);
            let mut generator = Generator::new(&mrt, cfg).unwrap();
            let mut total = 0u64;
            for item in items.iter().take(3) {
                let mut seqs = vec![SeqState::new(&item.prompt, 64, &mrt.manifest.special)];
                let report = generator.generate(&mut seqs, None).unwrap();
                total += report.steps;
            }
            steps.insert(method.name(), total);
        }
        assert!(
            steps["streaming"] < steps["fast-dllm"],
            "streaming {} !< fast-dllm {}",
            steps["streaming"],
            steps["fast-dllm"]
        );
        assert!(steps["fast-dllm"] < steps["vanilla"]);
    }

    #[test]
    fn streaming_preserves_vanilla_accuracy() {
        let _g = serial();
        let Some((_rt, mrt)) = load("llada15-mini") else { return };
        let root = artifacts().unwrap();
        let items = load_suite(&root.join("eval/gsm-mini.jsonl")).unwrap();
        // The paper's quality claim is *relative*: acceleration must not
        // degrade accuracy vs the vanilla schedule (Tables 1/2/8 show
        // ours within ±1.5 points of vanilla).
        let res_v =
            run_suite(&mrt, &GenConfig::preset(Method::Vanilla, 64), &items[..20], None).unwrap();
        let res_s =
            run_suite(&mrt, &GenConfig::preset(Method::Streaming, 64), &items[..20], None).unwrap();
        assert!(
            res_s.accuracy() + 15.0 >= res_v.accuracy(),
            "streaming {:.1}% far below vanilla {:.1}%",
            res_s.accuracy(),
            res_v.accuracy()
        );
    }

    #[test]
    fn batched_generation_matches_single() {
        let _g = serial();
        let Some((_rt, mrt)) = load("llada15-mini") else { return };
        let root = artifacts().unwrap();
        let items = load_suite(&root.join("eval/math-mini.jsonl")).unwrap();
        let cfg = GenConfig::preset(Method::Streaming, 64);
        let mut generator = Generator::new(&mrt, cfg.clone()).unwrap();

        let mut singles = vec![];
        for item in items.iter().take(2) {
            let mut seqs = vec![SeqState::new(&item.prompt, 64, &mrt.manifest.special)];
            generator.generate(&mut seqs, None).unwrap();
            singles.push(mrt.manifest.detokenize_until_eos(seqs[0].generated()));
        }
        let mut seqs: Vec<SeqState> = items
            .iter()
            .take(2)
            .map(|it| SeqState::new(&it.prompt, 64, &mrt.manifest.special))
            .collect();
        generator.generate(&mut seqs, None).unwrap();
        let batched: Vec<String> =
            seqs.iter().map(|s| mrt.manifest.detokenize_until_eos(s.generated())).collect();
        assert_eq!(singles, batched);
    }

    #[test]
    fn server_end_to_end_roundtrip() {
        let _g = serial();
        let Some(root) = artifacts() else { return };
        let items = load_suite(&root.join("eval/mbpp-mini.jsonl")).unwrap();
        let router =
            RouterHandle::spawn(root.clone(), "llada15-mini".into(), 4, Duration::from_millis(5));
        let metrics = router.metrics.clone();
        let server = Server::bind("127.0.0.1:0", router).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve_n(1));

        let mut client = Client::connect(&addr).unwrap();
        let stats = client.stats().unwrap();
        assert!(stats.get("requests_ok").is_some());
        for (i, item) in items.iter().take(2).enumerate() {
            let resp = client
                .call(&Request {
                    id: i as u64,
                    prompt: item.prompt.clone(),
                    method: Method::Streaming,
                    policy: None,
                    gen_len: 64,
                    deadline_ms: None,
                    park_on_miss: false,
                })
                .unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert!(!resp.text.is_empty());
            assert!(resp.latency_s > 0.0);
        }
        drop(client);
        handle.join().unwrap().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.get("requests_ok").unwrap().as_usize(), Some(2));
        assert!(snap.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn block_causal_model_serves_table7_path() {
        let _g = serial();
        let Some((_rt, mrt)) = load("pangu-mini") else { return };
        assert!(mrt.manifest.wants_p0);
        assert_eq!(mrt.manifest.attn_mode, "block_causal");
        let root = artifacts().unwrap();
        let items = load_suite(&root.join("eval/gsm-mini.jsonl")).unwrap();
        // temporal-only streaming: suffix pruning degenerates (w=0)
        let mut cfg = GenConfig::preset(Method::Streaming, 64);
        cfg.set_window(0);
        cfg.set_trailing(false);
        let mut generator = Generator::new(&mrt, cfg).unwrap();
        let mut seqs = vec![SeqState::new(&items[0].prompt, 64, &mrt.manifest.special)];
        let report = generator.generate(&mut seqs, None).unwrap();
        assert!(seqs[0].finished);
        assert!(report.steps > 0);
    }
}
