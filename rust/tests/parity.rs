//! Golden parity: the workspace-reuse decode core must be bit-identical
//! to the seed implementation it replaced.
//!
//! `seed_path` below is a faithful replica of the pre-workspace
//! generator hot path (fresh bundle/candidate/buffer allocations every
//! step, `SeqState` clone round-trip, block-lockstep batch march) built
//! on the same public API. Running both against identically-seeded
//! reference backends must produce the same canvases, the same NFE
//! count and the same prefill count — for the schedule-independent toy
//! mode *and* the schedule-dependent causal mode, where any divergence
//! in call order, buffer layout or commit order would corrupt the
//! confidence stream and show up as different tokens.

use streaming_dllm::engine::{
    prefix_scope_for, select, select_soa, Backend, BatchEngine, Candidate, GenConfig, Generator,
    Method, PrefixHandle, RefMode, ReferenceBackend, SeqState, SharedPrefixCache, TemporalPolicy,
    Trend, REFERENCE_SEED,
};
use streaming_dllm::eval::{extract_final, synthetic_suite};

/// The seed-path replica shared with `benches/host_overhead.rs` (the
/// `before` arm there): fresh allocations every step, clone round-trip,
/// block lockstep — see `tests/common/seed_path.rs`.
#[path = "common/seed_path.rs"]
mod seed_path;

const PROMPTS: [&[i32]; 4] = [
    &[2, 10, 11, 12],
    &[2, 15, 16, 17, 18, 19],
    &[2, 20, 21, 22, 23, 24, 25],
    &[2, 5, 6, 7, 47],
];

fn backend(mode: RefMode) -> ReferenceBackend {
    match mode {
        RefMode::Causal => ReferenceBackend::causal(REFERENCE_SEED),
        _ => ReferenceBackend::toy(REFERENCE_SEED),
    }
}

/// Decode-thread fan-out under test. CI re-runs this whole suite with
/// `SDLLM_DECODE_THREADS=4`: every production-side config here picks
/// the knob up, while the seed replica stays scalar — so the threaded
/// merge is pinned bit-identical against the same golden outputs.
fn decode_threads() -> usize {
    std::env::var("SDLLM_DECODE_THREADS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Apply the suite's decode-thread setting to a production config.
fn tune(mut cfg: GenConfig) -> GenConfig {
    cfg.decode_threads = decode_threads();
    cfg
}

/// Run the production generator over `prompts` as one batch.
fn run_new(mode: RefMode, cfg: &GenConfig, prompts: &[&[i32]]) -> (Vec<Vec<i32>>, u64, u64) {
    let be = backend(mode);
    let mut generator = Generator::new(&be, tune(cfg.clone())).unwrap();
    let mut seqs: Vec<SeqState> =
        prompts.iter().map(|p| SeqState::new(p, cfg.gen_len, &be.special())).collect();
    let report = generator.generate(&mut seqs, None).unwrap();
    (seqs.into_iter().map(|s| s.tokens).collect(), report.steps, report.prefills)
}

/// Run the seed replica over `prompts` as one batch.
fn run_seed(mode: RefMode, cfg: &GenConfig, prompts: &[&[i32]]) -> (Vec<Vec<i32>>, u64, u64) {
    let be = backend(mode);
    let mut seqs: Vec<SeqState> =
        prompts.iter().map(|p| SeqState::new(p, cfg.gen_len, &be.special())).collect();
    let report = seed_path::generate(&be, cfg, &mut seqs).unwrap();
    (seqs.into_iter().map(|s| s.tokens).collect(), report.steps, report.prefills)
}

fn assert_parity(mode: RefMode, cfg: &GenConfig, prompts: &[&[i32]], label: &str) {
    let (new_tokens, new_steps, new_prefills) = run_new(mode, cfg, prompts);
    let (seed_tokens, seed_steps, seed_prefills) = run_seed(mode, cfg, prompts);
    assert_eq!(new_tokens, seed_tokens, "canvas diverged: {label}");
    assert_eq!(new_steps, seed_steps, "NFE diverged: {label}");
    assert_eq!(new_prefills, seed_prefills, "prefills diverged: {label}");
}

#[test]
fn toy_decode_bit_identical_to_seed_path() {
    for method in Method::all() {
        let cfg = GenConfig::preset(method, 64);
        for p in PROMPTS {
            assert_parity(RefMode::Toy, &cfg, &[p], &format!("toy {} single", method.name()));
        }
        assert_parity(
            RefMode::Toy,
            &cfg,
            &[PROMPTS[0], PROMPTS[1]],
            &format!("toy {} batch-2 (padded to bucket 4)", method.name()),
        );
    }
}

#[test]
fn causal_decode_bit_identical_to_seed_path() {
    // the schedule-dependent mode: any change in call order, buffer
    // contents or commit order shifts the confidence stream and the
    // committed chain — exact parity is the strongest regression signal
    let mut fast = GenConfig::preset(Method::FastDllm, 64);
    fast.set_tau0(0.7); // aggressive: plenty of guessed commits
    let configs: Vec<(GenConfig, &str)> = vec![
        (GenConfig::preset(Method::Streaming, 64), "streaming"),
        (fast, "fast-dllm tau=0.7"),
        (GenConfig::preset(Method::PrefixCache, 64), "prefix-cache"),
        (GenConfig::preset(Method::DkvCache, 64), "dkv-cache"),
        (GenConfig::preset(Method::Vanilla, 64), "vanilla"),
    ];
    for (cfg, label) in &configs {
        for p in PROMPTS {
            assert_parity(RefMode::Causal, cfg, &[p], &format!("causal {label} single"));
        }
        assert_parity(
            RefMode::Causal,
            cfg,
            &[PROMPTS[2], PROMPTS[3]],
            &format!("causal {label} batch-2 (padded to bucket 4)"),
        );
    }
}

#[test]
fn remask_and_pruning_variants_bit_identical_to_seed_path() {
    let mut cfg = GenConfig::preset(Method::Streaming, 64);
    cfg.remask = true;
    cfg.remask_tau = 0.8;
    cfg.set_window(8);
    cfg.set_trailing(false);
    for mode in [RefMode::Toy, RefMode::Causal] {
        assert_parity(mode, &cfg, &[PROMPTS[0]], &format!("{} remask variant", mode.name()));
        assert_parity(
            mode,
            &cfg,
            &[PROMPTS[1], PROMPTS[2]],
            &format!("{} remask variant batch-2", mode.name()),
        );
    }
}

#[test]
fn workspace_reuse_is_deterministic_across_calls() {
    // the same generator (and thus the same recycled workspace) must
    // produce identical output on repeated calls — stale scratch
    // contents leaking between calls would break this
    let be = backend(RefMode::Causal);
    let mut generator =
        Generator::new(&be, tune(GenConfig::preset(Method::Streaming, 64))).unwrap();
    let mut outs = vec![];
    for _ in 0..3 {
        let mut seqs = vec![SeqState::new(PROMPTS[0], 64, &be.special())];
        generator.generate(&mut seqs, None).unwrap();
        outs.push(seqs.pop().unwrap().tokens);
    }
    // causal draws are keyed by the backend call counter, so re-runs on
    // one backend legitimately differ; determinism is vs a fresh
    // backend replaying the same call sequence
    let be2 = backend(RefMode::Causal);
    let mut generator2 =
        Generator::new(&be2, tune(GenConfig::preset(Method::Streaming, 64))).unwrap();
    let mut seqs = vec![SeqState::new(PROMPTS[0], 64, &be2.special())];
    generator2.generate(&mut seqs, None).unwrap();
    assert_eq!(outs[0], seqs[0].tokens);
}

#[test]
fn mixed_gen_len_batch_bit_identical_to_solo() {
    // Heterogeneous batch: rows with gen lengths {64, 16, 32, 64}
    // decode together in one BatchEngine, each retiring on its own
    // block budget. Every row's full canvas must be bit-identical to
    // the same request run solo at its own length — in toy mode
    // (schedule-independent by construction, checked with Streaming)
    // and in causal mode (sequential PrefixCache decoding only commits
    // fully-determined predictions, so batchmates cannot perturb it).
    let lens = [64usize, 16, 32, 64];
    for (mode, method) in
        [(RefMode::Toy, Method::Streaming), (RefMode::Causal, Method::PrefixCache)]
    {
        let be = backend(mode);
        let cfg = tune(GenConfig::preset(method, 64));
        let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
        for (i, (&p, len)) in PROMPTS.iter().zip(lens).enumerate() {
            assert!(engine.admit(i as u64, p, len), "admit row {i} (gen {len})");
        }
        let mut canvases: std::collections::HashMap<u64, Vec<i32>> = Default::default();
        let mut guard = 0;
        while engine.active() > 0 {
            guard += 1;
            assert!(guard < 1000, "engine failed to drain");
            for f in engine.step_block().unwrap() {
                canvases.insert(f.tag, f.seq.tokens.clone());
            }
        }
        assert_eq!(canvases.len(), lens.len());
        assert!(engine.mixed_rounds() > 0, "mixed-length rounds must be observed");

        for (i, (&p, len)) in PROMPTS.iter().zip(lens).enumerate() {
            let be2 = backend(mode);
            let mut generator =
                Generator::new(&be2, tune(GenConfig::preset(method, len))).unwrap();
            let mut seqs = vec![SeqState::new(p, len, &be2.special())];
            generator.generate(&mut seqs, None).unwrap();
            assert_eq!(
                canvases[&(i as u64)],
                seqs[0].tokens,
                "{} row {i} (gen {len}) diverged from its solo decode",
                mode.name()
            );
        }
    }
}

/// Drain one `BatchEngine` over `prompts` (admitted up front), with the
/// prefix cache optionally attached, returning per-row final canvases.
fn run_engine_cached(
    mode: RefMode,
    cfg: &GenConfig,
    prompts: &[&[i32]],
    cache: Option<&SharedPrefixCache>,
) -> Vec<Vec<i32>> {
    let be = backend(mode);
    let mut engine = BatchEngine::new(&be, tune(cfg.clone()), prompts.len()).unwrap();
    if let Some(cache) = cache {
        let scope = prefix_scope_for(&be, engine.config());
        engine.set_prefix_cache(PrefixHandle { cache: cache.clone(), scope });
    }
    for (i, p) in prompts.iter().enumerate() {
        assert!(engine.admit(i as u64, p, cfg.gen_len), "admit row {i}");
    }
    let mut canvases = vec![vec![]; prompts.len()];
    let mut guard = 0;
    while engine.active() > 0 {
        guard += 1;
        assert!(guard < 1000, "engine failed to drain");
        for f in engine.step_block().unwrap() {
            canvases[f.tag as usize] = f.seq.tokens.clone();
        }
    }
    canvases
}

#[test]
fn prefix_cache_warm_decode_bit_identical_to_cold() {
    // the cache's core contract: captures shorten prefill work but
    // never change a single committed token. Covered for the
    // schedule-independent toy mode, the schedule-dependent causal
    // mode, and the dkv-cache method whose mid-block re-prefills reuse
    // the span pinned at admission.
    for (mode, method) in [
        (RefMode::Toy, Method::Streaming),
        (RefMode::Causal, Method::Streaming),
        (RefMode::Causal, Method::DkvCache),
    ] {
        let cfg = GenConfig::preset(method, 64);
        let label = format!("{} {}", mode.name(), method.name());
        let baseline = run_engine_cached(mode, &cfg, &PROMPTS, None);

        let cache = SharedPrefixCache::new(1 << 20);
        let cold = run_engine_cached(mode, &cfg, &PROMPTS, Some(&cache));
        assert_eq!(cold, baseline, "cache-attached cold run diverged: {label}");
        let populated = cache.stats();
        assert!(populated.inserts > 0, "cold run inserted nothing: {label}");

        let warm = run_engine_cached(mode, &cfg, &PROMPTS, Some(&cache));
        assert_eq!(warm, baseline, "warm run diverged from cold: {label}");
        let stats = cache.stats();
        assert!(stats.hits > populated.hits, "warm run never hit the cache: {label}");
        assert!(
            stats.reused_tokens > populated.reused_tokens,
            "warm run reused no prompt tokens: {label}"
        );
        cache.check_invariants();
    }
}

#[test]
fn engine_row_output_stable_under_mid_flight_joins_causal() {
    // sequential (one-per-step) decoding under the causal model only
    // ever commits fully-determined predictions, so a row's output must
    // equal the sequential oracle no matter which rows join or leave
    // its batch mid-flight
    let oracle = ReferenceBackend::causal(REFERENCE_SEED);
    let items = synthetic_suite(&oracle, 4, 0xA11);
    let be = ReferenceBackend::causal(REFERENCE_SEED);
    let cfg = tune(GenConfig::preset(Method::PrefixCache, 64));
    let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
    let mut texts: std::collections::HashMap<u64, String> = std::collections::HashMap::new();

    // stagger admissions: row i joins after i rounds of the running batch
    assert!(engine.admit(0, &items[0].prompt, 64));
    let mut next = 1usize;
    let mut guard = 0;
    while engine.active() > 0 || next < items.len() {
        guard += 1;
        assert!(guard < 2000, "engine failed to drain");
        if next < items.len() && engine.has_free_slot() {
            assert!(engine.admit(next as u64, &items[next].prompt, 64));
            next += 1;
        }
        for f in engine.step_block().unwrap() {
            texts.insert(f.tag, be.detokenize(f.seq.generated()));
        }
    }
    assert_eq!(texts.len(), items.len());
    for (i, item) in items.iter().enumerate() {
        assert_eq!(
            extract_final(&texts[&(i as u64)]),
            item.answer,
            "row {i} diverged from the sequential oracle under mid-flight joins"
        );
    }
}

#[test]
fn mid_flight_joins_hitting_the_cache_stay_bit_identical_causal() {
    // same staggered-join schedule as above, run three times on fresh
    // backends: no cache, cache-cold (populates), cache-warm (joining
    // rows hit captures published moments earlier). All three must
    // produce identical texts — a join that lands on a warm cache is
    // the production fast path and must not perturb a single token.
    let suite_be = ReferenceBackend::causal(REFERENCE_SEED);
    let items = synthetic_suite(&suite_be, 4, 0xA11);
    let run = |cache: Option<&SharedPrefixCache>| -> Vec<String> {
        let be = ReferenceBackend::causal(REFERENCE_SEED);
        let cfg = tune(GenConfig::preset(Method::PrefixCache, 64));
        let mut engine = BatchEngine::new(&be, cfg, 4).unwrap();
        if let Some(cache) = cache {
            let scope = prefix_scope_for(&be, engine.config());
            engine.set_prefix_cache(PrefixHandle { cache: cache.clone(), scope });
        }
        let mut texts = vec![String::new(); items.len()];
        assert!(engine.admit(0, &items[0].prompt, 64));
        let mut next = 1usize;
        let mut guard = 0;
        while engine.active() > 0 || next < items.len() {
            guard += 1;
            assert!(guard < 2000, "engine failed to drain");
            if next < items.len() && engine.has_free_slot() {
                assert!(engine.admit(next as u64, &items[next].prompt, 64));
                next += 1;
            }
            for f in engine.step_block().unwrap() {
                texts[f.tag as usize] = be.detokenize(f.seq.generated());
            }
        }
        texts
    };

    let baseline = run(None);
    let cache = SharedPrefixCache::new(1 << 20);
    let cold = run(Some(&cache));
    let populated = cache.stats();
    assert!(populated.inserts > 0, "staggered cold pass inserted nothing");
    let warm = run(Some(&cache));
    assert_eq!(cold, baseline, "cache-attached staggered run diverged");
    assert_eq!(warm, baseline, "warm staggered run diverged");
    let stats = cache.stats();
    assert!(stats.hits > populated.hits, "joining rows never hit the cache");
    cache.check_invariants();
}

#[test]
fn vector_parity_chunked_selection_matches_scalar() {
    // The SoA/chunked selection kernel (`select_soa`) must agree with
    // the scalar reference (`select` over `Candidate`s) for every
    // temporal policy, on randomized inputs whose sizes straddle the
    // chunk width — including exact multiples, off-by-ones and tiny
    // remainders.
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (state >> 33) as u32
    };
    let policies = [
        TemporalPolicy::OnePerStep,
        TemporalPolicy::FixedTau { tau: 0.8 },
        TemporalPolicy::DynamicTau { tau0: 0.9, alpha: 0.5 },
        TemporalPolicy::Extrapolating {
            tau0: 0.9,
            alpha: 0.5,
            gain: 2.0,
            floor: 0.5,
            min_streak: 2,
        },
    ];
    let pinned_sizes = [1usize, 2, 7, 8, 9, 15, 16, 17, 24, 33];
    for iter in 0..600 {
        let n = if iter < pinned_sizes.len() {
            pinned_sizes[iter]
        } else {
            1 + (next() % 40) as usize
        };
        let r_mask = (next() % 1001) as f32 / 1000.0;
        let cands: Vec<Candidate> = (0..n)
            .map(|i| Candidate {
                pos: i,
                token: (next() % 100) as i32,
                conf: (next() % 1001) as f32 / 1000.0,
            })
            .collect();
        let trends: Vec<Trend> = (0..n)
            .map(|_| Trend { prev_conf: (next() % 1001) as f32 / 1000.0, streak: next() % 4 })
            .collect();
        let conf: Vec<f32> = cands.iter().map(|c| c.conf).collect();
        for policy in &policies {
            let scalar = select(policy, r_mask, &cands, &trends);
            let mut soa = Vec::new();
            select_soa(policy, r_mask, &conf, &trends, &mut soa);
            assert_eq!(
                soa, scalar,
                "select_soa diverged from scalar select: iter {iter}, n {n}, {policy:?}"
            );
            assert!(!soa.is_empty(), "selection must always commit at least one position");
        }
    }
}
