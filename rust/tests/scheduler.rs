//! Scheduler tests over the scripted ReferenceBackend: no artifacts
//! needed. These pin the generator's control-flow invariants —
//! termination under arbitrary confidence streams, early-exit
//! semantics, per-method call accounting (prefill counts for dKV vs
//! prefix-cache), and bundle/bucket behavior.

use streaming_dllm::engine::{
    build_bundle, GenConfig, Generator, Method, ReferenceBackend, SeqState, SpatialPolicy,
    SpecialTokens,
};
use streaming_dllm::util::prop;

fn seq(backend: &ReferenceBackend, prompt_len: usize, gen_len: usize) -> SeqState {
    let prompt: Vec<i32> = std::iter::once(backend.special.bos)
        .chain((0..prompt_len as i32 - 1).map(|i| 10 + (i % 30)))
        .collect();
    SeqState::new(&prompt, gen_len, &backend.special)
}

/// The scripted backend emits content below `answer_abs` absolute
/// position and EOS after — so with prompt_len=16 and answer_abs=24,
/// 8 content tokens.
fn backend(answer_abs: usize) -> ReferenceBackend {
    ReferenceBackend::scripted(answer_abs)
}

#[test]
fn all_methods_terminate_on_mock() {
    for method in Method::all() {
        let be = backend(24);
        let cfg = GenConfig::preset(method, 64);
        let mut generator = Generator::new(&be, cfg).unwrap();
        let mut seqs = vec![seq(&be, 16, 64)];
        let report = generator.generate(&mut seqs, None).unwrap();
        assert!(seqs[0].finished, "{}", method.name());
        assert!(report.steps > 0);
        assert!(
            seqs[0].generated().iter().all(|&t| t != be.special.mask),
            "{} left masks",
            method.name()
        );
    }
}

#[test]
fn early_exit_skips_blocks_and_saves_steps() {
    // answer ends at absolute 20 (prompt 16 + 4 content tokens) — blocks
    // 1..7 are pure EOS, early exit should skip them.
    let be = backend(20);
    let mut with = GenConfig::preset(Method::Streaming, 64);
    with.early_exit = true;
    let mut without = with.clone();
    without.early_exit = false;

    let mut g1 = Generator::new(&be, with).unwrap();
    let mut s1 = vec![seq(&be, 16, 64)];
    let r1 = g1.generate(&mut s1, None).unwrap();

    let be2 = backend(20);
    let mut g2 = Generator::new(&be2, without).unwrap();
    let mut s2 = vec![seq(&be2, 16, 64)];
    let r2 = g2.generate(&mut s2, None).unwrap();

    assert!(r1.blocks_skipped > 0, "no blocks skipped");
    assert!(r1.steps < r2.steps, "early exit did not save steps: {} vs {}", r1.steps, r2.steps);
    // same content either way
    assert_eq!(s1[0].non_eos_tokens(), s2[0].non_eos_tokens());
}

#[test]
fn blocks_skipped_counts_each_real_row_exactly_once() {
    // answer ends at absolute 20 (prompt 16 + 4 content tokens), so a
    // row early-exits inside block 0 and skips blocks 1..8: exactly 7.
    // The seed path double-counted: the all-finished fast path re-added
    // every remaining block (and counted dummy padding rows too).
    let be = backend(20);
    let cfg = GenConfig::preset(Method::Streaming, 64);
    let mut g = Generator::new(&be, cfg.clone()).unwrap();
    let mut s = vec![seq(&be, 16, 64)];
    let r = g.generate(&mut s, None).unwrap();
    assert_eq!(r.blocks_skipped, 7, "single row must count its skipped blocks once");

    // two real rows padded to bucket 4: 7 per real row, dummies excluded
    let be2 = backend(20);
    let mut g2 = Generator::new(&be2, cfg).unwrap();
    let mut s2 = vec![seq(&be2, 16, 64), seq(&be2, 16, 64)];
    let r2 = g2.generate(&mut s2, None).unwrap();
    assert_eq!(r2.blocks_skipped, 14, "padding rows must not contribute skipped blocks");
}

#[test]
fn dkv_pays_more_prefills_than_prefix_cache() {
    let be1 = backend(70);
    let cfg = GenConfig::preset(Method::DkvCache, 64);
    let mut g = Generator::new(&be1, cfg).unwrap();
    let mut s = vec![seq(&be1, 16, 64)];
    g.generate(&mut s, None).unwrap();
    let dkv_prefills = be1.calls.borrow().prefills;

    let be2 = backend(70);
    let cfg = GenConfig::preset(Method::PrefixCache, 64);
    let mut g = Generator::new(&be2, cfg).unwrap();
    let mut s = vec![seq(&be2, 16, 64)];
    g.generate(&mut s, None).unwrap();
    let pc_prefills = be2.calls.borrow().prefills;

    assert!(dkv_prefills > pc_prefills, "dkv {dkv_prefills} !> prefix-cache {pc_prefills}");
    // prefix-cache: exactly one prefill per block
    assert_eq!(pc_prefills, 8);
}

#[test]
fn vanilla_never_prefills_and_uses_full_forwards() {
    let be = backend(70);
    let cfg = GenConfig::preset(Method::Vanilla, 64);
    let mut g = Generator::new(&be, cfg).unwrap();
    let mut s = vec![seq(&be, 16, 64)];
    let report = g.generate(&mut s, None).unwrap();
    let calls = be.calls.borrow().clone();
    assert_eq!(calls.prefills, 0);
    assert_eq!(calls.decodes, 0);
    assert_eq!(calls.logits, report.steps);
    // one commit per step → steps == gen_len
    assert_eq!(report.steps, 64);
}

#[test]
fn parallel_decoding_uses_fewer_steps_than_one_per_step() {
    let be1 = backend(70);
    // high confidences from the mock (base 0.5..1.0); τ0=0.6 commits many
    let mut fast = GenConfig::preset(Method::FastDllm, 64);
    fast.set_tau0(0.6);
    let mut g = Generator::new(&be1, fast).unwrap();
    let mut s = vec![seq(&be1, 16, 64)];
    let r_fast = g.generate(&mut s, None).unwrap();

    let be2 = backend(70);
    let cfg = GenConfig::preset(Method::PrefixCache, 64);
    let mut g = Generator::new(&be2, cfg).unwrap();
    let mut s = vec![seq(&be2, 16, 64)];
    let r_pc = g.generate(&mut s, None).unwrap();

    assert!(r_fast.steps < r_pc.steps, "{} !< {}", r_fast.steps, r_pc.steps);
}

#[test]
fn batch_padding_preserves_real_rows() {
    let be = backend(24);
    let cfg = GenConfig::preset(Method::Streaming, 64);
    let mut g = Generator::new(&be, cfg).unwrap();
    // 2 real rows → padded to bucket 4 internally
    let mut seqs = vec![seq(&be, 16, 64), seq(&be, 12, 64)];
    let report = g.generate(&mut seqs, None).unwrap();
    assert!(seqs.iter().all(|s| s.finished));
    // non_eos counts only the two real rows
    let expected: u64 = seqs.iter().map(|s| s.non_eos_tokens() as u64).sum();
    assert_eq!(report.non_eos_tokens, expected);
}

#[test]
fn prop_terminates_under_any_confidence_stream() {
    prop::check(60, |g| {
        let answer_abs = g.usize(8, 60);
        let prompt_len = g.usize(2, 30);
        let gen_len = [16, 32, 64][g.usize(0, 2)];
        let method = Method::all()[g.usize(0, 4)];
        let mut be = backend(answer_abs);
        be.base_conf = g.f32(0.0, 0.9);
        be.conf_seed = g.usize(0, 1 << 30) as u64;
        let mut cfg = GenConfig::preset(method, gen_len);
        cfg.set_tau0(g.f32(0.3, 1.0));
        cfg.set_alpha(g.f32(0.0, 0.9));
        cfg.set_window(g.usize(0, 40));
        let mut generator = Generator::new(&be, cfg).map_err(|e| e.to_string())?;
        let mut seqs = vec![seq(&be, prompt_len, gen_len)];
        let report = generator.generate(&mut seqs, None).map_err(|e| e.to_string())?;
        if !seqs[0].finished {
            return Err("sequence not finished".into());
        }
        if seqs[0].generated().iter().any(|&t| t == be.special.mask) {
            return Err("mask left in canvas".into());
        }
        if report.steps == 0 {
            return Err("zero steps".into());
        }
        Ok(())
    });
}

#[test]
fn prop_early_exit_never_loses_content() {
    // with the mock's deterministic content/EOS split, early exit must
    // not change the number of content tokens
    prop::check(40, |g| {
        let prompt_len = g.usize(4, 24);
        let content = g.usize(1, 30);
        let answer_abs = prompt_len + content;
        let run = |exit: bool, seed: u64| -> Result<usize, String> {
            let mut be = backend(answer_abs);
            be.conf_seed = seed;
            let mut cfg = GenConfig::preset(Method::Streaming, 64);
            cfg.early_exit = exit;
            let mut generator = Generator::new(&be, cfg).map_err(|e| e.to_string())?;
            let mut seqs = vec![seq(&be, prompt_len, 64)];
            generator.generate(&mut seqs, None).map_err(|e| e.to_string())?;
            Ok(seqs[0].non_eos_tokens())
        };
        let seed = g.usize(0, 1 << 30) as u64;
        let with = run(true, seed)?;
        let without = run(false, seed)?;
        if with != without {
            return Err(format!("content changed: {with} vs {without}"));
        }
        Ok(())
    });
}

#[test]
fn remasking_terminates_and_adds_bounded_steps() {
    let be1 = backend(70);
    let mut cfg = GenConfig::preset(Method::Streaming, 64);
    cfg.remask = true;
    cfg.remask_tau = 0.8; // mock confs ∈ [0.5, 1.0] → plenty of remasks
    cfg.early_exit = false;
    let mut g = Generator::new(&be1, cfg).unwrap();
    let mut s = vec![seq(&be1, 16, 64)];
    let r_remask = g.generate(&mut s, None).unwrap();
    assert!(s[0].finished);
    assert!(s[0].generated().iter().all(|&t| t != be1.special.mask));

    let be2 = backend(70);
    let mut cfg2 = GenConfig::preset(Method::Streaming, 64);
    cfg2.early_exit = false;
    let mut g2 = Generator::new(&be2, cfg2).unwrap();
    let mut s2 = vec![seq(&be2, 16, 64)];
    let r_plain = g2.generate(&mut s2, None).unwrap();
    // revision costs extra steps, but bounded (≤ one extra pass per block)
    assert!(r_remask.steps >= r_plain.steps);
    assert!(r_remask.steps <= r_plain.steps + 64 * 2);
}

#[test]
fn prop_bundle_invariants_under_random_geometry() {
    // suffix::build_bundle across random p0/gen_len/block/window:
    // positions strictly increasing (hence duplicate-free), the block
    // prefix exact, and total length ≤ block + window + 1 (Eq. 7's
    // Ĩ ∪ {p_L + L} bound).
    prop::check(200, |g| {
        let block = [2usize, 4, 8, 16][g.usize(0, 3)];
        let n_blocks = g.usize(1, 10);
        let gen_len = block * n_blocks;
        let p0 = g.usize(1, 40);
        let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
        cfg.block_size = block;
        cfg.set_window(g.usize(0, 48));
        cfg.set_trailing(g.bool(0.5));
        let prompt: Vec<i32> = (0..p0).map(|i| 5 + (i % 36) as i32).collect();
        let mut s = SeqState::new(&prompt, gen_len, &SpecialTokens::default());
        s.block = g.usize(0, n_blocks - 1);
        let b = build_bundle(&s, &cfg);
        for w in b.positions.windows(2) {
            if w[1] <= w[0] {
                return Err(format!("positions not strictly increasing: {:?}", b.positions));
            }
        }
        let (bs, be) = s.block_span(s.block, block);
        if b.block_len != be - bs {
            return Err(format!("block_len {} != span {}", b.block_len, be - bs));
        }
        if b.positions[..b.block_len] != (bs..be).collect::<Vec<_>>()[..] {
            return Err("bundle does not start with the exact block".into());
        }
        if b.positions.len() > block + cfg.window() + 1 {
            return Err(format!(
                "bundle len {} > block {} + window {} + 1",
                b.positions.len(),
                block,
                cfg.window()
            ));
        }
        if *b.positions.last().unwrap() >= s.total_len() {
            return Err("position beyond the canvas".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bundle_prune_off_equals_full_suffix() {
    // with pruning disabled the bundle must be the block plus the whole
    // remaining suffix, and every pruned bundle is a subset of it
    prop::check(120, |g| {
        let block = [4usize, 8][g.usize(0, 1)];
        let n_blocks = g.usize(1, 8);
        let gen_len = block * n_blocks;
        let p0 = g.usize(1, 24);
        let mut pruned = GenConfig::preset(Method::Streaming, gen_len);
        pruned.block_size = block;
        pruned.set_window(g.usize(0, 32));
        let mut full = pruned.clone();
        full.set_suffix_pruning(false);
        let prompt: Vec<i32> = (0..p0).map(|i| 5 + (i % 36) as i32).collect();
        let mut s = SeqState::new(&prompt, gen_len, &SpecialTokens::default());
        s.block = g.usize(0, n_blocks - 1);
        let fb = build_bundle(&s, &full);
        let (bs, _) = s.block_span(s.block, block);
        if fb.positions != (bs..s.total_len()).collect::<Vec<_>>() {
            return Err(format!("prune-off bundle is not the full suffix: {:?}", fb.positions));
        }
        let pb = build_bundle(&s, &pruned);
        if !pb.positions.iter().all(|p| fb.positions.contains(p)) {
            return Err("pruned bundle not a subset of the full bundle".into());
        }
        Ok(())
    });
}

#[test]
fn prop_every_spatial_policy_bundles_a_subset_containing_the_block() {
    // the tentpole spatial invariant across ALL four variants: the
    // bundle is a strictly increasing subset of {block ∪ suffix} that
    // starts with the exact current block and never exceeds the
    // policy's worst-case length
    prop::check(200, |g| {
        let block = [4usize, 8][g.usize(0, 1)];
        let n_blocks = g.usize(1, 8);
        let gen_len = block * n_blocks;
        let p0 = g.usize(1, 24);
        let window = g.usize(0, 32);
        let trailing = g.bool(0.5);
        let mut cfg = GenConfig::preset(Method::Streaming, gen_len);
        cfg.block_size = block;
        cfg.policy.spatial = match g.usize(0, 3) {
            0 => SpatialPolicy::FullSuffix,
            1 => SpatialPolicy::Window { window, trailing },
            2 => SpatialPolicy::Attenuating {
                window,
                min_window: g.usize(0, window.max(1)),
                trailing,
            },
            _ => SpatialPolicy::Dropout {
                window,
                stride: g.usize(1, 8),
                seed: g.usize(0, 1 << 30) as u64,
                trailing,
            },
        };
        let prompt: Vec<i32> = (0..p0).map(|i| 5 + (i % 36) as i32).collect();
        let mut s = SeqState::new(&prompt, gen_len, &SpecialTokens::default());
        s.block = g.usize(0, n_blocks - 1);
        let b = build_bundle(&s, &cfg);
        let (bs, be) = s.block_span(s.block, block);
        if b.positions[..b.block_len] != (bs..be).collect::<Vec<_>>()[..] {
            return Err("bundle does not start with the exact block".into());
        }
        for w in b.positions.windows(2) {
            if w[1] <= w[0] {
                return Err(format!("positions not strictly increasing: {:?}", b.positions));
            }
        }
        // the post-block tail lives strictly inside the suffix
        if b.positions[b.block_len..].iter().any(|&p| p < be || p >= s.total_len()) {
            return Err("bundle position outside the suffix".into());
        }
        if b.positions.len() > cfg.policy.spatial.max_bundle_len(block, gen_len) {
            return Err(format!(
                "bundle len {} exceeds the policy's worst case {}",
                b.positions.len(),
                cfg.policy.spatial.max_bundle_len(block, gen_len)
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_remasking_always_terminates() {
    prop::check(40, |g| {
        let mut be = backend(g.usize(8, 60));
        be.base_conf = g.f32(0.0, 0.9);
        be.conf_seed = g.usize(0, 1 << 30) as u64;
        let mut cfg = GenConfig::preset(Method::Streaming, 32);
        cfg.remask = true;
        cfg.remask_tau = g.f32(0.0, 1.0);
        cfg.set_tau0(g.f32(0.3, 1.0));
        let mut generator = Generator::new(&be, cfg).map_err(|e| e.to_string())?;
        let mut seqs = vec![seq(&be, g.usize(2, 24), 32)];
        generator.generate(&mut seqs, None).map_err(|e| e.to_string())?;
        if !seqs[0].finished {
            return Err("not finished".into());
        }
        Ok(())
    });
}
