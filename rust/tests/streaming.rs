//! Streaming acceptance tests for the v1 wire protocol and the
//! SLA-aware parking path:
//!
//! 1. An in-process subscriber reassembles the out-of-order commit
//!    events into exactly the text the non-streaming oracle returns.
//! 2. The same property holds over TCP: `Client::subscribe` frames
//!    rebuild a canvas whose detokenization is bit-identical to a
//!    `call_v1` one-shot response for the same prompt.
//! 3. A `park_on_miss` request whose deadline blows mid-decode is
//!    evicted at a block boundary and answered with the `parked`
//!    terminal state — without disturbing its batch neighbors.
//! 4. A subscriber that disconnects mid-stream gets its row cancelled:
//!    the server detects the dead connection on the failed relay write
//!    and the worker evicts the row instead of decoding into the void.

use std::time::Duration;

use streaming_dllm::coordinator::{
    Client, Request, RouterHandle, Server, ServerFrame, StreamFrame,
};
use streaming_dllm::engine::{
    Backend, DecodeOut, DecodePolicy, GenConfig, Generator, Method, RefKv, ReferenceBackend,
    SeqState, SpecialTokens, REFERENCE_SEED,
};
use streaming_dllm::eval::{extract_final, synthetic_suite};

/// Apply a gapless commit-event stream to a fresh all-mask canvas and
/// detokenize the result (the subscriber-side reassembly rule).
fn reassemble(
    be: &ReferenceBackend,
    gen_len: usize,
    commits: &[(u64, u64, Vec<(usize, i32, f32)>)],
    id: u64,
) -> String {
    let mut canvas = vec![be.special().mask; gen_len];
    for (i, (cid, seq, writes)) in commits.iter().enumerate() {
        assert_eq!(*cid, id, "commit for a foreign row leaked into the stream");
        assert_eq!(*seq, i as u64, "commit seq must be gapless from 0");
        for &(off, tok, _conf) in writes {
            assert!(off < gen_len, "write offset {off} outside generation region");
            canvas[off] = tok;
        }
    }
    be.detokenize(&canvas)
}

/// Solo decode of `prompt` with `method`'s preset and the named decode
/// policy swapped in — the per-policy oracle the served texts must
/// match.
fn solo_policy_text(
    be: &ReferenceBackend,
    prompt: &[i32],
    method: Method,
    policy: &str,
) -> String {
    let mut cfg = GenConfig::preset(method, 64);
    cfg.policy = DecodePolicy::parse(policy).unwrap();
    let mut generator = Generator::new(be, cfg).unwrap();
    let mut seqs = vec![SeqState::new(prompt, 64, &be.special())];
    generator.generate(&mut seqs, None).unwrap();
    be.detokenize(seqs[0].generated())
}

#[test]
fn subscriber_reassembles_to_oracle_text() {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 2, 31);
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(2));

    for (i, item) in items.iter().enumerate() {
        let gen_len = 64usize;
        let mk = |id: u64| Request {
            id,
            prompt: item.prompt.clone(),
            method: Method::Streaming,
            policy: None,
            gen_len,
            deadline_ms: None,
            park_on_miss: false,
        };
        // non-streaming oracle for the same prompt
        let oracle = router.call(mk(i as u64)).unwrap();
        assert!(oracle.error.is_none(), "{:?}", oracle.error);
        assert_eq!(extract_final(&oracle.text), item.answer);

        // streamed run: commits then exactly one Done
        let rx = router.subscribe(mk(100 + i as u64));
        let mut commits = Vec::new();
        let done = loop {
            match rx.recv_timeout(Duration::from_secs(30)).expect("stream stalled") {
                StreamFrame::Commit(c) => commits.push((c.id, c.seq, c.writes)),
                StreamFrame::Done(resp) => break resp,
            }
        };
        assert!(rx.try_recv().is_err(), "frames after Done");
        assert!(done.error.is_none(), "{:?}", done.error);
        assert!(!done.parked);
        assert!(!commits.is_empty(), "streamed row produced no commit events");

        let text = reassemble(&be, gen_len, &commits, 100 + i as u64);
        assert_eq!(text, done.text, "reassembled canvas diverged from the Done frame");
        assert_eq!(text, oracle.text, "streamed text diverged from the one-shot oracle");
    }
    router.shutdown().unwrap();
}

#[test]
fn tcp_subscribe_matches_call_v1_bit_for_bit() {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 1, 47);
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(2));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let gen_len = 64usize;
    let mk = |id: u64| Request {
        id,
        prompt: items[0].prompt.clone(),
        method: Method::Streaming,
        policy: None,
        gen_len,
        deadline_ms: None,
        park_on_miss: false,
    };
    let mut client = Client::connect(&addr).unwrap();
    let oneshot = client.call_v1(&mk(1)).unwrap();
    assert!(oneshot.error.is_none(), "{:?}", oneshot.error);

    let frames = client.subscribe(&mk(2)).unwrap();
    let mut commits = Vec::new();
    let mut done = None;
    for f in frames {
        match f {
            ServerFrame::Commit(c) => {
                assert!(done.is_none(), "commit after the terminal done frame");
                commits.push((c.id, c.seq, c.writes));
            }
            ServerFrame::Done(resp) => done = Some(resp),
        }
    }
    let done = done.expect("stream ended without a done frame");
    assert!(done.error.is_none(), "{:?}", done.error);
    assert!(!commits.is_empty());

    let text = reassemble(&be, gen_len, &commits, 2);
    assert_eq!(text, done.text, "wire reassembly diverged from the done frame");
    assert_eq!(done.text, oneshot.text, "streamed text != one-shot v1 text");

    drop(client);
    handle.join().unwrap().unwrap();
}

/// Reference backend with an artificial per-decode delay, so a long row
/// reliably outlives a small deadline budget (same device as the
/// mid-flight-join integration tests).
struct SlowBackend {
    inner: ReferenceBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    type Kv = RefKv;

    fn special(&self) -> SpecialTokens {
        self.inner.special()
    }

    fn wants_p0(&self) -> bool {
        self.inner.wants_p0()
    }

    fn pick_batch(&self, need: usize) -> Option<usize> {
        self.inner.pick_batch(need)
    }

    fn pick_prefix(&self, need: usize) -> Option<usize> {
        self.inner.pick_prefix(need)
    }

    fn pick_query(&self, need: usize) -> Option<usize> {
        self.inner.pick_query(need)
    }

    fn pick_seq(&self, need: usize) -> Option<usize> {
        self.inner.pick_seq(need)
    }

    fn prefill(
        &self,
        batch: usize,
        p_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<RefKv> {
        self.inner.prefill(batch, p_bucket, tokens, pos, valid, p0)
    }

    fn decode(
        &self,
        kv: &RefKv,
        q_bucket: usize,
        q_tok: &[i32],
        q_pos: &[i32],
        q_valid: &[i32],
    ) -> anyhow::Result<DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.decode(kv, q_bucket, q_tok, q_pos, q_valid)
    }

    fn logits(
        &self,
        batch: usize,
        s_bucket: usize,
        tokens: &[i32],
        pos: &[i32],
        valid: &[i32],
        p0: Option<&[i32]>,
    ) -> anyhow::Result<DecodeOut> {
        self.inner.logits(batch, s_bucket, tokens, pos, valid, p0)
    }

    fn detokenize(&self, ids: &[i32]) -> String {
        self.inner.detokenize(ids)
    }
}

#[test]
fn blown_deadline_parks_row_without_disturbing_neighbors() {
    // A and B decode long answers (content past the whole generation
    // region → 32 slow block rounds each). A opts into parking with a
    // 50ms budget it cannot meet; B rides with a generous budget and no
    // parking opt-in. A must come back
    // `parked` long before a full decode could finish, and B must still
    // drain to a complete, unparked answer.
    let boundary = 300usize;
    let router = RouterHandle::spawn_with(
        move || {
            Ok(SlowBackend {
                inner: ReferenceBackend::scripted(boundary),
                delay: Duration::from_millis(2),
            })
        },
        2,
        Duration::from_millis(1),
    );
    let metrics = router.metrics.clone();

    let rx_a = router.submit(Request {
        id: 1,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 256,
        deadline_ms: Some(50),
        park_on_miss: true,
    });
    // B's budget is generous (10 min) so the miss counter stays a pure
    // function of A's behavior even on a heavily loaded test machine
    let rx_b = router.submit(Request {
        id: 2,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 256,
        deadline_ms: Some(600_000),
        park_on_miss: false,
    });

    let resp_a = rx_a.recv_timeout(Duration::from_secs(30)).expect("A never answered");
    assert!(resp_a.error.is_none(), "{:?}", resp_a.error);
    assert!(resp_a.parked, "A blew its 50ms budget and must be parked");

    let resp_b = rx_b.recv_timeout(Duration::from_secs(120)).expect("B never completed");
    assert!(resp_b.error.is_none(), "{:?}", resp_b.error);
    assert!(!resp_b.parked, "B never opted into parking and must not be parked");
    assert!(resp_b.non_eos_tokens > 0);

    router.shutdown().unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap.get("parked").unwrap().as_usize(), Some(1));
    assert_eq!(
        snap.get("deadline_misses").unwrap().as_usize(),
        Some(0),
        "a parked row is answered on time by definition — it is not a miss"
    );
    assert_eq!(snap.get("requests_ok").unwrap().as_usize(), Some(2));
}

#[test]
fn tcp_subscriber_disconnect_cancels_row_and_frees_worker() {
    use std::io::{BufRead, BufReader, Write};

    // 32 slow block rounds (~200ms): the subscriber walks away after
    // two commits, so the worker must NOT decode the remaining ~30
    // rounds into the void — the server cancels the row on the first
    // failed relay write and the router evicts it at a block boundary.
    let boundary = 300usize;
    let router = RouterHandle::spawn_with(
        move || {
            Ok(SlowBackend {
                inner: ReferenceBackend::scripted(boundary),
                delay: Duration::from_millis(6),
            })
        },
        2,
        Duration::from_millis(1),
    );
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let metrics = server.metrics();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let req = Request {
        id: 7,
        prompt: vec![2; 4],
        method: Method::Streaming,
        policy: None,
        gen_len: 256,
        deadline_ms: None,
        park_on_miss: false,
    };
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut line = req.to_frame("subscribe").to_string();
    line.push('\n');
    stream.write_all(line.as_bytes()).unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for _ in 0..2 {
        let mut frame = String::new();
        assert!(reader.read_line(&mut frame).unwrap() > 0, "stream ended before any commit");
        assert!(frame.contains("\"commit\""), "expected a commit frame, got {frame}");
    }
    drop(reader);
    drop(stream); // mid-stream disconnect

    let t0 = std::time::Instant::now();
    loop {
        if metrics.snapshot().get("cancelled").unwrap().as_usize() == Some(1) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "row was never cancelled after the subscriber disconnected"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    handle.join().unwrap().unwrap();
    let snap = metrics.snapshot();
    assert_eq!(
        snap.get("answered").unwrap().as_usize(),
        Some(0),
        "a cancelled subscription must not count as answered"
    );
    assert_eq!(snap.get("requests_ok").unwrap().as_usize(), Some(0));
}

#[test]
fn wire_policy_override_decodes_one_token_per_step() {
    // A v1 subscribe naming the "vanilla" policy (full suffix ×
    // one-per-step) on the fast-dllm method must show one-per-step
    // commit granularity on the wire: exactly gen_len commit frames of
    // exactly one write each. The policy carried over the wire — not
    // the method's native parallel τ schedule — decides the commit
    // cadence, and the text still matches the solo decode of the same
    // method+policy pair.
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 1, 83);
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(2));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(1));

    let gen_len = 64usize;
    let req = Request {
        id: 21,
        prompt: items[0].prompt.clone(),
        method: Method::FastDllm,
        policy: DecodePolicy::parse("vanilla"),
        gen_len,
        deadline_ms: None,
        park_on_miss: false,
    };
    let mut client = Client::connect(&addr).unwrap();
    let frames = client.subscribe(&req).unwrap();
    let mut commits = Vec::new();
    let mut done = None;
    for f in frames {
        match f {
            ServerFrame::Commit(c) => commits.push((c.id, c.seq, c.writes)),
            ServerFrame::Done(resp) => done = Some(resp),
        }
    }
    let done = done.expect("stream ended without a done frame");
    assert!(done.error.is_none(), "{:?}", done.error);

    assert_eq!(commits.len(), gen_len, "one-per-step must take exactly one commit per token");
    for (_, seq, writes) in &commits {
        assert_eq!(writes.len(), 1, "commit {seq} batched writes under one-per-step");
    }
    let text = reassemble(&be, gen_len, &commits, 21);
    assert_eq!(text, done.text, "wire reassembly diverged from the done frame");
    assert_eq!(
        done.text,
        solo_policy_text(&be, &items[0].prompt, Method::FastDllm, "vanilla"),
        "served text diverged from the solo decode of the wire-selected policy"
    );

    drop(client);
    handle.join().unwrap().unwrap();
}

#[test]
fn concurrent_wire_requests_with_different_policies_match_solo_oracles() {
    // One served fleet decodes two different policies at once. The
    // batcher must keep the group keys apart (mixed-policy rows never
    // share an engine), and each response must equal the solo decode of
    // its own policy — the toy model is schedule-independent, so any
    // cross-policy contamination in routing or batching would surface
    // as a wrong answer or an error frame.
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let items = synthetic_suite(&be, 2, 59);
    let router = RouterHandle::spawn_reference(2, Duration::from_millis(2));
    let server = Server::bind("127.0.0.1:0", router).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_n(2));

    let mk = |id: u64, prompt: Vec<i32>, policy: &str| Request {
        id,
        prompt,
        method: Method::Streaming,
        policy: DecodePolicy::parse(policy),
        gen_len: 64,
        deadline_ms: None,
        park_on_miss: false,
    };
    let req_a = mk(31, items[0].prompt.clone(), "attenuating");
    let req_b = mk(32, items[1].prompt.clone(), "dropout");
    let addr_a = addr.clone();
    let ta =
        std::thread::spawn(move || Client::connect(&addr_a).unwrap().call_v1(&req_a).unwrap());
    let tb = std::thread::spawn(move || Client::connect(&addr).unwrap().call_v1(&req_b).unwrap());
    let resp_a = ta.join().unwrap();
    let resp_b = tb.join().unwrap();

    for r in [&resp_a, &resp_b] {
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.parked && !r.rejected && !r.shed);
    }
    assert_eq!(
        resp_a.text,
        solo_policy_text(&be, &items[0].prompt, Method::Streaming, "attenuating"),
        "attenuating response diverged from its solo-policy oracle"
    );
    assert_eq!(
        resp_b.text,
        solo_policy_text(&be, &items[1].prompt, Method::Streaming, "dropout"),
        "dropout response diverged from its solo-policy oracle"
    );
    assert_eq!(extract_final(&resp_a.text), items[0].answer);
    assert_eq!(extract_final(&resp_b.text), items[1].answer);
    handle.join().unwrap().unwrap();
}
