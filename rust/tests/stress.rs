//! Randomized scheduler stress harness: seeded random arrival schedules
//! (mixed methods, gen lengths, priorities, a sprinkling of oversized
//! prompts) driven through the full router, plus a pure-`Batcher`
//! randomized model check. Invariants pinned:
//!
//! 1. every request is answered exactly once (no drops, no duplicates)
//! 2. an oversized prompt fails alone — it never poisons a batch, and
//!    every well-formed request still decodes its solo-oracle text
//! 3. deadline ordering: slot claiming within a method group always
//!    takes the earliest effective deadline first
//! 4. metrics conservation: `joins + batch_started == admissions`, and
//!    every admission is answered ok
//! 5. streaming: a subscribed row's commit events carry gapless
//!    per-row sequence numbers from 0, and replaying their writes onto
//!    an all-mask canvas reassembles exactly the terminal text
//!
//! Seeds are printed per schedule and embedded in every assertion, so a
//! CI flake bisects to a single reproducible seed:
//! `SDLLM_STRESS_SEED_BASE=<seed> SDLLM_STRESS_SCHEDULES=1 cargo test --test stress`.
//! (Both knobs resolve through [`ServeConfig`], so `--schedules` /
//! `--seed-base` mean the same thing everywhere.)

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use streaming_dllm::coordinator::{
    Batcher, Request, Response, RouterHandle, ServeConfig, StreamFrame,
};
use streaming_dllm::engine::{
    Backend, GenConfig, Generator, Method, ReferenceBackend, SeqState, REFERENCE_SEED,
};
use streaming_dllm::util::rng::Rng;

fn stress_cfg() -> ServeConfig {
    ServeConfig::from_env().expect("invalid SDLLM_* stress configuration")
}

/// Solo decode of one request on a fresh toy backend — the oracle every
/// served row is checked against (toy mode is schedule-independent, so
/// batch composition must never change a row's text).
fn solo_text(prompt: &[i32], method: Method, gen_len: usize) -> String {
    let be = ReferenceBackend::toy(REFERENCE_SEED);
    let mut generator = Generator::new(&be, GenConfig::preset(method, gen_len)).unwrap();
    let mut seqs = vec![SeqState::new(prompt, gen_len, &be.special)];
    generator.generate(&mut seqs, None).unwrap();
    be.detokenize(seqs[0].generated())
}

struct Planned {
    req: Request,
    oversized: bool,
}

fn plan_schedule(rng: &mut Rng) -> Vec<Planned> {
    let n = rng.range(6, 14);
    let methods = Method::all();
    (0..n)
        .map(|i| {
            let oversized = rng.bool(0.12);
            let prompt: Vec<i32> = if oversized {
                // beyond the reference prefix/seq buckets (1056)
                vec![2; 1100]
            } else {
                std::iter::once(2)
                    .chain((0..rng.range(1, 9)).map(|_| rng.range(5, 45) as i32))
                    .collect()
            };
            let req = Request {
                id: i as u64,
                prompt,
                method: methods[rng.below(methods.len())],
                gen_len: *rng.choose(&[16usize, 32, 64]),
                deadline_ms: rng.bool(0.5).then(|| rng.range(0, 80) as u64),
                park_on_miss: false,
            };
            Planned { req, oversized }
        })
        .collect()
}

/// A planned request's reply channel: classic one-shot or a commit
/// stream (the randomized subset that exercises `subscribe`).
enum Rx {
    One(Receiver<Response>),
    Stream(Receiver<StreamFrame>),
}

/// Drain one subscription: collect commits until the terminal `Done`,
/// assert gapless per-row sequence numbers, and — for ok rows — that
/// replaying the writes onto an all-mask canvas reassembles exactly the
/// terminal text (out-of-order commits, retractions and all).
fn drain_stream(seed: u64, req: &Request, rx: &Receiver<StreamFrame>) -> Response {
    let mut commits = vec![];
    let resp = loop {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(StreamFrame::Commit(c)) => commits.push(c),
            Ok(StreamFrame::Done(r)) => break r,
            Err(e) => panic!("seed {seed}: stream for request {} stalled: {e}", req.id),
        }
    };
    assert!(
        rx.try_recv().is_err(),
        "seed {seed}: request {} streamed frames after Done",
        req.id
    );
    for (i, c) in commits.iter().enumerate() {
        assert_eq!(c.id, req.id, "seed {seed}: commit for the wrong row on request {}", req.id);
        assert_eq!(
            c.seq, i as u64,
            "seed {seed}: commit seq gap on request {} (got {}, want {i})",
            req.id, c.seq
        );
    }
    if resp.error.is_none() {
        let be = ReferenceBackend::toy(REFERENCE_SEED);
        let mut canvas = vec![be.special.mask; req.gen_len];
        for c in &commits {
            for &(off, tok, _conf) in &c.writes {
                assert!(off < canvas.len(), "seed {seed}: commit write out of range");
                canvas[off] = tok;
            }
        }
        assert_eq!(
            be.detokenize(&canvas),
            resp.text,
            "seed {seed}: reassembled stream diverged from terminal text on request {}",
            req.id
        );
    }
    resp
}

#[test]
fn randomized_schedules_answer_every_request_exactly_once() {
    let cfg = stress_cfg();
    let base = cfg.stress_seed_base;
    for s in 0..cfg.stress_schedules {
        let seed = base.wrapping_add(s);
        eprintln!("[stress] schedule seed {seed}");
        let mut rng = Rng::new(seed ^ 0x5DCE_DDE5);
        let max_batch = rng.range(2, 4);
        let router = RouterHandle::spawn_reference(max_batch, Duration::from_millis(1));
        let metrics = router.metrics.clone();

        let planned = plan_schedule(&mut rng);
        let mut receivers = vec![];
        for p in &planned {
            // a random subset subscribes to the commit stream instead of
            // a one-shot reply; both paths must answer exactly once
            if rng.bool(0.35) {
                receivers.push(Rx::Stream(router.subscribe(p.req.clone())));
            } else {
                receivers.push(Rx::One(router.submit(p.req.clone())));
            }
            if rng.bool(0.35) {
                // stagger arrivals so some requests start batches and
                // others join mid-flight
                std::thread::sleep(Duration::from_millis(rng.range(1, 3) as u64));
            }
        }

        let mut ok = 0usize;
        let mut err = 0usize;
        for (p, rx) in planned.iter().zip(&receivers) {
            let resp = match rx {
                Rx::One(rx) => {
                    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap_or_else(|e| {
                        panic!("seed {seed}: request {} unanswered: {e}", p.req.id)
                    });
                    // exactly once: the reply channel must never carry a
                    // second message for the same request
                    assert!(
                        rx.try_recv().is_err(),
                        "seed {seed}: request {} answered more than once",
                        p.req.id
                    );
                    resp
                }
                Rx::Stream(rx) => drain_stream(seed, &p.req, rx),
            };
            assert_eq!(resp.id, p.req.id, "seed {seed}: reply routed to the wrong request");
            if p.oversized {
                err += 1;
                let msg = resp.error.as_deref().unwrap_or_else(|| {
                    panic!("seed {seed}: oversized request {} must fail", p.req.id)
                });
                assert!(msg.contains("buckets"), "seed {seed}: wrong oversize error: {msg}");
            } else {
                ok += 1;
                assert!(
                    resp.error.is_none(),
                    "seed {seed}: request {} ({}, gen {}) failed: {:?}",
                    p.req.id,
                    p.req.method.name(),
                    p.req.gen_len,
                    resp.error
                );
                // oversized batchmates must not have poisoned this row
                assert_eq!(
                    resp.text,
                    solo_text(&p.req.prompt, p.req.method, p.req.gen_len),
                    "seed {seed}: request {} ({}, gen {}) diverged from its solo decode",
                    p.req.id,
                    p.req.method.name(),
                    p.req.gen_len
                );
            }
        }

        router.shutdown().unwrap_or_else(|e| panic!("seed {seed}: router died: {e:#}"));
        let snap = metrics.snapshot();
        let get = |k: &str| snap.get(k).unwrap().as_usize().unwrap();
        assert_eq!(get("requests_ok"), ok, "seed {seed}: ok-count conservation");
        assert_eq!(get("requests_err"), err, "seed {seed}: err-count conservation");
        assert_eq!(
            get("joins") + get("batch_started"),
            get("admissions"),
            "seed {seed}: joins + batch-starts must equal admissions"
        );
        assert_eq!(
            get("admissions"),
            ok,
            "seed {seed}: every admission must be answered ok (toy backend never poisons)"
        );
    }
}

// ---------------------------------------------------------------------
// Pure-batcher model check: deadline ordering + conservation, no router
// timing involved, so the invariant is exact.
// ---------------------------------------------------------------------

/// Shadow entry mirroring the batcher's effective-deadline order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct Shadow {
    id: u64,
    method_ix: usize,
    deadline: Instant,
    arrived: Instant,
}

impl Shadow {
    fn urgency(&self) -> (Instant, Instant) {
        (self.deadline, self.arrived)
    }
}

#[test]
fn randomized_batcher_respects_deadline_order_and_conserves_requests() {
    let cfg = stress_cfg();
    let base = cfg.stress_seed_base;
    for s in 0..cfg.stress_schedules {
        let seed = base.wrapping_add(s);
        let mut rng = Rng::new(seed ^ 0xBA7C_4E12);
        let max_batch = rng.range(1, 6);
        let mut b = Batcher::new(max_batch, Duration::from_millis(5));
        let methods = Method::all();
        let t0 = Instant::now();
        let mut clock_ms = 0u64;
        let mut next_id = 0u64;
        let mut model: Vec<Shadow> = vec![];
        let mut popped_ids: Vec<u64> = vec![];
        let mut pushed = 0usize;

        for _ in 0..rng.range(30, 80) {
            clock_ms += 1; // distinct arrivals → total order, no ties
            let now = t0 + Duration::from_millis(clock_ms);
            match rng.below(3) {
                0 => {
                    let method_ix = rng.below(methods.len());
                    let deadline_ms = rng.bool(0.6).then(|| rng.range(0, 40) as u64);
                    let req = Request {
                        id: next_id,
                        prompt: vec![2],
                        method: methods[method_ix],
                        gen_len: *rng.choose(&[16usize, 64]),
                        deadline_ms,
                        park_on_miss: false,
                    };
                    let deadline =
                        now + deadline_ms.map(Duration::from_millis).unwrap_or(b.default_sla);
                    b.push_at(req, now);
                    model.push(Shadow { id: next_id, method_ix, deadline, arrived: now });
                    next_id += 1;
                    pushed += 1;
                }
                1 => {
                    let method_ix = rng.below(methods.len());
                    let got = b.pop_compatible(methods[method_ix]);
                    let want = model
                        .iter()
                        .filter(|e| e.method_ix == method_ix)
                        .min_by_key(|e| e.urgency())
                        .copied();
                    match (got, want) {
                        (None, None) => {}
                        (Some(r), Some(w)) => {
                            assert_eq!(
                                r.id,
                                w.id,
                                "seed {seed}: pop_compatible must take the earliest deadline"
                            );
                            model.retain(|e| e.id != w.id);
                            popped_ids.push(r.id);
                        }
                        (got, want) => panic!(
                            "seed {seed}: pop_compatible disagreed with model: \
                             got {got:?} want {want:?}"
                        ),
                    }
                }
                _ => {
                    if let Some((method, batch)) = b.pop_ready(now, &[]) {
                        assert!(
                            !batch.is_empty() && batch.len() <= max_batch,
                            "seed {seed}: bad batch size {}",
                            batch.len()
                        );
                        let method_ix = methods.iter().position(|m| *m == method).unwrap();
                        // the batch is exactly the n most urgent waiters
                        // of its group, most urgent first
                        let mut expect: Vec<Shadow> = model
                            .iter()
                            .filter(|e| e.method_ix == method_ix)
                            .copied()
                            .collect();
                        expect.sort_by_key(|e| e.urgency());
                        for (r, w) in batch.iter().zip(&expect) {
                            assert_eq!(r.method, method, "seed {seed}: mixed-method batch");
                            assert_eq!(
                                r.id,
                                w.id,
                                "seed {seed}: batch must drain in deadline order"
                            );
                        }
                        for r in &batch {
                            model.retain(|e| e.id != r.id);
                            popped_ids.push(r.id);
                        }
                    }
                }
            }
        }

        // drain whatever is left; nothing may be lost or duplicated
        for (ix, m) in methods.iter().enumerate() {
            while let Some(r) = b.pop_compatible(*m) {
                let want = model
                    .iter()
                    .filter(|e| e.method_ix == ix)
                    .min_by_key(|e| e.urgency())
                    .copied()
                    .unwrap_or_else(|| panic!("seed {seed}: popped unknown id {}", r.id));
                assert_eq!(r.id, want.id, "seed {seed}: drain must follow deadline order");
                model.retain(|e| e.id != r.id);
                popped_ids.push(r.id);
            }
        }
        assert!(model.is_empty(), "seed {seed}: batcher lost requests: {model:?}");
        assert_eq!(popped_ids.len(), pushed, "seed {seed}: pop count != push count");
        popped_ids.sort_unstable();
        popped_ids.dedup();
        assert_eq!(popped_ids.len(), pushed, "seed {seed}: duplicate pops");
        assert_eq!(b.pending(), 0, "seed {seed}: batcher still holds requests");
    }
}
